"""Sharding rules + multi-device behaviour.

Two flavours of multi-device coverage:

  * ``@pytest.mark.slow`` subprocess tests (8 forced host devices in a child
    process: the main tier-1 process must keep seeing 1 device per the
    assignment) — full train/decode steps.
  * in-process ``@multidevice`` tests for the shard-mapped batch-compression
    layer (``sharding/batch.py``): they need the test process itself to see
    8 devices, so they skip under plain tier-1 and run in the CI
    ``multidevice`` lane (``make test-multidevice``, which sets
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import lzss, pipeline
from repro.sharding import batch as shbatch, rules

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 host devices: run via `make test-multidevice` "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def test_spec_mapping():
    assert rules.spec_for(("embed", "heads", "head_dim")) == P(
        "data", "model", None
    )
    assert rules.spec_for(("experts", "embed", "expert_ffn")) == P(
        "model", "data", None
    )
    assert rules.spec_for(("vocab", "embed_out")) == P("model", "data")


def test_zero_spec_adds_data_once():
    import jax

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # already data-sharded -> unchanged
    assert rules.zero_spec(P("data", "model"), (16, 16), mesh) == P(
        "data", "model"
    )
    # free dim gets data
    got = rules.zero_spec(P(None, "model"), (16, 16), mesh)
    assert got == P("data", "model")


def _run_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=540,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ------------------------------------------------- sharded batch layer


def _buffers(seed, b):
    """Ragged run-heavy + noisy buffers (matches + literals per container)."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(b):
        n = 40 + 7 * i
        runs = np.repeat(rng.integers(0, 10, n), rng.integers(1, 6, n))
        noise = rng.integers(0, 256, 60)
        out.append(np.concatenate([runs, noise, runs]).astype(np.uint8))
    return out


def test_sharded_registry_pair_registered():
    assert "sharded" in lzss.available_backends()
    assert "sharded" in lzss.available_decoders()


def test_sharded_config_validation():
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="batch_axis requires mesh"):
        lzss.LZSSConfig(batch_axis="data")
    with pytest.raises(ValueError, match="only consulted by the 'sharded'"):
        lzss.LZSSConfig(mesh=mesh)  # neither backend nor decoder sharded
    with pytest.raises(ValueError, match="not in mesh axes"):
        lzss.LZSSConfig(backend="sharded", mesh=mesh, batch_axis="pod")
    # decode-only sharding is a valid combination (compress elsewhere)
    cfg = lzss.LZSSConfig(decoder="sharded", mesh=mesh)
    assert cfg.backend == "xla"


def test_kv_store_compress_side_sharding_only():
    """mesh + an explicitly non-sharded decoder shards compression only;
    restore must fall back to the single-device dispatch, not conflict."""
    from repro.serving.kvcache import KVBlockStore

    mesh = jax.make_mesh((1,), ("data",))
    store = KVBlockStore(compress=True, mesh=mesh, decoder="xla-parallel")
    assert store.config.backend == "sharded"
    assert store.config.decoder == "xla-parallel"
    block = np.repeat(np.arange(64, dtype=np.int16), 16)
    store.evict("b", block)
    assert np.array_equal(store.restore("b"), block)


def test_runner_axes_and_shard_count():
    mesh = jax.make_mesh((1,), ("data",))
    r = shbatch.ShardedBatchRunner(mesh)
    assert r.axes == ("data",) and r.n_shards == 1
    assert shbatch.ShardedBatchRunner(mesh, ("data",)).axes == ("data",)
    assert shbatch.ShardedBatchRunner(None).n_shards == 1
    with pytest.raises(ValueError, match="not in mesh axes"):
        shbatch.normalize_batch_axes(mesh, "pod")


def test_unsharded_resolves_to_platform_dispatch():
    cfg = lzss.LZSSConfig(backend="sharded", decoder="sharded")
    inner = shbatch.unsharded(cfg)
    assert inner.backend == pipeline.default_backend()
    assert inner.decoder == pipeline.default_decoder()
    assert inner.mesh is None and inner.batch_axis is None
    # non-sharded configs pass through untouched
    plain = lzss.LZSSConfig(backend="xla", decoder="xla-parallel")
    assert shbatch.unsharded(plain) is plain


def test_sharded_degenerate_matches_xla_byte_for_byte():
    """Without a mesh the 'sharded' pair must be the platform dispatch."""
    items = _buffers(0, 3)
    kw = dict(symbol_size=1, window=32, chunk_symbols=64)
    ref = lzss.compress_many(items, lzss.LZSSConfig(**kw))
    got = lzss.compress_many(
        items, lzss.LZSSConfig(**kw, backend="sharded", decoder="sharded")
    )
    assert np.array_equal(ref.data, got.data)
    outs = lzss.decompress_many(got, decoder="sharded")
    for item, out in zip(items, outs):
        assert np.array_equal(out, item)
    # single-buffer path delegates too
    one = lzss.compress(
        items[0], lzss.LZSSConfig(**kw, backend="sharded", decoder="sharded")
    )
    assert np.array_equal(one.data, lzss.compress(items[0], lzss.LZSSConfig(**kw)).data)


def test_sharded_entropy_degenerate_matches_single_device():
    """Entropy (method-1) batches thread through the sharded runner: with a
    1-device mesh the containers must be byte-identical to the meshless
    entropy dispatch, and decode must route the per-shard inner decoder to
    'deflate-full' automatically."""
    from repro.core import format as fmt

    mesh = jax.make_mesh((1,), ("data",))
    items = _buffers(11, 3)
    kw = dict(symbol_size=1, window=32, chunk_symbols=64,
              backend="deflate-full")
    ref = lzss.compress_many(items, lzss.LZSSConfig(**kw))
    got = lzss.compress_many(items, lzss.LZSSConfig(**kw, mesh=mesh))
    assert np.array_equal(ref.data, got.data)
    assert np.array_equal(ref.total_bytes, got.total_bytes)
    assert fmt.parse_header(got.data[0]).method == fmt.METHOD_HUFFMAN
    for mesh_arg in (None, mesh):
        outs = lzss.decompress_many(got, mesh=mesh_arg)
        for item, out in zip(items, outs):
            assert np.array_equal(out, item), mesh_arg is None
    # an explicit raw decoder on the entropy batch stays a clean error
    with pytest.raises(ValueError, match="entropy"):
        lzss.decompress_many(got, decoder="xla-parallel")


@multidevice
def test_sharded_entropy_byte_identity_8dev():
    """Forced 8-device mesh, uneven B: sharded entropy compression is
    byte-identical to single-device, and the sharded decode reconstructs."""
    mesh = jax.make_mesh((8,), ("data",))
    items = _buffers(12, 5)
    kw = dict(symbol_size=1, window=32, chunk_symbols=64,
              backend="deflate-full")
    ref = lzss.compress_many(items, lzss.LZSSConfig(**kw))
    got = lzss.compress_many(items, lzss.LZSSConfig(**kw, mesh=mesh))
    assert np.array_equal(ref.data, got.data)
    assert np.array_equal(ref.total_bytes, got.total_bytes)
    outs = lzss.decompress_many(got, mesh=mesh)
    for item, out in zip(items, outs):
        assert np.array_equal(out, item)


@multidevice
@pytest.mark.parametrize("b", [8, 5, 11])
def test_sharded_byte_identity_vs_single_device(b):
    """Forced 8-device mesh: blobs byte-identical, totals identical, for B
    divisible and not divisible by the mesh axis size."""
    mesh = jax.make_mesh((8,), ("data",))
    kw = dict(symbol_size=1, window=32, chunk_symbols=64)
    items = _buffers(b, b)
    ref = lzss.compress_many(items, lzss.LZSSConfig(**kw))
    cfg = lzss.LZSSConfig(**kw, backend="sharded", decoder="sharded", mesh=mesh)
    got = lzss.compress_many(items, cfg)
    assert np.array_equal(ref.data, got.data)
    assert np.array_equal(ref.total_bytes, got.total_bytes)
    # sharded + unsharded decode both reconstruct the originals exactly
    for decoder, mesh_arg in [
        ("xla-parallel", None),
        ("sharded", None),
        ("auto", mesh),
    ]:
        outs = lzss.decompress_many(got, decoder=decoder, mesh=mesh_arg)
        for i, (item, out) in enumerate(zip(items, outs)):
            assert np.array_equal(out, item), (decoder, mesh_arg is None, i)


@multidevice
def test_sharded_cross_product_sweep_8dev():
    """S x W sweep, compressor x decoder cross-product including 'sharded',
    uneven B (6 buffers over 8 shards)."""
    mesh = jax.make_mesh((8,), ("data",))
    items = _buffers(3, 6)
    for s in (1, 2):
        for w in (32, 255):
            kw = dict(symbol_size=s, window=w, chunk_symbols=64)
            ref = lzss.compress_many(items, lzss.LZSSConfig(**kw))
            for backend in ("xla", "fused", "fused-mono", "sharded"):
                if backend == "sharded":
                    cfg = lzss.LZSSConfig(
                        **kw, backend="sharded", decoder="sharded", mesh=mesh
                    )
                else:
                    cfg = lzss.LZSSConfig(**kw, backend=backend)
                got = lzss.compress_many(items, cfg)
                assert np.array_equal(ref.data, got.data), (s, w, backend)
            for decoder in ("xla-parallel", "xla-scan", "sharded"):
                outs = lzss.decompress_many(
                    ref,
                    decoder=decoder,
                    mesh=mesh if decoder == "sharded" else None,
                )
                for i, (item, out) in enumerate(zip(items, outs)):
                    assert np.array_equal(out, item), (s, w, decoder, i)


@multidevice
def test_sharded_batch_axis_tuple_2d_mesh():
    """Default batch axis ('data' -> 4 shards) and an explicit axis tuple
    (('data', 'model') -> 8 shards) on a 2D mesh, both byte-identical."""
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    items = _buffers(5, 5)
    kw = dict(symbol_size=1, window=16, chunk_symbols=64)
    ref = lzss.compress_many(items, lzss.LZSSConfig(**kw))
    for axis in (None, ("data", "model")):
        cfg = lzss.LZSSConfig(
            **kw, backend="sharded", decoder="sharded", mesh=mesh,
            batch_axis=axis,
        )
        got = lzss.compress_many(items, cfg)
        assert np.array_equal(ref.data, got.data), axis
        outs = lzss.decompress_many(got, decoder="sharded", mesh=mesh,
                                    batch_axis=axis)
        for item, out in zip(items, outs):
            assert np.array_equal(out, item)


@multidevice
def test_pod_exchange_compresses_where_shards_live_8dev():
    """The shard-mapped pod exchange averages exactly like the per-pod
    quantized reference (lossless wire budget)."""
    from repro.optim import grad_compress as gc

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(2, 131072)).astype(np.float32))
    out = jax.jit(
        lambda s: gc.pod_exchange_compressed(s, mesh, ratio_cap=1.0)
    )({"w": g})
    want = 0.0
    for k in range(2):
        codes, scale = gc.quantize_u16(g[k])
        want = want + np.asarray(gc.dequantize_u16(codes, scale))
    np.testing.assert_allclose(np.asarray(out["w"]), want / 2, atol=1e-6)


@multidevice
def test_kv_store_sharded_roundtrip_8dev():
    from repro.serving.kvcache import KVBlockStore

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    items = [
        ((0, i), np.repeat(rng.integers(0, 50, 256).astype(np.int16), 8))
        for i in range(5)
    ]
    store = KVBlockStore(compress=True, mesh=mesh)
    store.evict_many(items)
    assert store.config.backend == "sharded"
    for (key, blk), out in zip(items, store.restore_many([k for k, _ in items])):
        assert np.array_equal(out, blk), key
    # stored bytes match the single-device store exactly
    ref = KVBlockStore(compress=True)
    ref.evict_many(items)
    assert store.stats.evicted_bytes_stored == ref.stats.evicted_bytes_stored


@multidevice
def test_checkpoint_sharded_save_restores_on_smaller_mesh_8dev(tmp_path):
    """A checkpoint compressed on an 8-device mesh restores on a 2-device
    mesh (and with no mesh at all) — blobs are mesh-agnostic bytes."""
    from repro.checkpoint.manager import CheckpointManager

    rng = np.random.default_rng(1)
    tree = {
        "w": np.repeat(rng.normal(size=300).astype(np.float32), 4).reshape(30, 40),
        "codes": rng.integers(0, 3, 5000).astype(np.int16),
        "scalar": np.float32(3.0),
    }
    mgr = CheckpointManager(str(tmp_path), lz_mesh=jax.make_mesh((8,), ("data",)))
    mgr.save(tree, 1)
    for target in (jax.make_mesh((2,), ("data",)), None):
        out, step = dataclasses.replace(mgr, lz_mesh=target).restore(tree, 1)
        assert step == 1
        for k in tree:
            assert np.array_equal(np.asarray(out[k]), tree[k]), (k, target)


def test_restore_onto_mesh_repoints_decode_mesh(monkeypatch, tmp_path):
    """elastic.restore_onto_mesh must decode with the mesh being restored
    ONTO, not the (possibly gone) mesh the checkpoint was written on."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.launch import steps as steps_lib
    from repro.runtime import elastic

    new_mesh = jax.make_mesh((1,), ("data",))
    monkeypatch.setattr(
        steps_lib, "abstract_train_state", lambda cfg, tc: {"x": None}
    )
    monkeypatch.setattr(
        steps_lib, "train_state_shardings", lambda cfg, tc, m: None
    )
    seen = {}

    def fake_restore_latest(self, template, shardings=None):
        seen["mesh"] = self.lz_mesh
        return template, 7

    monkeypatch.setattr(CheckpointManager, "restore_latest", fake_restore_latest)
    mgr = CheckpointManager(str(tmp_path), lz_decoder="sharded")
    _, step = elastic.restore_onto_mesh(mgr, None, None, new_mesh)
    assert step == 7
    assert seen["mesh"] is new_mesh
    assert mgr.lz_mesh is None  # the caller's manager is left untouched
    # unsharded managers are not silently switched to sharded decode
    seen.clear()
    plain = CheckpointManager(str(tmp_path))
    elastic.restore_onto_mesh(plain, None, None, new_mesh)
    assert seen["mesh"] is None


def test_restore_onto_mesh_drops_stale_batch_axis(monkeypatch, tmp_path):
    """Regression: a checkpoint saved with lz_batch_axis='pod' must restore
    onto a mesh that has no 'pod' axis — the stale axis used to ride along
    with the re-pointed mesh and blow up in normalize_batch_axes."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.launch import steps as steps_lib
    from repro.runtime import elastic

    monkeypatch.setattr(
        steps_lib, "abstract_train_state", lambda cfg, tc: {"x": None}
    )
    monkeypatch.setattr(
        steps_lib, "train_state_shardings", lambda cfg, tc, m: None
    )
    seen = {}

    def fake_restore_latest(self, template, shardings=None):
        seen["mesh"], seen["axis"] = self.lz_mesh, self.lz_batch_axis
        # the restore path builds configs from these fields; a stale axis
        # must not survive long enough to reach mesh validation
        lzss.LZSSConfig(
            backend="sharded",
            decoder="sharded",
            mesh=self.lz_mesh,
            batch_axis=self.lz_batch_axis,
        )
        return template, 3

    monkeypatch.setattr(CheckpointManager, "restore_latest", fake_restore_latest)
    save_mesh = jax.make_mesh((1, 1), ("pod", "data"))
    restore_mesh = jax.make_mesh((1,), ("data",))
    mgr = CheckpointManager(
        str(tmp_path), lz_mesh=save_mesh, lz_batch_axis="pod"
    )
    _, step = elastic.restore_onto_mesh(mgr, None, None, restore_mesh)
    assert step == 3
    assert seen["mesh"] is restore_mesh
    assert seen["axis"] is None  # re-derived from the restore-side mesh
    # ...but an explicit axis the new mesh still has is preserved (a manager
    # deliberately sharding over only 'data' keeps that choice)
    seen.clear()
    restore_mesh2 = jax.make_mesh((1, 1), ("pod", "data"))
    mgr2 = CheckpointManager(
        str(tmp_path), lz_mesh=save_mesh, lz_batch_axis="data"
    )
    elastic.restore_onto_mesh(mgr2, None, None, restore_mesh2)
    assert seen["mesh"] is restore_mesh2
    assert seen["axis"] == "data"


# --------------------------------------------- slow subprocess train tests


@pytest.mark.slow
def test_sharded_train_and_decode_8dev():
    stdout = _run_subprocess(
        """
import json, jax, jax.numpy as jnp
from repro import configs
from repro.configs.base import ShapeConfig, TrainConfig
from repro.launch import mesh as mesh_lib, steps
from repro.data.pipeline import DataConfig, make_batch_for_step
from repro.models import transformer

mesh = mesh_lib.make_host_mesh(data=4, model=2)
cfg = configs.reduced_config(configs.get_config("llama3-8b"))
tc = TrainConfig(total_steps=4, warmup_steps=1)
shape = ShapeConfig("t", 64, 8, "train")
jfn, st_sh, b_sh = steps.make_train_step(cfg, tc, mesh, shape)
state = jax.device_put(steps.init_train_state(cfg, tc, 0), st_sh)
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
losses = []
for i in range(2):
    batch = {k: jax.device_put(v, b_sh[k]) for k, v in make_batch_for_step(dc, i).items()}
    state, m = jfn(state, batch)
    losses.append(float(m["loss"]))
d = ShapeConfig("d", 64, 8, "decode")
djfn, p_sh, c_sh, db_sh = steps.make_decode_step(cfg, mesh, d)
caches = jax.device_put(transformer.init_cache(cfg, 8, 64), c_sh)
toks = jax.device_put(jnp.zeros((8,), jnp.int32), db_sh["tokens"])
nt, _ = djfn(state["params"], caches, {"tokens": toks, "pos": jnp.int32(0)})
print(json.dumps({"losses": losses, "decode_shape": list(nt.shape)}))
"""
    )
    r = json.loads(stdout.strip().splitlines()[-1])
    assert len(r["losses"]) == 2 and all(l > 0 for l in r["losses"])
    assert r["decode_shape"] == [8]


@pytest.mark.slow
def test_compressed_pod_step_matches_baseline_8dev():
    stdout = _run_subprocess(
        """
import json, jax, jax.numpy as jnp
from repro import configs
from repro.configs.base import ShapeConfig, TrainConfig, CompressionConfig
from repro.launch import mesh as mesh_lib, steps
from repro.data.pipeline import DataConfig, make_batch_for_step

mesh = mesh_lib.make_host_mesh(data=2, model=2, pod=2)
cfg = configs.reduced_config(configs.get_config("llama3.2-1b"))
shape = ShapeConfig("t", 64, 8, "train")
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
out = {}
for name, compressed in [("base", False), ("lz", True)]:
    tc = TrainConfig(total_steps=4, warmup_steps=1,
                     compression=CompressionConfig(grad_cross_pod=compressed))
    jfn, st_sh, b_sh = steps.make_train_step(cfg, tc, mesh, shape, compressed=compressed)
    state = jax.device_put(steps.init_train_state(cfg, tc, 0), st_sh)
    batch = {k: jax.device_put(v, b_sh[k]) for k, v in make_batch_for_step(dc, 0).items()}
    state, m = jfn(state, batch)
    out[name] = [float(m["loss"]), float(m["grad_norm"])]
print(json.dumps(out))
"""
    )
    r = json.loads(stdout.strip().splitlines()[-1])
    assert abs(r["base"][0] - r["lz"][0]) < 1e-2       # same loss
    assert abs(r["base"][1] - r["lz"][1]) / r["base"][1] < 0.02  # ~same grads
