"""Sharding rules + multi-device behaviour (subprocess with 8 host devices:
the main test process must keep seeing 1 device per the assignment)."""

import json
import os
import subprocess
import sys

import pytest

from jax.sharding import PartitionSpec as P

from repro.sharding import rules

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_spec_mapping():
    assert rules.spec_for(("embed", "heads", "head_dim")) == P(
        "data", "model", None
    )
    assert rules.spec_for(("experts", "embed", "expert_ffn")) == P(
        "model", "data", None
    )
    assert rules.spec_for(("vocab", "embed_out")) == P("model", "data")


def test_zero_spec_adds_data_once():
    import jax

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # already data-sharded -> unchanged
    assert rules.zero_spec(P("data", "model"), (16, 16), mesh) == P(
        "data", "model"
    )
    # free dim gets data
    got = rules.zero_spec(P(None, "model"), (16, 16), mesh)
    assert got == P("data", "model")


def _run_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=540,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_train_and_decode_8dev():
    stdout = _run_subprocess(
        """
import json, jax, jax.numpy as jnp
from repro import configs
from repro.configs.base import ShapeConfig, TrainConfig
from repro.launch import mesh as mesh_lib, steps
from repro.data.pipeline import DataConfig, make_batch_for_step
from repro.models import transformer

mesh = mesh_lib.make_host_mesh(data=4, model=2)
cfg = configs.reduced_config(configs.get_config("llama3-8b"))
tc = TrainConfig(total_steps=4, warmup_steps=1)
shape = ShapeConfig("t", 64, 8, "train")
jfn, st_sh, b_sh = steps.make_train_step(cfg, tc, mesh, shape)
state = jax.device_put(steps.init_train_state(cfg, tc, 0), st_sh)
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
losses = []
for i in range(2):
    batch = {k: jax.device_put(v, b_sh[k]) for k, v in make_batch_for_step(dc, i).items()}
    state, m = jfn(state, batch)
    losses.append(float(m["loss"]))
d = ShapeConfig("d", 64, 8, "decode")
djfn, p_sh, c_sh, db_sh = steps.make_decode_step(cfg, mesh, d)
caches = jax.device_put(transformer.init_cache(cfg, 8, 64), c_sh)
toks = jax.device_put(jnp.zeros((8,), jnp.int32), db_sh["tokens"])
nt, _ = djfn(state["params"], caches, {"tokens": toks, "pos": jnp.int32(0)})
print(json.dumps({"losses": losses, "decode_shape": list(nt.shape)}))
"""
    )
    r = json.loads(stdout.strip().splitlines()[-1])
    assert len(r["losses"]) == 2 and all(l > 0 for l in r["losses"])
    assert r["decode_shape"] == [8]


@pytest.mark.slow
def test_compressed_pod_step_matches_baseline_8dev():
    stdout = _run_subprocess(
        """
import json, jax, jax.numpy as jnp
from repro import configs
from repro.configs.base import ShapeConfig, TrainConfig, CompressionConfig
from repro.launch import mesh as mesh_lib, steps
from repro.data.pipeline import DataConfig, make_batch_for_step

mesh = mesh_lib.make_host_mesh(data=2, model=2, pod=2)
cfg = configs.reduced_config(configs.get_config("llama3.2-1b"))
shape = ShapeConfig("t", 64, 8, "train")
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
out = {}
for name, compressed in [("base", False), ("lz", True)]:
    tc = TrainConfig(total_steps=4, warmup_steps=1,
                     compression=CompressionConfig(grad_cross_pod=compressed))
    jfn, st_sh, b_sh = steps.make_train_step(cfg, tc, mesh, shape, compressed=compressed)
    state = jax.device_put(steps.init_train_state(cfg, tc, 0), st_sh)
    batch = {k: jax.device_put(v, b_sh[k]) for k, v in make_batch_for_step(dc, 0).items()}
    state, m = jfn(state, batch)
    out[name] = [float(m["loss"]), float(m["grad_norm"])]
print(json.dumps(out))
"""
    )
    r = json.loads(stdout.strip().splitlines()[-1])
    assert abs(r["base"][0] - r["lz"][0]) < 1e-2       # same loss
    assert abs(r["base"][1] - r["lz"][1]) / r["base"][1] < 0.02  # ~same grads
