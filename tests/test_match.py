"""Vectorized matcher vs brute-force oracle (+ properties of matches).

Property-based variants (hypothesis) live in test_properties.py.
"""

import numpy as np
import pytest

from repro.core import match


@pytest.mark.parametrize(
    "nc,c,w,vocab",
    [(2, 64, 8, 4), (3, 128, 32, 3), (1, 96, 255, 2), (2, 200, 17, 10),
     (1, 64, 1, 2)],
)
def test_matches_equal_bruteforce(nc, c, w, vocab):
    rng = np.random.default_rng(nc * c + w)
    syms = rng.integers(0, vocab, size=(nc, c)).astype(np.int32)
    lengths, offsets = match.find_matches(syms, window=w)
    ref_l, ref_o = match.find_matches_reference(syms, window=w)
    np.testing.assert_array_equal(np.asarray(lengths), ref_l)
    np.testing.assert_array_equal(np.asarray(offsets), ref_o)


@pytest.mark.parametrize("w", [2, 7, 32])
def test_match_invariants_random(w):
    rng = np.random.default_rng(w)
    syms = rng.integers(0, 3, size=(1, 96)).astype(np.int32)
    lengths, offsets = map(np.asarray, match.find_matches(syms, window=w))
    c = syms.shape[1]
    for i in range(c):
        ln, off = lengths[0, i], offsets[0, i]
        assert 0 <= ln <= min(w, 255)
        if ln == 0:
            assert off == 0
            continue
        assert 1 <= off <= min(i, w)
        assert ln <= off          # paper §3.3.2: length never exceeds offset
        assert i + ln <= c        # never crosses the chunk end
        # the claimed match is real
        np.testing.assert_array_equal(
            syms[0, i : i + ln], syms[0, i - off : i - off + ln]
        )


def test_window_monotonicity():
    """A larger window can only find equal-or-longer matches."""
    rng = np.random.default_rng(0)
    syms = rng.integers(0, 3, size=(2, 256)).astype(np.int32)
    prev = None
    for w in (4, 16, 64, 255):
        lengths, _ = match.find_matches(syms, window=w)
        lengths = np.asarray(lengths)
        if prev is not None:
            assert (lengths >= prev).all()
        prev = lengths


def test_capped_run_lengths():
    eq = np.array([[1, 1, 1, 0, 1, 0, 1, 1]], np.int32)
    r = np.asarray(match.capped_run_lengths(eq, levels=3))
    np.testing.assert_array_equal(r, [[3, 2, 1, 0, 1, 0, 2, 1]])
