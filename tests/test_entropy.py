"""Entropy-container subsystem (core/entropy.py + kernels/lz_entropy.py).

Covers the layers bottom-up: code-length assignment (host heapq vs the
in-graph mirror, degenerate histograms, Kraft repair, the stored escape),
canonical code maps (prefix-freeness, host/jax agreement), the histogram
and bitstream kernels (Pallas interpret vs XLA fallback, forced via
``impl=``), the section transcode roundtrip, and the full ``deflate-full``
container: roundtrips across dtypes/corpora, the worst-case size bound,
ratio superiority at amortized sizes, config normalization, validation of
corrupted method-1 metadata, batching, sharded/entropy interplay smoke and
the grad-compress consumer.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import entropy, format as fmt, lzss, pipeline

# ------------------------------------------------------------ histograms


def _hist(counts_dict, n=256):
    h = np.zeros(n, np.int64)
    for k, v in counts_dict.items():
        h[k] = v
    return h


def _kraft(lengths, max_len=entropy.MAX_CODE_LEN):
    l = np.asarray(lengths)
    return int(np.where(l > 0, 1 << (max_len - l), 0).sum())


ADVERSARIAL_HISTS = {
    "single-symbol": _hist({7: 1000}),
    "two-symbols": _hist({0: 1, 255: 1}),
    "all-equal": np.full(256, 3, np.int64),
    "one-dominant": _hist({0: 1 << 20, **{i: 1 for i in range(1, 40)}}),
    # fibonacci counts force a maximally skewed tree (depth ~ n): the
    # classic worst case for the 15-bit length limit
    "fibonacci-skew": _hist(
        {i: f for i, f in enumerate(np.array(
            [1, 1, 2, 3, 5, 8, 13, 21, 34, 55, 89, 144, 233, 377, 610, 987,
             1597, 2584, 4181, 6765, 10946, 17711, 28657, 46368], np.int64))}
    ),
    "powers-of-two": _hist({i: 1 << i for i in range(20)}),
    "sparse-tail": _hist({250 + i: 10**i for i in range(5)}),
}


@pytest.mark.parametrize("name", sorted(ADVERSARIAL_HISTS))
def test_huffman_lengths_host_jax_equal(name):
    """The in-graph merge loop reproduces the host heapq build exactly
    (tie order included) on every adversarial histogram."""
    counts = ADVERSARIAL_HISTS[name]
    host = entropy.huffman_code_lengths(counts)
    traced = np.asarray(entropy.huffman_code_lengths_jax(counts))
    np.testing.assert_array_equal(host, traced, err_msg=name)


@pytest.mark.parametrize("name", sorted(ADVERSARIAL_HISTS))
def test_container_lengths_host_jax_equal(name):
    counts = ADVERSARIAL_HISTS[name]
    host = entropy.container_code_lengths(counts)
    traced = np.asarray(entropy.container_code_lengths_jax(counts))
    np.testing.assert_array_equal(host, traced, err_msg=name)
    # and the single-API wrapper takes the host path on concrete input
    np.testing.assert_array_equal(host, np.asarray(entropy.code_lengths(counts)))


def test_code_lengths_traced_path_matches_host():
    """code_lengths under jit (tracer input) equals the eager host path."""
    counts = ADVERSARIAL_HISTS["fibonacci-skew"]
    traced = np.asarray(jax.jit(entropy.code_lengths)(jnp.asarray(counts)))
    np.testing.assert_array_equal(traced, entropy.code_lengths(counts))


def test_single_symbol_histogram_gets_one_bit():
    l = entropy.huffman_code_lengths(_hist({42: 999}))
    assert l[42] == 1 and l.sum() == 1


def test_all_equal_histogram_is_flat_eight_bit():
    """256 equally likely symbols -> a perfectly balanced 8-level tree."""
    l = entropy.huffman_code_lengths(np.full(256, 7, np.int64))
    assert (l == 8).all()


def test_fibonacci_skew_exceeds_limit_then_repairs():
    counts = ADVERSARIAL_HISTS["fibonacci-skew"]
    unlimited = entropy.huffman_code_lengths(counts)
    assert unlimited.max() > entropy.MAX_CODE_LEN  # the limit must matter
    limited = entropy.limit_code_lengths(unlimited, entropy.MAX_CODE_LEN)
    assert limited.max() <= entropy.MAX_CODE_LEN
    assert _kraft(limited) <= 1 << entropy.MAX_CODE_LEN
    # every present symbol keeps a code, absent symbols stay absent
    assert ((limited > 0) == (counts > 0)).all()
    # the in-graph repair makes the identical deterministic choices
    np.testing.assert_array_equal(
        limited, np.asarray(entropy.limit_code_lengths_jax(unlimited))
    )


@pytest.mark.parametrize("name", sorted(ADVERSARIAL_HISTS))
def test_limited_lengths_satisfy_kraft(name):
    l = entropy.huffman_code_lengths(
        ADVERSARIAL_HISTS[name], max_len=entropy.MAX_CODE_LEN
    )
    assert l.max() <= entropy.MAX_CODE_LEN
    assert _kraft(l) <= 1 << entropy.MAX_CODE_LEN


def test_stored_escape_on_uniform_noise():
    """An incompressible histogram (uniform bytes) triggers the 8-bit
    identity escape, so the bitstream can never expand past the raw
    section: the worst-case container bound depends on this."""
    rng = np.random.default_rng(0)
    counts = np.bincount(rng.integers(0, 256, 1 << 16), minlength=256)
    l = entropy.container_code_lengths(counts)
    assert (l == entropy.STORED_LEN).all()
    # ... and the canonical code over all-8 lengths is the identity map
    codes = entropy.canonical_codes(l)
    np.testing.assert_array_equal(codes, np.arange(256))


def test_empty_histogram_all_zero_lengths():
    l = entropy.container_code_lengths(np.zeros(256, np.int64))
    assert (l == 0).all()


# ------------------------------------------------------- canonical tables


@pytest.mark.parametrize("name", sorted(ADVERSARIAL_HISTS))
def test_canonical_codes_prefix_free(name):
    l = entropy.huffman_code_lengths(
        ADVERSARIAL_HISTS[name], max_len=entropy.MAX_CODE_LEN
    )
    codes = entropy.canonical_codes(l)
    live = np.nonzero(l)[0]
    pads = [
        (int(codes[s]) << (entropy.MAX_CODE_LEN - int(l[s])), int(l[s]))
        for s in live
    ]
    for i, (ci, li) in enumerate(pads):
        for j, (cj, lj) in enumerate(pads):
            if i == j:
                continue
            m = min(li, lj)
            assert (ci >> (entropy.MAX_CODE_LEN - m)) != (
                cj >> (entropy.MAX_CODE_LEN - m)
            ), f"{name}: codes for {live[i]} and {live[j]} share a prefix"


@pytest.mark.parametrize("name", sorted(ADVERSARIAL_HISTS))
def test_canonical_tables_jax_matches_host(name):
    l = entropy.huffman_code_lengths(
        ADVERSARIAL_HISTS[name], max_len=entropy.MAX_CODE_LEN
    )
    tabs = {k: np.asarray(v) for k, v in entropy.canonical_tables_jax(l).items()}
    np.testing.assert_array_equal(tabs["codes"], entropy.canonical_codes(l))
    # decode-map invariants: order sorts by (length, symbol); base/count
    # partition the live symbols by length
    assert tabs["count"].sum() == (l > 0).sum()
    for ll in range(1, entropy.MAX_CODE_LEN + 1):
        segment = tabs["order"][
            tabs["base"][ll] : tabs["base"][ll] + tabs["count"][ll]
        ]
        assert (l[segment] == ll).all()
        assert (np.diff(segment) > 0).all() if segment.size > 1 else True


# ----------------------------------------------- histogram kernel parity


def test_byte_histogram_impls_agree():
    rng = np.random.default_rng(1)
    buf = jnp.asarray(rng.integers(0, 256, 5000), jnp.int32)
    for start, length in [(0, 5000), (17, 3000), (4999, 1), (100, 0)]:
        xla = np.asarray(entropy.byte_histogram(buf, start, length, impl="xla"))
        pal = np.asarray(
            entropy.byte_histogram(buf, start, length, impl="pallas")
        )
        np.testing.assert_array_equal(xla, pal, err_msg=f"{start}+{length}")
        want = np.bincount(
            np.asarray(buf)[start : start + length], minlength=256
        )
        np.testing.assert_array_equal(xla, want)


def test_use_pallas_selection(monkeypatch):
    assert entropy._use_pallas("pallas") is True
    assert entropy._use_pallas("xla") is False
    with pytest.raises(ValueError, match="impl"):
        entropy._use_pallas("cuda")
    monkeypatch.setenv("REPRO_ENTROPY_PALLAS", "0")
    assert entropy._use_pallas(None) is False
    monkeypatch.setenv("REPRO_ENTROPY_PALLAS", "1")
    assert entropy._use_pallas(None) is True
    monkeypatch.delenv("REPRO_ENTROPY_PALLAS")
    import jax as _jax

    monkeypatch.setattr(_jax, "default_backend", lambda: "tpu")
    assert entropy._use_pallas(None) is True
    monkeypatch.setattr(_jax, "default_backend", lambda: "cpu")
    assert entropy._use_pallas(None) is False


# ------------------------------------------------- section transcode


def _section_roundtrip(section_bytes, cap, impl):
    buf = jnp.asarray(np.pad(section_bytes, (0, 4)), jnp.int32)
    counts = np.bincount(section_bytes, minlength=256)
    l = entropy.container_code_lengths(counts)
    stream, nbits, gaps = entropy.encode_section(
        buf, 0, section_bytes.size, jnp.asarray(l, jnp.int32), cap=cap
    )
    assert int(nbits) == int((counts * l).sum())
    assert int(nbits) <= 8 * section_bytes.size  # stored escape bound
    out = entropy.decode_section(
        stream, 0, gaps, jnp.asarray(l, jnp.int32),
        count=section_bytes.size, cap=cap, impl=impl,
    )
    np.testing.assert_array_equal(
        np.asarray(out)[: section_bytes.size], section_bytes
    )
    return int(nbits)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_section_roundtrip_multi_subblock(impl):
    """> 1 sub-block: every gap entry point must land on a codeword."""
    rng = np.random.default_rng(2)
    sec = np.repeat(rng.integers(0, 40, 700), rng.integers(1, 4, 700))
    sec = sec.astype(np.int64)[:1500]
    nbits = _section_roundtrip(sec, cap=1536, impl=impl)
    assert nbits < 8 * sec.size  # skewed bytes actually compress


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_section_roundtrip_degenerate(impl):
    one = np.full(600, 9, np.int64)  # single-symbol: 1-bit codes
    assert _section_roundtrip(one, cap=1024, impl=impl) == 600
    rng = np.random.default_rng(3)
    # noisy bytes: a small sample still has a slightly skewed histogram,
    # so the code may squeeze under 8 bits/byte — but the stored escape
    # guarantees it never goes OVER (checked inside _section_roundtrip)
    noise = rng.integers(0, 256, 600).astype(np.int64)
    assert _section_roundtrip(noise, cap=1024, impl=impl) <= 8 * 600
    # an exactly-flat histogram pins the identity code: 8 bits/byte even
    flat = np.tile(np.arange(256, dtype=np.int64), 3)
    assert _section_roundtrip(flat, cap=1024, impl=impl) == 8 * flat.size


def test_encode_section_gap_entries_are_codeword_offsets():
    sec = np.tile(np.arange(8, dtype=np.int64), 200)  # 1600 bytes, 3 subs
    buf = jnp.asarray(sec, jnp.int32)
    l = entropy.container_code_lengths(np.bincount(sec, minlength=256))
    _, nbits, gaps = entropy.encode_section(
        buf, 0, sec.size, jnp.asarray(l, jnp.int32), cap=sec.size
    )
    gaps = np.asarray(gaps)
    sub = 1 << fmt.DEFAULT_SUB_LOG2
    csum = np.cumsum(l[sec])
    want = np.concatenate([[0], csum[:-1]])[::sub][: gaps.size]
    np.testing.assert_array_equal(gaps, want)
    assert int(nbits) == int(csum[-1])


# ----------------------------------------------- full-container behavior

DTYPE_CORPORA = {
    "u8-runs": lambda rng: np.repeat(
        rng.integers(0, 12, 400), rng.integers(1, 6, 400)
    ).astype(np.uint8)[:1200],
    "u16-deltas": lambda rng: rng.integers(-3, 4, 700)
    .cumsum()
    .astype(np.int16),
    "f32-waves": lambda rng: np.sin(np.linspace(0, 8, 500)).astype(np.float32),
    "i32-ramp": lambda rng: (np.arange(400, dtype=np.int32) * 7) % 512,
    "empty": lambda rng: np.zeros(0, np.uint8),
    "one-byte": lambda rng: np.array([170], np.uint8),
}

_S = {"u8-runs": 1, "u16-deltas": 2, "f32-waves": 4, "i32-ramp": 4,
      "empty": 1, "one-byte": 1}


@pytest.mark.parametrize("name", sorted(DTYPE_CORPORA))
def test_deflate_full_roundtrip(name):
    data = DTYPE_CORPORA[name](np.random.default_rng(5))
    raw = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
    cfg = lzss.LZSSConfig(
        symbol_size=_S[name], window=64, chunk_symbols=128,
        backend="deflate-full",
    )
    res = lzss.compress(data, cfg)
    h = fmt.parse_header(np.asarray(res.data))
    assert h.version == fmt.VERSION
    assert h.method == fmt.METHOD_HUFFMAN
    assert h.sub_log2 == fmt.DEFAULT_SUB_LOG2
    np.testing.assert_array_equal(lzss.decompress(res.data), raw)
    # worst-case bound is unconditional
    nsym = -(-max(raw.size, 1) // _S[name])
    nc = -(-nsym // 128)
    assert res.total_bytes <= fmt.entropy_max_compressed_bytes(
        nc * 128 * _S[name], _S[name], 128
    )


def test_deflate_full_pallas_xla_identical():
    """Forcing the Pallas kernels (interpret mode off-TPU) changes neither
    the container bytes nor the decoded output."""
    rng = np.random.default_rng(6)
    data = np.repeat(rng.integers(0, 30, 900), rng.integers(1, 5, 900))
    data = data.astype(np.uint8)[:2200]
    cfg = lzss.LZSSConfig(
        symbol_size=1, window=64, chunk_symbols=128, backend="deflate-full"
    )
    res_x = lzss.compress(data, cfg)
    out_x = lzss.decompress(res_x.data)
    import os

    os.environ["REPRO_ENTROPY_PALLAS"] = "1"
    jax.clear_caches()
    try:
        res_p = lzss.compress(data, cfg)
        out_p = lzss.decompress(res_p.data)
    finally:
        del os.environ["REPRO_ENTROPY_PALLAS"]
        jax.clear_caches()
    np.testing.assert_array_equal(res_x.data, res_p.data)
    np.testing.assert_array_equal(out_x, out_p)
    np.testing.assert_array_equal(out_x, data)


def test_ratio_strictly_better_at_amortized_sizes():
    """On >= 32 KiB skewed corpora the entropy container must strictly beat
    the LZSS-only container (the tentpole's acceptance criterion); text-like
    and quant-code-like corpora both."""
    rng = np.random.default_rng(7)
    text = rng.choice(
        np.frombuffer(b"the quick brown fox jumps over the lazy dog ",
                      np.uint8),
        1 << 15,
        p=None,
    ).astype(np.uint8)
    quant = np.repeat(
        rng.integers(120, 136, 1 << 14), rng.integers(1, 5, 1 << 14)
    ).astype(np.uint8)[: 1 << 15]
    for name, corpus in [("text", text), ("quant", quant)]:
        raw_cfg = lzss.LZSSConfig(
            symbol_size=1, window=128, chunk_symbols=2048,
            backend="fused-mono",
        )
        ent_cfg = lzss.LZSSConfig(
            symbol_size=1, window=128, chunk_symbols=2048,
            backend="deflate-full",
        )
        r_raw = lzss.compress(corpus, raw_cfg)
        r_ent = lzss.compress(corpus, ent_cfg)
        assert r_ent.total_bytes < r_raw.total_bytes, (
            f"{name}: entropy {r_ent.total_bytes} >= raw {r_raw.total_bytes}"
        )
        np.testing.assert_array_equal(lzss.decompress(r_ent.data), corpus)


def test_incompressible_bound_holds():
    rng = np.random.default_rng(8)
    data = rng.integers(0, 256, 4096).astype(np.uint8)
    cfg = lzss.LZSSConfig(
        symbol_size=1, window=64, chunk_symbols=128, backend="deflate-full"
    )
    res = lzss.compress(data, cfg)
    assert res.total_bytes <= fmt.entropy_max_compressed_bytes(4096, 1, 128)
    np.testing.assert_array_equal(lzss.decompress(res.data), data)


def test_entropy_meta_bound_documented_form():
    """entropy_max_compressed_bytes = raw worst case + fixed metadata +
    gap arrays: spot-check the arithmetic the bound tests rely on."""
    n, s, c = 4096, 1, 128
    raw_cap = fmt.max_compressed_bytes(n, s, c)
    assert fmt.entropy_max_compressed_bytes(n, s, c) == raw_cap + (
        fmt.entropy_meta_bytes(
            -(-n // (s * c)) * ((c + 7) // 8), -(-n // (s * c)) * c * s
        )
    )


# -------------------------------------------------- routing and guards


def test_config_normalization():
    cfg = lzss.LZSSConfig(backend="deflate-full")
    assert cfg.decoder == "deflate-full"  # auto pairs with the backend
    cfg2 = lzss.LZSSConfig(backend="deflate-full", decoder="deflate-full")
    assert cfg2.decoder == "deflate-full"
    with pytest.raises(ValueError, match="deflate-full"):
        lzss.LZSSConfig(decoder="deflate-full")  # entropy decode needs
        # an entropy container: raw backends never produce one


def test_entropy_container_rejects_raw_decoders():
    data = np.arange(500, dtype=np.uint8)
    cfg = lzss.LZSSConfig(
        symbol_size=1, window=32, chunk_symbols=64, backend="deflate-full"
    )
    res = lzss.compress(data, cfg)
    for decoder in ("fused", "fused-mono", "xla-parallel", "xla-scan"):
        with pytest.raises(ValueError, match="entropy"):
            lzss.decompress(res.data, decoder=decoder)
    # auto and the explicit key both work
    np.testing.assert_array_equal(lzss.decompress(res.data), data)
    np.testing.assert_array_equal(
        lzss.decompress(res.data, decoder="deflate-full"), data
    )


def test_raw_container_rejects_entropy_decoder():
    data = np.arange(500, dtype=np.uint8)
    res = lzss.compress(
        data, lzss.LZSSConfig(symbol_size=1, window=32, chunk_symbols=64)
    )
    with pytest.raises(ValueError, match="method-1"):
        lzss.decompress(res.data, decoder="deflate-full")


def test_version_mismatch_names_both_versions():
    data = np.arange(300, dtype=np.uint8)
    res = lzss.compress(
        data, lzss.LZSSConfig(symbol_size=1, window=32, chunk_symbols=64)
    )
    bad = res.data.copy()
    bad[4] = 7
    with pytest.raises(ValueError) as ei:
        lzss.decompress(bad)
    msg = str(ei.value)
    assert "7" in msg and str(fmt.SUPPORTED_VERSIONS) in msg


def _entropy_container(n=1500, seed=9, chunk_symbols=128):
    rng = np.random.default_rng(seed)
    data = np.repeat(rng.integers(0, 20, n), rng.integers(1, 4, n))
    data = data.astype(np.uint8)[:n]
    cfg = lzss.LZSSConfig(
        symbol_size=1, window=64, chunk_symbols=chunk_symbols,
        backend="deflate-full",
    )
    return lzss.compress(data, cfg), data


def test_validate_rejects_corrupt_entropy_metadata():
    res, _ = _entropy_container()
    h = fmt.parse_header(np.asarray(res.data))
    sec = fmt.HEADER_BYTES + 8 * h.n_chunks

    bad = res.data.copy()
    bad[41] = 3  # sub_log2 drifted from the pinned value; pad so the
    # (sub-dependent) declared total still fits and this check is reached
    bad = np.concatenate([bad, np.zeros(1 << 14, np.uint8)])
    with pytest.raises(ValueError, match="sub-block log2"):
        lzss.decompress(bad)

    bad = res.data.copy()
    bad[sec : sec + 128] = 0x11  # 256 one-bit codes: Kraft oversubscribed
    with pytest.raises(ValueError, match="corrupted container"):
        lzss.decompress(bad)

    bad = res.data.copy()
    # flag_bits just past the 8 * flag_bytes stored-escape cap (padded so
    # the slightly larger declared total passes the truncation check)
    over = 8 * h.flag_bytes + 8
    bad[sec + 256 : sec + 264] = np.frombuffer(
        int(over).to_bytes(8, "little"), np.uint8
    )
    bad = np.concatenate([bad, np.zeros(1 << 10, np.uint8)])
    with pytest.raises(ValueError, match="corrupted container"):
        lzss.decompress(bad)

    bad = res.data.copy()
    # non-monotone flag gap array (first entry must be bit offset 0)
    bad[sec + fmt.ENTROPY_META_FIXED] = 0xFF
    with pytest.raises(ValueError, match="corrupted container"):
        lzss.decompress(bad)


def test_truncated_entropy_container_raises():
    res, _ = _entropy_container()
    for cut in (1, 8, res.total_bytes // 2):
        with pytest.raises(ValueError):
            lzss.decompress(res.data[: res.total_bytes - cut])


def test_entropy_container_padded_blob_accepted():
    res, data = _entropy_container()
    padded = np.concatenate([res.data, np.zeros(99, np.uint8)])
    np.testing.assert_array_equal(lzss.decompress(padded), data)


# ----------------------------------------------------- batched dispatch


def test_compress_many_matches_single():
    rng = np.random.default_rng(10)
    # equal sizes: ragged batches pad to the common chunk count, so only
    # same-size items produce byte-identical single-buffer containers
    # (ragged entropy roundtrips ride test_decoders / the property suite)
    items = [
        np.repeat(rng.integers(0, 9, 300), 3).astype(np.uint8)[:768],
        rng.integers(0, 5, 768).astype(np.uint8),
        np.zeros(768, np.uint8),
    ]
    cfg = lzss.LZSSConfig(
        symbol_size=1, window=32, chunk_symbols=128, backend="deflate-full"
    )
    batch = lzss.compress_many(items, cfg)
    outs = lzss.decompress_many(batch)
    singles = [lzss.compress(i, cfg) for i in items]
    for item, out in zip(items, outs):
        np.testing.assert_array_equal(out, item)
    # batched rows equal the single-buffer containers byte-for-byte
    for row, total, single in zip(batch.data, batch.total_bytes, singles):
        assert int(total) == single.total_bytes
        np.testing.assert_array_equal(
            np.asarray(row)[: int(total)], single.data
        )


def test_decompress_many_mixed_methods_rejected():
    res_ent, data = _entropy_container(chunk_symbols=128)
    res_raw = lzss.compress(
        data, lzss.LZSSConfig(symbol_size=1, window=64, chunk_symbols=128)
    )
    with pytest.raises(ValueError, match="method="):
        lzss.decompress_many([res_ent.data, res_raw.data])


def test_grad_compress_with_entropy_backend():
    """grad_compress threads the entropy pair end to end: compressible
    slabs ride method-1 containers, the wire stays budget-shaped, and the
    roundtrip is u16-lossless."""
    from repro.optim import grad_compress as gc

    g = np.repeat(np.linspace(-0.1, 0.1, 256).astype(np.float32), 32)
    cfg = lzss.LZSSConfig(
        symbol_size=2, window=32, chunk_symbols=512, backend="deflate-full"
    )
    wire = gc.compress_leaf(jnp.asarray(g), cfg, ratio_cap=1.0)
    out = np.asarray(gc.decompress_leaf(wire, g.shape, cfg, ratio_cap=1.0))
    codes, scale = gc.quantize_u16(jnp.asarray(g))
    want = np.asarray(gc.dequantize_u16(codes, scale))
    np.testing.assert_allclose(out, want, atol=1e-12)
