"""Property-based tests (hypothesis).

hypothesis is an optional [test] extra — the offline CI container doesn't
ship it, so this module is guarded with importorskip; the deterministic
variants of these invariants live in the per-domain test files.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

import test_conformance as conf  # noqa: E402  (same-dir pytest import)
from repro.core import encode, format as fmt, lzss, match  # noqa: E402
from repro.core import pipeline, quant  # noqa: E402

RAW_BACKENDS = sorted(
    b for b in lzss.available_backends()
    if pipeline.container_method(b) == fmt.METHOD_RAW
)
RAW_DECODERS = sorted(
    d for d in lzss.available_decoders()
    if pipeline.container_method(d) == fmt.METHOD_RAW
)
# the f32-only lossy pair has its own bound property below; the lossless
# differential fuzz sweeps every bit-exact backend
LOSSLESS_BACKENDS = sorted(
    b for b in lzss.available_backends()
    if pipeline.container_method(b) != fmt.METHOD_LOSSY
)


def roundtrip(data: np.ndarray, cfg: lzss.LZSSConfig):
    res = lzss.compress(data, cfg)
    out = lzss.decompress(res.data)
    raw = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
    assert np.array_equal(out, raw), f"roundtrip failed: cfg={cfg} n={raw.size}"
    return res


@given(
    data=st.binary(min_size=0, max_size=2000),
    symbol_size=st.sampled_from([1, 2, 4]),
    window=st.sampled_from([4, 17, 64, 255]),
    backend=st.sampled_from(["xla", "fused-deflate", "fused-mono"]),
)
def test_roundtrip_property(data, symbol_size, window, backend):
    """Round-trips through the unfused tail, the fused deflate-scatter emit
    path AND the single-kernel compressor — backends_identical_property
    below additionally pins their containers byte-identical."""
    arr = np.frombuffer(data, np.uint8)
    cfg = lzss.LZSSConfig(symbol_size=symbol_size, window=window,
                          chunk_symbols=128, backend=backend)
    roundtrip(arr, cfg)


# --------------------------- differential fuzz vs the kernels/ref oracle


@st.composite
def adversarial_case(draw):
    """(array, symbol_size, window, chunk_symbols): one corpus drawn from
    tests/test_conformance.corpora() — the SAME builders the deterministic
    suite enumerates, with size, seed, window and geometry fuzzed here.
    New shapes added to corpora() are fuzzed automatically."""
    dtype_label = draw(st.sampled_from(sorted(conf.DTYPES)))
    dtype, s = conf.DTYPES[dtype_label]
    window = draw(st.sampled_from(sorted(lzss.WINDOW_LEVELS.values())))
    chunk_symbols = draw(st.sampled_from([64, 128]))
    n = draw(st.integers(min_value=1, max_value=600))
    rng = np.random.default_rng(draw(st.integers(0, 1 << 16)))
    pool = conf.corpora(dtype, window, n=n, rng=rng)
    kind = draw(st.sampled_from(sorted(pool)))
    return pool[kind], s, window, chunk_symbols


@given(
    case=adversarial_case(),
    backend=st.sampled_from(LOSSLESS_BACKENDS),
    decoder=st.sampled_from(sorted(lzss.available_decoders())),
)
def test_differential_fuzz_property(case, backend, decoder):
    """Every registered compressor x decoder pair (sampled per example; the
    full deterministic product lives in tests/test_conformance.py) must
    emit the kernels/ref.py oracle bytes and roundtrip bit-exactly on
    adversarial corpora over dtype x window level x chunk_symbols.  Entropy
    backends wrap the oracle bytes in a bitstream, so for them the oracle
    comparison is symbol-level and mismatched decoders must raise."""
    arr, s, window, chunk_symbols = case
    cfg = lzss.LZSSConfig(symbol_size=s, window=window,
                          chunk_symbols=chunk_symbols, backend=backend)
    res = lzss.compress(arr, cfg)
    raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
    method = pipeline.container_method(backend)
    if method == fmt.METHOD_RAW:
        oracle = conf.oracle_container(arr, cfg)
        assert res.total_bytes == oracle.size, (backend, cfg)
        np.testing.assert_array_equal(
            res.data, oracle, err_msg=f"{backend} {cfg}"
        )
    if pipeline.container_method(decoder) != method:
        with pytest.raises(ValueError):
            lzss.decompress(res.data, decoder=decoder)
        return
    out = lzss.decompress(res.data, decoder=decoder)
    np.testing.assert_array_equal(out, raw, err_msg=f"{backend}/{decoder}")


@given(case=adversarial_case(), frac=st.integers(min_value=0, max_value=1 << 20))
def test_truncation_always_raises_never_garbage_property(case, frac):
    """Chopping ANY suffix off a valid container raises ValueError (the
    header/length validation satellite) — never silent garbage output.
    ``frac`` scales over the whole container, so cuts land in the header,
    the A/B tables, the flag section and the payload alike."""
    arr, s, window, chunk_symbols = case
    cfg = lzss.LZSSConfig(symbol_size=s, window=window,
                          chunk_symbols=chunk_symbols)
    res = lzss.compress(arr, cfg)
    cut = 1 + frac % max(1, res.total_bytes - 1)  # 1..total-1 bytes cut
    with pytest.raises(ValueError):
        lzss.decompress(res.data[: res.total_bytes - cut])


@given(st.lists(st.integers(0, 3), min_size=1, max_size=600))
def test_roundtrip_low_entropy_property(vals):
    arr = np.array(vals, np.uint8)
    cfg = lzss.LZSSConfig(symbol_size=1, window=32, chunk_symbols=128)
    roundtrip(arr, cfg)


@given(st.lists(st.integers(0, 3), min_size=1, max_size=400))
def test_backends_identical_property(vals):
    """Every registered method-0 backend emits byte-identical containers
    (the entropy backend emits a method-1 container by design — its
    symbol-level agreement rides test_differential_fuzz_property and
    test_deflate_full_roundtrip_property)."""
    arr = np.array(vals, np.uint8)
    results = []
    for backend in RAW_BACKENDS:
        cfg = lzss.LZSSConfig(symbol_size=1, window=16, chunk_symbols=64,
                              backend=backend)
        results.append(lzss.compress(arr, cfg).data)
    for other in results[1:]:
        np.testing.assert_array_equal(results[0], other)


@given(st.lists(st.integers(0, 3), min_size=1, max_size=400))
def test_decoders_identical_property(vals):
    """Every registered method-0 decoder reconstructs the original bytes."""
    arr = np.array(vals, np.uint8)
    cfg = lzss.LZSSConfig(symbol_size=1, window=16, chunk_symbols=64)
    res = lzss.compress(arr, cfg)
    for decoder in RAW_DECODERS:
        out = lzss.decompress(res.data, decoder=decoder)
        np.testing.assert_array_equal(out, arr, err_msg=f"decoder {decoder}")


@settings(max_examples=20)
@given(data=st.binary(min_size=0, max_size=1500))
def test_deflate_full_roundtrip_property(data):
    """The entropy container roundtrips arbitrary bytes AND never grows past
    the documented worst case: incompressible input hits the stored-length
    escape (all code lengths forced to 8), so the bitstream is bounded by
    the raw section bytes and the whole container by
    ``fmt.entropy_max_compressed_bytes``."""
    arr = np.frombuffer(data, np.uint8)
    cfg = lzss.LZSSConfig(symbol_size=1, window=32, chunk_symbols=128,
                          backend="deflate-full")
    res = lzss.compress(arr, cfg)
    h = fmt.parse_header(np.asarray(res.data))
    assert h.version == fmt.VERSION and h.method == fmt.METHOD_HUFFMAN
    assert res.total_bytes <= fmt.entropy_max_compressed_bytes(
        max(arr.size, 1), 1, 128
    )
    out = lzss.decompress(res.data)
    np.testing.assert_array_equal(out, arr)


@given(
    st.lists(st.integers(0, 4), min_size=16, max_size=128),
    st.sampled_from([4, 16, 64]),
    st.sampled_from([1, 2, 4]),
)
def test_selectors_agree_property(vals, w, s):
    syms = np.array(vals, np.int32)[None, :]
    lengths, _ = match.find_matches(syms, window=w)
    mm = encode.min_match_length(s)
    a = np.asarray(encode.select_tokens_scan(lengths, min_match=mm))
    b = np.asarray(encode.select_tokens_doubling(lengths, min_match=mm))
    np.testing.assert_array_equal(a, b)


@given(
    st.lists(st.integers(0, 2), min_size=8, max_size=96),
    st.sampled_from([2, 7, 32]),
)
def test_match_invariants_property(vals, w):
    syms = np.array(vals, np.int32)[None, :]
    lengths, offsets = map(np.asarray, match.find_matches(syms, window=w))
    c = syms.shape[1]
    for i in range(c):
        ln, off = lengths[0, i], offsets[0, i]
        assert 0 <= ln <= min(w, 255)
        if ln == 0:
            assert off == 0
            continue
        assert 1 <= off <= min(i, w)
        assert ln <= off          # paper §3.3.2: length never exceeds offset
        assert i + ln <= c        # never crosses the chunk end
        # the claimed match is real
        np.testing.assert_array_equal(
            syms[0, i : i + ln], syms[0, i - off : i - off + ln]
        )


@settings(max_examples=25, deadline=None)
@given(
    vals=st.lists(
        st.floats(width=32, allow_nan=True, allow_infinity=True),
        min_size=1, max_size=300,
    ),
    eb=st.sampled_from([1e-1, 1e-3, 1e-5, 0.0]),
)
def test_lossy_container_bound_property(vals, eb):
    """The full lossy-fz container round-trip honors max |x' - x| <= eb for
    every finite element (bit-exact at eb == 0), with NaN/±inf bit patterns
    preserved through the outlier section — on arbitrary f32 streams, not
    just the curated corpora (the deterministic twin is tests/test_lossy.py,
    which is what runs in the CI lossy lane; hypothesis widens the inputs)."""
    x = np.array(vals, np.float32)
    cfg = lzss.LZSSConfig(symbol_size=4, window=64, chunk_symbols=128,
                          backend="lossy-fz", lossy_eb=eb)
    res = lzss.compress(x, cfg)
    rec = np.asarray(lzss.decompress(res.data)).view(np.float32)
    if eb == 0.0:
        np.testing.assert_array_equal(rec.view(np.uint32), x.view(np.uint32))
        return
    fin = np.isfinite(x)
    np.testing.assert_array_equal(
        rec[~fin].view(np.uint32), x[~fin].view(np.uint32)
    )
    if fin.any():
        assert float(np.max(np.abs(rec[fin] - x[fin]))) <= np.float32(eb)


@given(
    st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
             min_size=2, max_size=200),
    st.sampled_from([1e-1, 1e-2, 1e-3]),
)
def test_error_bound_property(vals, rel):
    import jax.numpy as jnp

    x = np.array(vals, np.float32)
    eb = quant.relative_error_bound(x, rel)
    q = quant.quantize(jnp.asarray(x), error_bound=eb, ndim=1)
    xr = quant.dequantize(q.codes, q.outlier_mask, q.outlier_vals,
                          error_bound=eb, ndim=1)
    assert float(jnp.max(jnp.abs(xr - x))) <= eb * 1.01 + 1e-6
