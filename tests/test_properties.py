"""Property-based tests (hypothesis).

hypothesis is an optional [test] extra — the offline CI container doesn't
ship it, so this module is guarded with importorskip; the deterministic
variants of these invariants live in the per-domain test files.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, strategies as st  # noqa: E402

from repro.core import encode, lzss, match, quant  # noqa: E402


def roundtrip(data: np.ndarray, cfg: lzss.LZSSConfig):
    res = lzss.compress(data, cfg)
    out = lzss.decompress(res.data)
    raw = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
    assert np.array_equal(out, raw), f"roundtrip failed: cfg={cfg} n={raw.size}"
    return res


@given(
    data=st.binary(min_size=0, max_size=2000),
    symbol_size=st.sampled_from([1, 2, 4]),
    window=st.sampled_from([4, 17, 64, 255]),
    backend=st.sampled_from(["xla", "fused-deflate"]),
)
def test_roundtrip_property(data, symbol_size, window, backend):
    """Round-trips through the unfused tail AND the fused deflate-scatter
    emit path (fused Kernel II+III) — backends_identical_property below
    additionally pins their containers byte-identical."""
    arr = np.frombuffer(data, np.uint8)
    cfg = lzss.LZSSConfig(symbol_size=symbol_size, window=window,
                          chunk_symbols=128, backend=backend)
    roundtrip(arr, cfg)


@given(st.lists(st.integers(0, 3), min_size=1, max_size=600))
def test_roundtrip_low_entropy_property(vals):
    arr = np.array(vals, np.uint8)
    cfg = lzss.LZSSConfig(symbol_size=1, window=32, chunk_symbols=128)
    roundtrip(arr, cfg)


@given(st.lists(st.integers(0, 3), min_size=1, max_size=400))
def test_backends_identical_property(vals):
    """Every registered backend emits byte-identical containers."""
    arr = np.array(vals, np.uint8)
    results = []
    for backend in lzss.available_backends():
        cfg = lzss.LZSSConfig(symbol_size=1, window=16, chunk_symbols=64,
                              backend=backend)
        results.append(lzss.compress(arr, cfg).data)
    for other in results[1:]:
        np.testing.assert_array_equal(results[0], other)


@given(st.lists(st.integers(0, 3), min_size=1, max_size=400))
def test_decoders_identical_property(vals):
    """Every registered decoder reconstructs the original bytes exactly."""
    arr = np.array(vals, np.uint8)
    cfg = lzss.LZSSConfig(symbol_size=1, window=16, chunk_symbols=64)
    res = lzss.compress(arr, cfg)
    for decoder in lzss.available_decoders():
        out = lzss.decompress(res.data, decoder=decoder)
        np.testing.assert_array_equal(out, arr, err_msg=f"decoder {decoder}")


@given(
    st.lists(st.integers(0, 4), min_size=16, max_size=128),
    st.sampled_from([4, 16, 64]),
    st.sampled_from([1, 2, 4]),
)
def test_selectors_agree_property(vals, w, s):
    syms = np.array(vals, np.int32)[None, :]
    lengths, _ = match.find_matches(syms, window=w)
    mm = encode.min_match_length(s)
    a = np.asarray(encode.select_tokens_scan(lengths, min_match=mm))
    b = np.asarray(encode.select_tokens_doubling(lengths, min_match=mm))
    np.testing.assert_array_equal(a, b)


@given(
    st.lists(st.integers(0, 2), min_size=8, max_size=96),
    st.sampled_from([2, 7, 32]),
)
def test_match_invariants_property(vals, w):
    syms = np.array(vals, np.int32)[None, :]
    lengths, offsets = map(np.asarray, match.find_matches(syms, window=w))
    c = syms.shape[1]
    for i in range(c):
        ln, off = lengths[0, i], offsets[0, i]
        assert 0 <= ln <= min(w, 255)
        if ln == 0:
            assert off == 0
            continue
        assert 1 <= off <= min(i, w)
        assert ln <= off          # paper §3.3.2: length never exceeds offset
        assert i + ln <= c        # never crosses the chunk end
        # the claimed match is real
        np.testing.assert_array_equal(
            syms[0, i : i + ln], syms[0, i - off : i - off + ln]
        )


@given(
    st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
             min_size=2, max_size=200),
    st.sampled_from([1e-1, 1e-2, 1e-3]),
)
def test_error_bound_property(vals, rel):
    import jax.numpy as jnp

    x = np.array(vals, np.float32)
    eb = quant.relative_error_bound(x, rel)
    q = quant.quantize(jnp.asarray(x), error_bound=eb, ndim=1)
    xr = quant.dequantize(q.codes, q.outlier_mask, q.outlier_vals,
                          error_bound=eb, ndim=1)
    assert float(jnp.max(jnp.abs(xr - x))) <= eb * 1.01 + 1e-6
