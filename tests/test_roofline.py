"""HLO collective parser + roofline arithmetic."""

from repro.launch import roofline

HLO = """
ENTRY main {
  %p = bf16[32,1024]{1,0} parameter(0)
  %ag = bf16[512,1024]{1,0} all-gather(bf16[32,1024]{1,0} %p), dimensions={0}
  %ar.1 = f32[16,4096]{1,0} all-reduce(f32[16,4096]{1,0} %x), to_apply=%add
  %ars = f32[8,8]{1,0} all-reduce-start(f32[8,8]{1,0} %y), to_apply=%add
  %ard = f32[8,8]{1,0} all-reduce-done(f32[8,8]{1,0} %ars)
  %a2a = bf16[4,256]{1,0} all-to-all(bf16[4,256]{1,0} %z), dimensions={0}
  %cp = u8[1000]{0} collective-permute(u8[1000]{0} %w), source_target_pairs={{0,1}}
}
"""


def test_collective_bytes_parsing():
    out = roofline.collective_bytes(HLO)
    assert out["all-gather"] == 32 * 1024 * 2
    assert out["all-reduce"] == 16 * 4096 * 4 + 8 * 8 * 4  # -done not counted
    assert out["all-to-all"] == 4 * 256 * 2
    assert out["collective-permute"] == 1000
    assert out["count"] == 5
    assert out["total"] == sum(
        out[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute")
    )


def test_roofline_terms_and_dominant():
    rl = roofline.Roofline(
        arch="a", shape="s", mesh="16x16", chips=256,
        flops_per_device=197e12,        # exactly 1s compute
        bytes_per_device=819e9 * 2,     # 2s memory
        coll_bytes_per_device=50e9 * 0.5,
        model_flops=197e12 * 256,       # == chips x peak x 1s
        coll_breakdown={},
    )
    assert abs(rl.compute_s - 1.0) < 1e-9
    assert abs(rl.memory_s - 2.0) < 1e-9
    assert abs(rl.collective_s - 0.5) < 1e-9
    assert rl.dominant == "memory"
    assert abs(rl.roofline_fraction - 0.5) < 1e-9  # bound by 2s memory
    assert abs(rl.useful_flops_ratio - 1.0) < 1e-9


def test_model_flops_kinds():
    from repro import configs

    cfg = configs.get_config("llama3-8b")
    tr = roofline.model_flops_for(cfg, configs.get_shape("train_4k"))
    pf = roofline.model_flops_for(cfg, configs.get_shape("prefill_32k"))
    dc = roofline.model_flops_for(cfg, configs.get_shape("decode_32k"))
    assert tr == 6.0 * cfg.active_param_count() * 256 * 4096
    assert pf == 2.0 * cfg.active_param_count() * 32 * 32768
    assert dc == 2.0 * cfg.active_param_count() * 128


def test_moe_uses_active_params():
    from repro import configs

    cfg = configs.get_config("deepseek-v2-236b")
    tr = roofline.model_flops_for(cfg, configs.get_shape("train_4k"))
    assert tr < 6.0 * cfg.param_count() * 256 * 4096 * 0.2
