"""Per-arch smoke tests: reduced same-family config, one forward/train step
on CPU, output shapes + no NaNs (assignment requirement), plus
decode-vs-forward consistency in fp32 for one arch per family."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.base import ShapeConfig
from repro.models import model as model_lib, transformer

ARCHS = sorted(configs.ARCHS)
SHAPE = ShapeConfig("smoke", 64, 2, "train")


@pytest.fixture(scope="module")
def reduced():
    out = {}
    for name in ARCHS:
        cfg = configs.reduced_config(configs.get_config(name))
        out[name] = (cfg, model_lib.init_params(cfg, 0))
    return out


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_no_nans(reduced, name):
    cfg, params = reduced[name]
    batch = model_lib.make_batch(cfg, SHAPE)
    h, aux = transformer.forward(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        remat="none",
    )
    assert h.shape == (SHAPE.global_batch, SHAPE.seq_len, cfg.d_model)
    logits = transformer.unembed(params, cfg, h)
    assert logits.shape == (SHAPE.global_batch, SHAPE.seq_len,
                            cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_no_nans(reduced, name):
    from repro.configs.base import TrainConfig
    from repro.launch import steps

    cfg, params = reduced[name]
    tc = TrainConfig(total_steps=4, warmup_steps=0)  # nonzero lr at step 0
    state = {"params": params,
             "opt": __import__("repro.optim", fromlist=["adamw"]).adamw.init_opt_state(params),
             "step": jnp.zeros((), jnp.int32)}
    batch = model_lib.make_batch(cfg, SHAPE)
    new_state, metrics = steps.train_step(state, batch, cfg=cfg, traincfg=tc)
    assert not bool(jnp.isnan(metrics["loss"]))
    assert not bool(jnp.isnan(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    moved = any(
        not jnp.array_equal(a, b)
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"]))
    )
    assert moved


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step_shapes(reduced, name):
    cfg, params = reduced[name]
    caches = transformer.init_cache(cfg, 2, 32)
    toks = jnp.array([1, 2], jnp.int32)
    logits, caches = transformer.decode_step(params, cfg, caches, toks,
                                             jnp.int32(0))
    assert logits.shape == (2, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize(
    "name",
    ["llama3-8b", "mamba2-2.7b", "hymba-1.5b", "deepseek-v2-236b",
     "llama4-scout-17b-a16e"],
)
def test_decode_matches_forward_fp32(name):
    """Sequential decode == full forward (KV/ring/SSD/MLA-absorb parity).

    MoE runs at no-drop capacity: dropped-token routing legitimately differs
    between a 96-token train batch and a 1-token decode step, and this test
    isolates *cache/recurrence* parity, not drop policy.
    """
    cfg = dataclasses.replace(
        configs.reduced_config(configs.get_config(name)), dtype="float32"
    )
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg,
            moe=dataclasses.replace(cfg.moe, capacity_factor=float(
                cfg.moe.num_experts)),
        )
    params = model_lib.init_params(cfg, 0)
    t, b = 48, 2
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    h, _ = transformer.forward(params, cfg, tokens=toks, remat="none")
    full = transformer.unembed(params, cfg, h)
    caches = transformer.init_cache(cfg, b, t)
    step = jax.jit(
        lambda c, tk, p: transformer.decode_step(params, cfg, c, tk, p)
    )
    outs = []
    for pos in range(t):
        logits, caches = step(caches, toks[:, pos], jnp.int32(pos))
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(dec - full))) < 1e-3


def test_kv_quant_decode_close_to_fp32():
    """int8 KV cache (decode memory lever): logits stay close to exact."""
    cfg = dataclasses.replace(
        configs.reduced_config(configs.get_config("llama3-8b")),
        dtype="float32",
    )
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    params = model_lib.init_params(cfg, 0)
    t, b = 32, 2
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, t), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    outs = {}
    for name, c in [("exact", cfg), ("int8", cfg_q)]:
        caches = transformer.init_cache(c, b, t)
        seq = []
        for pos in range(t):
            logits, caches = transformer.decode_step(
                params, c, caches, toks[:, pos], jnp.int32(pos)
            )
            seq.append(logits)
        outs[name] = jnp.stack(seq, 1)
    err = float(jnp.max(jnp.abs(outs["int8"] - outs["exact"])))
    ref = float(jnp.max(jnp.abs(outs["exact"])))
    assert err / ref < 0.05, (err, ref)
    # greedy decisions should essentially agree
    agree = float(jnp.mean(
        (jnp.argmax(outs["int8"], -1) == jnp.argmax(outs["exact"], -1))
    ))
    assert agree > 0.95


def test_param_counts_sane():
    """Analytic parameter counts within 15% of the published sizes."""
    expected = {
        "deepseek-7b": 7e9, "llama3-8b": 8e9, "llama3.2-1b": 1.2e9,
        "phi3-medium-14b": 14e9, "mamba2-2.7b": 2.7e9,
        "deepseek-v2-236b": 236e9, "chameleon-34b": 34e9,
        "hymba-1.5b": 1.5e9,
    }
    for name, want in expected.items():
        got = configs.get_config(name).param_count()
        assert 0.7 * want < got < 1.35 * want, f"{name}: {got:.2e} vs {want:.2e}"


def test_moe_active_params():
    cfg = configs.get_config("deepseek-v2-236b")
    active = cfg.active_param_count()
    total = cfg.param_count()
    assert active < 0.15 * total  # 21B-ish active of 236B
