"""Pallas kernels vs pure-jnp oracles — shape/dtype/config sweeps in
interpret mode (TPU is the compile target; interpret executes the kernel
body on CPU for correctness).  Integer outputs => exact equality."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import lz_match as kmod, ref


def _data(nc, c, vocab, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, vocab, size=(nc, c)).astype(np.int32))


@pytest.mark.parametrize("c", [128, 256, 512])
@pytest.mark.parametrize("w", [8, 32, 128])
@pytest.mark.parametrize("g", [2, 8])
def test_match_kernel_sweep(c, w, g):
    syms = _data(5, c, 4, c + w)
    got_l, got_o = kmod.lz_match_pallas(
        syms, window=w, chunks_per_block=g, interpret=True
    )
    exp_l, exp_o = ref.lz_match(syms, window=w)
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(exp_l))
    np.testing.assert_array_equal(np.asarray(got_o), np.asarray(exp_o))


@pytest.mark.parametrize("w", [17, 255])
def test_match_kernel_odd_windows(w):
    syms = _data(3, 192, 2, w)
    got_l, got_o = kmod.lz_match_pallas(
        syms, window=w, chunks_per_block=4, interpret=True
    )
    exp_l, exp_o = ref.lz_match(syms, window=w)
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(exp_l))
    np.testing.assert_array_equal(np.asarray(got_o), np.asarray(exp_o))


@pytest.mark.parametrize("s,mm", [(1, 3), (2, 2), (4, 1)])
@pytest.mark.parametrize("c", [128, 512])
def test_fused_kernel1_sweep(s, mm, c):
    syms = _data(4, c, 6, s * c)
    got = kmod.lz_kernel1_pallas(
        syms, window=32, min_match=mm, symbol_size=s,
        chunks_per_block=4, interpret=True,
    )
    exp = ref.lz_kernel1(syms, window=32, min_match=mm, symbol_size=s)
    for k in exp:
        np.testing.assert_array_equal(
            np.asarray(got[k]), np.asarray(exp[k]), err_msg=f"field {k}"
        )


def test_kernel_symbol_dtypes():
    """Symbols packed from u8/u16/u32 views (incl. negative int32 patterns)."""
    rng = np.random.default_rng(0)
    raw = rng.integers(0, 2**32 - 1, size=(2, 256), dtype=np.uint32)
    raw[:, 50:70] = raw[:, 10:30]  # plant repeats
    syms = jnp.asarray(raw.view(np.int32))
    got_l, got_o = kmod.lz_match_pallas(syms, window=64, interpret=True)
    exp_l, exp_o = ref.lz_match(syms, window=64)
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(exp_l))
    np.testing.assert_array_equal(np.asarray(got_o), np.asarray(exp_o))


def test_kernel_grid_padding():
    """nc not divisible by chunks_per_block."""
    syms = _data(3, 128, 3, 1)
    got_l, _ = kmod.lz_match_pallas(
        syms, window=16, chunks_per_block=8, interpret=True
    )
    exp_l, _ = ref.lz_match(syms, window=16)
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(exp_l))
