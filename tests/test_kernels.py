"""Pallas kernels vs pure-jnp oracles — shape/dtype/config sweeps in
interpret mode (TPU is the compile target; interpret executes the kernel
body on CPU for correctness).  Integer outputs => exact equality."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import decode, deflate
from repro.core import format as fmt
from repro.core.pipeline import LZSSConfig, get_backend
from repro.kernels import lz_decode as kdec, lz_match as kmod, ref
from repro.kernels import lz_scatter as kscat


def _data(nc, c, vocab, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, vocab, size=(nc, c)).astype(np.int32))


@pytest.mark.parametrize("c", [128, 256, 512])
@pytest.mark.parametrize("w", [8, 32, 128])
@pytest.mark.parametrize("g", [2, 8])
def test_match_kernel_sweep(c, w, g):
    syms = _data(5, c, 4, c + w)
    got_l, got_o = kmod.lz_match_pallas(
        syms, window=w, chunks_per_block=g, interpret=True
    )
    exp_l, exp_o = ref.lz_match(syms, window=w)
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(exp_l))
    np.testing.assert_array_equal(np.asarray(got_o), np.asarray(exp_o))


@pytest.mark.parametrize("w", [17, 255])
def test_match_kernel_odd_windows(w):
    syms = _data(3, 192, 2, w)
    got_l, got_o = kmod.lz_match_pallas(
        syms, window=w, chunks_per_block=4, interpret=True
    )
    exp_l, exp_o = ref.lz_match(syms, window=w)
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(exp_l))
    np.testing.assert_array_equal(np.asarray(got_o), np.asarray(exp_o))


@pytest.mark.parametrize("s,mm", [(1, 3), (2, 2), (4, 1)])
@pytest.mark.parametrize("c", [128, 512])
def test_fused_kernel1_sweep(s, mm, c):
    syms = _data(4, c, 6, s * c)
    got = kmod.lz_kernel1_pallas(
        syms, window=32, min_match=mm, symbol_size=s,
        chunks_per_block=4, interpret=True,
    )
    exp = ref.lz_kernel1(syms, window=32, min_match=mm, symbol_size=s)
    for k in exp:
        np.testing.assert_array_equal(
            np.asarray(got[k]), np.asarray(exp[k]), err_msg=f"field {k}"
        )


def test_kernel_symbol_dtypes():
    """Symbols packed from u8/u16/u32 views (incl. negative int32 patterns)."""
    rng = np.random.default_rng(0)
    raw = rng.integers(0, 2**32 - 1, size=(2, 256), dtype=np.uint32)
    raw[:, 50:70] = raw[:, 10:30]  # plant repeats
    syms = jnp.asarray(raw.view(np.int32))
    got_l, got_o = kmod.lz_match_pallas(syms, window=64, interpret=True)
    exp_l, exp_o = ref.lz_match(syms, window=64)
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(exp_l))
    np.testing.assert_array_equal(np.asarray(got_o), np.asarray(exp_o))


def test_kernel_grid_padding():
    """nc not divisible by chunks_per_block."""
    syms = _data(3, 128, 3, 1)
    got_l, _ = kmod.lz_match_pallas(
        syms, window=16, chunks_per_block=8, interpret=True
    )
    exp_l, _ = ref.lz_match(syms, window=16)
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(exp_l))


# ------------------------------------------------------- fused decoder


def _decode_sections(nc, c, s, seed):
    """Real per-chunk aligned flag/payload sections via the encode pipeline."""
    rng = np.random.default_rng(seed)
    raw = np.repeat(rng.integers(0, 6, nc * c // 2), 2)[: nc * c]
    syms = jnp.asarray(raw.reshape(nc, c).astype(np.int32))
    cfg = LZSSConfig(symbol_size=s, window=16, chunk_symbols=c)
    k1 = get_backend("xla").kernel1(syms, cfg)
    flag_bytes, _ = deflate.pack_flags(
        k1["emitted"], k1["use_match"], n_tokens=k1["n_tokens"]
    )
    payload = deflate.build_chunk_payloads(
        syms, k1["lengths"], k1["offsets"], k1, symbol_size=s
    )
    return flag_bytes, payload, k1["n_tokens"], syms


@pytest.mark.parametrize("s", [1, 2, 4])
@pytest.mark.parametrize("c", [64, 128])
@pytest.mark.parametrize("g", [2, 8])
def test_decode_kernel_sweep(s, c, g):
    fb, pay, ntok, syms = _decode_sections(5, c, s, seed=s * c + g)
    got = kdec.lz_decode_pallas(
        fb, pay, ntok, symbol_size=s, chunks_per_block=g, interpret=True
    )
    exp = decode.decode_parallel(fb, pay, ntok, symbol_size=s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(syms))


def test_decode_kernel_non_pow2_chunk_and_padding():
    """C not a power of two + nc not divisible by chunks_per_block."""
    fb, pay, ntok, syms = _decode_sections(3, 72, 2, seed=9)
    got = kdec.lz_decode_pallas(
        fb, pay, ntok, symbol_size=2, chunks_per_block=8, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(syms))


def test_offsets_kernel_matches_global_offsets():
    """Fused Kernel II == deflate.global_offsets (both prefix sums + totals)."""
    rng = np.random.default_rng(3)
    ntok = jnp.asarray(rng.integers(1, 100, 11).astype(np.int32))
    paysz = jnp.asarray(rng.integers(0, 256, 11).astype(np.int32))
    flag_sizes = (ntok + 7) // 8
    exp_po, exp_pt, exp_fo, exp_ft = deflate.global_offsets(paysz, flag_sizes)
    fo, po, ft, pt = kscat.lz_global_offsets_pallas(ntok, paysz, interpret=True)
    assert int(ft) == int(exp_ft)
    assert int(pt) == int(exp_pt)
    np.testing.assert_array_equal(np.asarray(fo)[:11], np.asarray(exp_fo))
    # pay offsets come out pre-based past the flag section
    np.testing.assert_array_equal(
        np.asarray(po)[:11], np.asarray(exp_po) + int(exp_ft)
    )


def _scatter_reference(syms, k1, s):
    """The unfused XLA tail's section bytes (Kernels II+III), header left 0."""
    nc, c = syms.shape
    flag_bytes, flag_sizes = deflate.pack_flags(
        k1["emitted"], k1["use_match"], n_tokens=k1["n_tokens"]
    )
    payload = deflate.build_chunk_payloads(
        syms, k1["lengths"], k1["offsets"], k1, symbol_size=s
    )
    pay_off, pay_total, flag_off, flag_total = deflate.global_offsets(
        k1["payload_sizes"], flag_sizes
    )
    cap = fmt.max_compressed_bytes(nc * c * s, s, c)
    sec_flags = fmt.HEADER_BYTES + 8 * nc
    out = jnp.zeros((cap,), jnp.int32)
    out = deflate.scatter_section(out, sec_flags, flag_bytes, flag_sizes, flag_off)
    out = deflate.scatter_section(
        out, sec_flags + flag_total, payload, k1["payload_sizes"], pay_off
    )
    return out, flag_total, pay_total, cap, sec_flags


@pytest.mark.parametrize("s", [1, 2, 4])
@pytest.mark.parametrize("c", [64, 128])
@pytest.mark.parametrize("g", [2, 8])
def test_scatter_kernel_sweep(s, c, g):
    """Fused Kernel II+III == the XLA deflate tail, byte for byte."""
    rng = np.random.default_rng(s * c + g)
    raw = np.repeat(rng.integers(0, 6, 5 * c // 2), 2)[: 5 * c]
    syms = jnp.asarray(raw.reshape(5, c).astype(np.int32))
    cfg = LZSSConfig(symbol_size=s, window=16, chunk_symbols=c)
    k1 = get_backend("xla").kernel1(syms, cfg)
    exp, exp_ft, exp_pt, cap, sec_flags = _scatter_reference(syms, k1, s)
    got, ft, pt = kscat.lz_scatter_pallas(
        syms, k1["lengths"], k1["offsets"], k1["emitted"], k1["use_match"],
        k1["local_off"], k1["n_tokens"], k1["payload_sizes"],
        symbol_size=s, cap=cap, sec_flags=sec_flags, chunks_per_block=g,
        interpret=True,
    )
    assert int(ft) == int(exp_ft)
    assert int(pt) == int(exp_pt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_scatter_kernel_grid_padding_exceeds_offset_lanes():
    """nc a multiple of 128 with a chunks_per_block that does not divide 128:
    the scatter grid (129 chunk rows) outruns pass 1's 128-lane offset
    padding, which must be extended — a regression for an OOB scalar-prefetch
    read."""
    rng = np.random.default_rng(11)
    raw = np.repeat(rng.integers(0, 5, 128 * 16), 2)[: 128 * 32]
    syms = jnp.asarray(raw.reshape(128, 32).astype(np.int32))
    cfg = LZSSConfig(symbol_size=1, window=8, chunk_symbols=32)
    k1 = get_backend("xla").kernel1(syms, cfg)
    exp, exp_ft, exp_pt, cap, sec_flags = _scatter_reference(syms, k1, 1)
    got, ft, pt = kscat.lz_scatter_pallas(
        syms, k1["lengths"], k1["offsets"], k1["emitted"], k1["use_match"],
        k1["local_off"], k1["n_tokens"], k1["payload_sizes"],
        symbol_size=1, cap=cap, sec_flags=sec_flags, chunks_per_block=3,
        interpret=True,
    )
    assert int(ft) == int(exp_ft)
    assert int(pt) == int(exp_pt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_scatter_kernel_all_literal_worst_case():
    """Noise input (all-literal chunks fill the worst-case capacity): the
    grid-padded rows' clamped windows must stay in bounds and write nothing."""
    rng = np.random.default_rng(7)
    syms = jnp.asarray(rng.integers(0, 2**16, (3, 64)).astype(np.int32))
    cfg = LZSSConfig(symbol_size=2, window=16, chunk_symbols=64)
    k1 = get_backend("xla").kernel1(syms, cfg)
    exp, exp_ft, exp_pt, cap, sec_flags = _scatter_reference(syms, k1, 2)
    got, ft, pt = kscat.lz_scatter_pallas(
        syms, k1["lengths"], k1["offsets"], k1["emitted"], k1["use_match"],
        k1["local_off"], k1["n_tokens"], k1["payload_sizes"],
        symbol_size=2, cap=cap, sec_flags=sec_flags, chunks_per_block=8,
        interpret=True,
    )
    assert int(pt) == int(exp_pt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_decode_kernel_empty_and_full_chunks():
    """All-zero chunks (max matches) and token counts of zero decode cleanly."""
    fb, pay, ntok, syms = _decode_sections(2, 64, 1, seed=1)
    # zero out the second chunk's tokens: kernel must emit zero symbols
    ntok = ntok.at[1].set(0)
    fb = fb.at[1].set(0)
    pay = pay.at[1].set(0)
    got = kdec.lz_decode_pallas(
        fb, pay, ntok, symbol_size=1, chunks_per_block=2, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got)[0], np.asarray(syms)[0])
    np.testing.assert_array_equal(np.asarray(got)[1], np.zeros(64, np.int32))
