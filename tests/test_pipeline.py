"""Pluggable pipeline backends: fused-vs-xla container equality, registry
semantics, the batched in-graph API, and the decompress dispatch-padding
regression.

Pallas kernels execute in interpret mode on CPU, so chunk sizes here are kept
small; containers are compared byte-for-byte (integer pipeline => exact)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import format as fmt, lzss, pipeline


def _corpus(seed, n=1500):
    """Run-heavy + noisy segments: exercises matches, literals and flags."""
    rng = np.random.default_rng(seed)
    runs = np.repeat(rng.integers(0, 16, 300), rng.integers(1, 8, 300))
    noise = rng.integers(0, 256, 300)
    return np.concatenate([runs, noise, runs]).astype(np.uint16)[:n]


# ------------------------------------------------------------ registry


def test_registry_lists_all_backends():
    assert {
        "xla",
        "xla-scan",
        "pallas-match",
        "fused",
        "fused-deflate",
        "fused-mono",
    } <= set(lzss.available_backends())


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown backend"):
        lzss.LZSSConfig(backend="nope")
    with pytest.raises(ValueError, match="unknown backend"):
        pipeline.get_backend("nope")


def test_register_custom_backend():
    class Echo:
        name = "test-echo"

        def kernel1(self, symbols, cfg):
            return pipeline.get_backend("xla").kernel1(symbols, cfg)

    pipeline.register_backend(Echo())
    try:
        cfg = lzss.LZSSConfig(symbol_size=1, window=16, chunk_symbols=64,
                              backend="test-echo")
        data = _corpus(0).astype(np.uint8)
        ref = lzss.LZSSConfig(symbol_size=1, window=16, chunk_symbols=64)
        assert np.array_equal(
            lzss.compress(data, cfg).data, lzss.compress(data, ref).data
        )
    finally:
        pipeline._BACKENDS.pop("test-echo", None)


def test_register_backend_duplicate_raises():
    """Silent overwrite of a registered backend is a bug (satellite fix)."""

    class Dup:
        name = "test-dup"

        def kernel1(self, symbols, cfg):
            return pipeline.get_backend("xla").kernel1(symbols, cfg)

    pipeline.register_backend(Dup())
    try:
        with pytest.raises(ValueError, match="already registered"):
            pipeline.register_backend(Dup())
        # the built-in entries are protected too
        with pytest.raises(ValueError, match="already registered"):
            pipeline.register_backend(pipeline.XlaBackend())
        # explicit overwrite is the sanctioned replacement path
        replacement = Dup()
        assert pipeline.register_backend(replacement, overwrite=True) is replacement
        assert pipeline._BACKENDS["test-dup"] is replacement
    finally:
        pipeline._BACKENDS.pop("test-dup", None)


# ----------------------- fused / fused-deflate == xla, bit for bit


@pytest.mark.parametrize("backend", ["fused", "fused-deflate", "fused-mono"])
@pytest.mark.parametrize("symbol_size", [1, 2, 4])
@pytest.mark.parametrize("level", [1, 2, 3, 4])
def test_fused_container_identical_to_xla(backend, symbol_size, level):
    window = lzss.WINDOW_LEVELS[level]
    data = _corpus(symbol_size * 10 + level)
    kw = dict(symbol_size=symbol_size, window=window, chunk_symbols=128)
    a = lzss.compress(data, lzss.LZSSConfig(backend="xla", **kw))
    b = lzss.compress(data, lzss.LZSSConfig(backend=backend, **kw))
    assert a.total_bytes == b.total_bytes
    assert np.array_equal(a.data, b.data)
    # and the container actually decodes back to the input
    out = lzss.decompress(b.data)
    assert np.array_equal(out, data.view(np.uint8).reshape(-1))


def test_fused_routes_through_kernel1(monkeypatch):
    """backend='fused' must enter ops.lz_kernel1; backend='xla' must not."""
    from repro.kernels import ops

    calls = {"n": 0}
    real = ops.lz_kernel1

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(ops, "lz_kernel1", counting)
    data = _corpus(42)
    # unusual geometry => fresh jit trace, so the python-level kernel entry
    # is observed (a cached trace would bypass the wrapper)
    kw = dict(symbol_size=2, window=31, chunk_symbols=88)
    lzss.compress(data, lzss.LZSSConfig(backend="xla", **kw))
    assert calls["n"] == 0
    lzss.compress(data, lzss.LZSSConfig(backend="fused", **kw))
    assert calls["n"] == 1


def test_fused_deflate_routes_through_scatter_kernel(monkeypatch):
    """backend='fused-deflate' must emit through ops.lz_scatter (fused
    Kernel II+III); 'fused' and 'xla' must keep using the XLA tail."""
    from repro.kernels import ops

    calls = {"scatter": 0, "kernel1": 0}
    real_scatter, real_k1 = ops.lz_scatter, ops.lz_kernel1

    def counting_scatter(*args, **kwargs):
        calls["scatter"] += 1
        return real_scatter(*args, **kwargs)

    def counting_k1(*args, **kwargs):
        calls["kernel1"] += 1
        return real_k1(*args, **kwargs)

    monkeypatch.setattr(ops, "lz_scatter", counting_scatter)
    monkeypatch.setattr(ops, "lz_kernel1", counting_k1)
    data = _corpus(43)
    # unusual geometry => fresh jit trace (see above)
    kw = dict(symbol_size=2, window=27, chunk_symbols=96)
    lzss.compress(data, lzss.LZSSConfig(backend="xla", **kw))
    lzss.compress(data, lzss.LZSSConfig(backend="fused", **kw))
    assert calls["scatter"] == 0
    lzss.compress(data, lzss.LZSSConfig(backend="fused-deflate", **kw))
    assert calls["scatter"] == 1
    assert calls["kernel1"] == 2  # fused-deflate reuses the fused Kernel I


# -------------------------------------------------- batched in-graph API


def test_compress_many_ragged_roundtrip():
    rng = np.random.default_rng(7)
    items = [
        np.repeat(rng.integers(0, 8, 50), rng.integers(1, 6, 50)).astype(np.uint8),
        rng.integers(0, 256, 1).astype(np.uint8),
        rng.integers(0, 4, 3000).astype(np.uint8),
        np.zeros(513, np.uint8),
    ]
    cfg = lzss.LZSSConfig(symbol_size=1, window=32, chunk_symbols=128)
    batch = lzss.compress_many(items, cfg)
    assert len(batch) == len(items)
    outs = lzss.decompress_many(batch)
    for item, out in zip(items, outs):
        assert np.array_equal(out, item)
    # every row is also a standalone container: per-item decompress agrees
    for b, item in enumerate(items):
        assert np.array_equal(lzss.decompress(batch[b].data), item)
        assert batch[b].orig_bytes == item.size


def test_compress_many_2d_batch_and_fused():
    rng = np.random.default_rng(8)
    block = np.repeat(rng.integers(0, 6, (4, 64)), 4, axis=1).astype(np.uint8)
    cfg = lzss.LZSSConfig(symbol_size=1, window=16, chunk_symbols=64,
                          backend="fused")
    batch = lzss.compress_many(block, cfg)
    outs = lzss.decompress_many(batch)
    for i in range(block.shape[0]):
        assert np.array_equal(outs[i], block[i])
    # batched containers == the single-buffer path, byte for byte
    single = lzss.compress(block[0], cfg)
    assert np.array_equal(batch[0].data, single.data)


def test_compress_many_matches_per_item_compress():
    rng = np.random.default_rng(9)
    items = [rng.integers(0, 4, n).astype(np.uint8) for n in (700, 700, 700)]
    cfg = lzss.LZSSConfig(symbol_size=1, window=32, chunk_symbols=128)
    batch = lzss.compress_many(items, cfg)
    for b, item in enumerate(items):
        assert np.array_equal(batch[b].data, lzss.compress(item, cfg).data)


def test_decompress_many_rejects_mixed_geometry():
    """The heterogeneous-batch error must name the offending buffer index
    and both geometries (regression: it used to be a bare ValueError)."""
    cfg_a = lzss.LZSSConfig(symbol_size=1, window=16, chunk_symbols=64)
    cfg_b = lzss.LZSSConfig(symbol_size=2, window=16, chunk_symbols=64)
    a = lzss.compress(np.zeros(100, np.uint8), cfg_a)
    b = lzss.compress(np.zeros(100, np.uint8), cfg_b)
    with pytest.raises(ValueError, match="homogeneous") as ei:
        lzss.decompress_many([a.data, b.data])
    msg = str(ei.value)
    assert "buffer 0" in msg and "symbol_size=1" in msg
    assert "buffer 1" in msg and "symbol_size=2" in msg
    # the index reported is the first mismatching buffer, not just "1"
    with pytest.raises(ValueError, match="buffer 2"):
        lzss.decompress_many([a.data, a.data, b.data])
    # ragged sizes with equal geometry (same chunk count) are fine
    c = lzss.compress(np.arange(120, dtype=np.uint8), cfg_a)
    outs = lzss.decompress_many([a.data, c.data])
    assert np.array_equal(outs[1], np.arange(120, dtype=np.uint8))


def test_decompress_many_mesh_requires_sharded_decoder():
    cfg = lzss.LZSSConfig(symbol_size=1, window=16, chunk_symbols=64)
    blob = lzss.compress(np.zeros(64, np.uint8), cfg)
    import jax

    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="sharded"):
        lzss.decompress_many([blob.data], decoder="xla-scan", mesh=mesh)
    # batch_axis without a mesh is a silent no-op upstream of the vmap
    # default path — reject it like LZSSConfig does (review fix)
    with pytest.raises(ValueError, match="batch_axis requires mesh"):
        lzss.decompress_many([blob.data], batch_axis="data")


def test_in_graph_batched_cores_roundtrip():
    """compress_many_chunks/decompress_many_chunks compose under jit."""
    rng = np.random.default_rng(10)
    c, nc, B = 64, 2, 3
    raw = np.repeat(rng.integers(0, 5, (B, nc * c // 4)), 4, axis=1)
    symbols = jnp.asarray(raw.reshape(B, nc, c).astype(np.int32))
    cfg = lzss.LZSSConfig(symbol_size=1, window=16, chunk_symbols=c)
    blobs, totals = pipeline.compress_many_chunks(symbols, cfg)
    import jax

    n_tok, pay = jax.vmap(
        lambda b: fmt.parse_tables_jax(b.astype(jnp.int32), nc)
    )(blobs)
    back = pipeline.decompress_many_chunks(
        blobs, n_tok, pay, symbol_size=1, chunk_symbols=c, n_chunks=nc
    )
    np.testing.assert_array_equal(np.asarray(back), raw.reshape(B, nc, c))


# ------------------------------------------- header truth + dispatch pad


def test_header_orig_bytes_written_in_graph():
    """No host-side header patching: the jitted core emits the true size."""
    data = np.arange(777, dtype=np.uint8)
    cfg = lzss.LZSSConfig(symbol_size=2, window=32, chunk_symbols=256)
    res = lzss.compress(data, cfg)
    h = fmt.parse_header(res.data)
    assert h.orig_bytes == 777
    # the same header bytes appear in the batched path
    batch = lzss.compress_many([data], cfg)
    assert fmt.parse_header(batch[0].data).orig_bytes == 777


def test_decompress_dispatch_is_linear_not_worst_case():
    """Small blobs must not be zero-padded to the worst-case capacity of
    their chunk geometry (the old quadratic-ish host blow-up)."""
    cfg = lzss.LZSSConfig(symbol_size=2, window=128, chunk_symbols=2048)
    res = lzss.compress(np.zeros(64, np.uint8), cfg)  # ~60-byte container
    cap = fmt.max_compressed_bytes(
        1 * 2048 * 2, 2, 2048
    )
    dispatch = lzss._dispatch_capacity(res.data.size)
    assert dispatch <= res.data.size + lzss._DISPATCH_QUANTUM
    assert dispatch < cap  # strictly smaller than the old worst-case pad
    # and correctness is unchanged
    assert np.array_equal(lzss.decompress(res.data), np.zeros(64, np.uint8))


def test_dispatch_capacity_buckets():
    q = lzss._DISPATCH_QUANTUM
    assert lzss._dispatch_capacity(1) == q
    assert lzss._dispatch_capacity(q) == q
    assert lzss._dispatch_capacity(q + 1) == 2 * q
    for n in (5, 4097, 100_000):
        assert lzss._dispatch_capacity(n) >= n
