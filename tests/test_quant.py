"""cuSZ-style quantizer: the error bound is a hard invariant.

Property-based variants (hypothesis) live in test_properties.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import quant


@pytest.mark.parametrize("ndim", [1, 2, 3])
def test_error_bound_random(ndim):
    rng = np.random.default_rng(ndim)
    x = rng.normal(size=(16, 16, 16)).astype(np.float32) * 100
    eb = 0.05
    q = quant.quantize(jnp.asarray(x), error_bound=eb, ndim=ndim)
    xr = quant.dequantize(q.codes, q.outlier_mask, q.outlier_vals,
                          error_bound=eb, ndim=ndim)
    assert float(jnp.max(jnp.abs(xr - x))) <= eb + 1e-5


@pytest.mark.parametrize("rel", [1e-1, 1e-2, 1e-3])
def test_error_bound_random_rel(rel):
    rng = np.random.default_rng(int(1 / rel))
    x = (rng.uniform(-1e4, 1e4, size=200)).astype(np.float32)
    eb = quant.relative_error_bound(x, rel)
    q = quant.quantize(jnp.asarray(x), error_bound=eb, ndim=1)
    xr = quant.dequantize(q.codes, q.outlier_mask, q.outlier_vals,
                          error_bound=eb, ndim=1)
    assert float(jnp.max(jnp.abs(xr - x))) <= eb * 1.01 + 1e-6


def test_smooth_field_codes_compress():
    """Smooth fields -> near-constant codes -> GPULZ ratio like the paper's
    quant datasets (hurr/nyx: 4-9x at W=128/S=2)."""
    from repro.core import lzss

    t = np.linspace(0, 30 * np.pi, 128 * 128).astype(np.float32)
    field = (np.sin(t) * 40 + np.cos(2.7 * t) * 3).reshape(128, 128)
    eb = quant.relative_error_bound(field, 1e-3)
    q = quant.quantize(jnp.asarray(field), error_bound=eb, ndim=2)
    codes = np.asarray(q.codes)
    res = lzss.compress(codes, lzss.LZSSConfig(symbol_size=2, window=128,
                                               chunk_symbols=2048))
    assert res.ratio > 3.0
    out = lzss.decompress(res.data).view(np.uint16).reshape(codes.shape)
    np.testing.assert_array_equal(out, codes)


def test_outlier_handling():
    x = np.zeros(100, np.float32)
    x[50] = 1e9  # saturates int16 code range -> outlier path
    q = quant.quantize(jnp.asarray(x), error_bound=1e-3, ndim=1)
    assert bool(q.outlier_mask[50]) or bool(q.outlier_mask[51])
    xr = quant.dequantize(q.codes, q.outlier_mask, q.outlier_vals,
                          error_bound=1e-3, ndim=1)
    assert abs(float(xr[50]) - 1e9) <= 1.0


def _roundtrip_err(x, eb):
    q = quant.quantize(jnp.asarray(x), error_bound=eb, ndim=1)
    xr = quant.dequantize(q.codes, q.outlier_mask, q.outlier_vals,
                          error_bound=eb, ndim=1)
    return q, float(jnp.max(jnp.abs(xr - jnp.asarray(x))))


def test_all_outlier_input():
    """Every element saturating the code range must stay within the bound
    (each one rides the exact outlier path, not a clipped code)."""
    rng = np.random.default_rng(7)
    x = (rng.normal(size=300) * 1e9).astype(np.float32)
    eb = 1e-3
    q, err = _roundtrip_err(x, eb)
    assert bool(q.outlier_mask.all())
    assert err <= eb + 1e-5


def test_eb_larger_than_data_range():
    """A bound wider than the whole data range quantizes everything to the
    zero bin — still within eb, no outliers, maximally compressible codes."""
    rng = np.random.default_rng(8)
    x = rng.uniform(-0.4, 0.4, 256).astype(np.float32)
    q, err = _roundtrip_err(x, 1.0)
    assert err <= 1.0
    assert not bool(q.outlier_mask.any())
    assert int(np.unique(np.asarray(q.codes)).size) <= 2  # first-delta + runs


def test_denormal_floats():
    """Denormals are within any positive eb of zero; the quantizer must not
    overflow or promote them to outliers."""
    x = np.full(128, 1e-42, np.float32)
    x[::5] = -4e-44
    x[7] = np.float32(5e-324)  # rounds to the smallest f32 denormal or 0
    eb = 1e-6
    q, err = _roundtrip_err(x, eb)
    assert err <= eb + 1e-12
    assert not bool(q.outlier_mask.any())
