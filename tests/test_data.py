"""Data pipeline: determinism + exact resume + shapes."""

import numpy as np

from repro.data.pipeline import DataConfig, make_batch_for_step


def test_deterministic_per_step():
    cfg = DataConfig(vocab_size=1000, seq_len=128, global_batch=4, seed=3)
    a = make_batch_for_step(cfg, 17)["tokens"]
    b = make_batch_for_step(cfg, 17)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = make_batch_for_step(cfg, 18)["tokens"]
    assert not np.array_equal(a, c)


def test_resume_is_pure_function_of_step():
    """Restart-from-checkpoint reproduces the stream with no iterator state."""
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=2, seed=0)
    run1 = [make_batch_for_step(cfg, s)["tokens"] for s in range(6)]
    run2 = [make_batch_for_step(cfg, s)["tokens"] for s in range(3, 6)]
    for a, b in zip(run1[3:], run2):
        np.testing.assert_array_equal(a, b)


def test_shapes_and_vocab_range():
    cfg = DataConfig(vocab_size=777, seq_len=32, global_batch=3, seed=1)
    t = make_batch_for_step(cfg, 0)["tokens"]
    assert t.shape == (3, 32) and t.dtype == np.int32
    assert t.min() >= 0 and t.max() < 777


def test_mmap_source(tmp_path):
    path = tmp_path / "tokens.bin"
    data = np.arange(1024, dtype=np.int32)
    data.tofile(path)
    cfg = DataConfig(vocab_size=2048, seq_len=16, global_batch=4,
                     source="mmap", path=str(path))
    t0 = make_batch_for_step(cfg, 0)["tokens"]
    np.testing.assert_array_equal(t0.reshape(-1), np.arange(64))
