"""Serving engine + KV block store."""

import numpy as np
import pytest

from repro import configs
from repro.models import model as model_lib
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import KVBlockStore, PagedKVTracker


def test_generate_greedy_deterministic():
    cfg = configs.reduced_config(configs.get_config("llama3.2-1b"))
    params = model_lib.init_params(cfg, 0)
    eng = ServingEngine(cfg, params, max_len=64)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    r1 = eng.generate(prompts, max_new_tokens=8)
    r2 = eng.generate(prompts, max_new_tokens=8)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    # prompt region is teacher-forced
    np.testing.assert_array_equal(r1.tokens[:, :8], prompts)


def test_kv_store_roundtrip_compressed():
    store = KVBlockStore(compress=True)
    rng = np.random.default_rng(1)
    block = (rng.normal(size=(64, 4, 16)) * 0.02).astype(np.float32)
    block[8:16] = block[0:8]
    store.evict(("s0", 0), block)
    assert ("s0", 0) in store
    out = store.restore(("s0", 0))
    np.testing.assert_array_equal(out, block)
    assert store.stats.evictions == 1 and store.stats.restores == 1


def test_tracker_lru_eviction():
    tr = PagedKVTracker(block_tokens=4, budget_blocks=2)
    tr.touch(0, 0)
    tr.touch(0, 4)
    tr.touch(0, 8)
    cands = tr.eviction_candidates()
    assert cands == [(0, 0)]  # oldest block evicted first


def test_kv_store_batched_evict_single_dispatch():
    """An eviction round compresses every block in ONE jitted call."""
    store = KVBlockStore(compress=True)
    rng = np.random.default_rng(2)
    blocks = []
    for i in range(5):
        b = (rng.normal(size=(32, 4, 16)) * 0.02).astype(np.float32)
        b[8:16] = b[0:8]
        blocks.append((("s", i), b))
    store.evict_many(blocks)
    assert store.stats.evictions == 5
    assert store.stats.eviction_dispatches == 1
    outs = store.restore_many([k for k, _ in blocks])
    for (_, want), got in zip(blocks, outs):
        np.testing.assert_array_equal(got, want)
    assert store.stats.restores == 5


def test_kv_store_batched_ragged_blocks():
    store = KVBlockStore(compress=True)
    rng = np.random.default_rng(3)
    big = np.repeat(rng.normal(size=(8, 64)).astype(np.float32), 8, axis=0)
    small = np.zeros((4, 16), np.float32)
    store.evict_many([("big", big), ("small", small)])
    np.testing.assert_array_equal(store.restore("small"), small)
    np.testing.assert_array_equal(store.restore("big"), big)


def test_engine_offloads_cold_blocks():
    """kv_offload evicts LRU-cold blocks (compressed, slot freed) in
    batched rounds and restores them on access."""
    cfg = configs.reduced_config(configs.get_config("llama3.2-1b"))
    params = model_lib.init_params(cfg, 0)
    eng = ServingEngine(cfg, params, max_len=64, kv_compress=True,
                        kv_offload=True, block_tokens=8, budget_blocks=12)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 4)).astype(np.int32)
    eng.generate(prompts, max_new_tokens=40)
    s = eng.kv_store.stats
    assert s.evictions > 0
    assert s.evicted_bytes_raw > 0
    # batched: far fewer dispatches than evicted blocks
    assert s.eviction_dispatches <= s.evictions
    # eviction is real: the allocator never exceeded the resident budget
    assert eng.paging_stats()["high_water"] <= 12


def test_kv_store_restore_many_missing_key_loses_nothing():
    store = KVBlockStore(compress=False)
    store.evict("a", np.zeros((4, 4), np.float32))
    with pytest.raises(KeyError):
        store.restore_many(["a", "missing"])
    assert "a" in store  # bad batch must not destroy stored blocks
