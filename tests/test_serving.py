"""Serving engine + KV block store."""

import numpy as np
import pytest

from repro import configs
from repro.models import model as model_lib
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import KVBlockStore, PagedKVTracker


def test_generate_greedy_deterministic():
    cfg = configs.reduced_config(configs.get_config("llama3.2-1b"))
    params = model_lib.init_params(cfg, 0)
    eng = ServingEngine(cfg, params, max_len=64)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    r1 = eng.generate(prompts, max_new_tokens=8)
    r2 = eng.generate(prompts, max_new_tokens=8)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    # prompt region is teacher-forced
    np.testing.assert_array_equal(r1.tokens[:, :8], prompts)


def test_kv_store_roundtrip_compressed():
    store = KVBlockStore(compress=True)
    rng = np.random.default_rng(1)
    block = (rng.normal(size=(64, 4, 16)) * 0.02).astype(np.float32)
    block[8:16] = block[0:8]
    store.evict(("s0", 0), block)
    assert ("s0", 0) in store
    out = store.restore(("s0", 0))
    np.testing.assert_array_equal(out, block)
    assert store.stats.evictions == 1 and store.stats.restores == 1


def test_tracker_lru_eviction():
    tr = PagedKVTracker(block_tokens=4, budget_blocks=2)
    tr.touch(0, 0)
    tr.touch(0, 4)
    tr.touch(0, 8)
    cands = tr.eviction_candidates()
    assert cands == [(0, 0)]  # oldest block evicted first
