"""Selector equivalence (scan vs pointer-doubling) and decoder equivalence.

Property-based variants (hypothesis) live in test_properties.py.
"""

import numpy as np
import pytest

from repro.core import deflate, encode, match


@pytest.mark.parametrize("w", [4, 16, 64])
@pytest.mark.parametrize("s", [1, 2, 4])
def test_selectors_agree_random(w, s):
    rng = np.random.default_rng(w * 10 + s)
    syms = rng.integers(0, 5, size=(3, 128)).astype(np.int32)
    lengths, _ = match.find_matches(syms, window=w)
    mm = encode.min_match_length(s)
    a = np.asarray(encode.select_tokens_scan(lengths, min_match=mm))
    b = np.asarray(encode.select_tokens_doubling(lengths, min_match=mm))
    np.testing.assert_array_equal(a, b)


def test_selector_greedy_semantics():
    # lengths: pos0 match len 3 (skip 1,2), pos3 no match, pos4 len 2...
    lengths = np.array([[3, 9, 9, 0, 2, 9, 0, 0]], np.int32)
    emitted = np.asarray(encode.select_tokens_scan(lengths, min_match=2))
    np.testing.assert_array_equal(
        emitted[0], [True, False, False, True, True, False, True, True]
    )


def test_token_fields_sizes():
    lengths = np.array([[3, 0, 0, 0, 2, 0, 0, 0]], np.int32)
    emitted = encode.select_tokens_scan(lengths, min_match=2)
    f = encode.token_fields(lengths, emitted, min_match=2, symbol_size=2)
    # tokens: match(2B) @0, literal(2B) @3, match(2B) @4, literal @6, literal @7
    assert int(f["payload_sizes"][0]) == 2 + 2 + 2 + 2 + 2
    assert int(f["n_tokens"][0]) == 5
    np.testing.assert_array_equal(
        np.asarray(f["local_off"][0]), [0, 2, 2, 2, 4, 6, 6, 8]
    )


def test_flag_packing_bits():
    emitted = np.array([[1, 0, 1, 1, 0, 0, 1, 1]], bool)
    use_match = np.array([[1, 0, 0, 1, 0, 0, 0, 1]], bool)
    fb, fs = deflate.pack_flags(emitted, use_match)
    # 5 tokens, bits (in emit order): 1,0,1,0,1 -> 0b10101 = 21
    assert int(fs[0]) == 1
    assert int(fb[0, 0]) == 0b10101


@pytest.mark.parametrize("s", [1, 2, 4])
def test_decoders_agree_random_streams(s):
    rng = np.random.default_rng(s)
    from repro.core import lzss

    data = np.repeat(rng.integers(0, 10, 400), rng.integers(1, 9, 400))
    data = data.astype(np.uint8)
    cfg = lzss.LZSSConfig(symbol_size=s, window=32, chunk_symbols=128)
    res = lzss.compress(data, cfg)
    a = lzss.decompress(res.data, decoder="scan")
    b = lzss.decompress(res.data, decoder="parallel")
    np.testing.assert_array_equal(a, b)
