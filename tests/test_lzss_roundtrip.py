"""Core invariant: decompress(compress(x)) == x for ANY input, any config.

Property-based variants (hypothesis) live in test_properties.py.
"""

import numpy as np
import pytest

from repro.core import lzss


def roundtrip(data: np.ndarray, cfg: lzss.LZSSConfig, decoder="parallel"):
    res = lzss.compress(data, cfg)
    out = lzss.decompress(res.data, decoder=decoder)
    raw = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
    assert np.array_equal(out, raw), (
        f"roundtrip failed: cfg={cfg} n={raw.size}"
    )
    return res


@pytest.mark.parametrize("symbol_size", [1, 2, 4])
@pytest.mark.parametrize("window", [8, 32, 255])
def test_roundtrip_random(symbol_size, window):
    rng = np.random.default_rng(symbol_size * 1000 + window)
    data = rng.integers(0, 256, size=3000).astype(np.uint8)
    cfg = lzss.LZSSConfig(symbol_size=symbol_size, window=window,
                          chunk_symbols=256)
    roundtrip(data, cfg)


@pytest.mark.parametrize("symbol_size", [1, 2, 4])
def test_roundtrip_compressible(symbol_size):
    rng = np.random.default_rng(7)
    base = np.repeat(rng.integers(0, 8, 500), rng.integers(1, 12, 500))
    data = base.astype(np.uint16)
    cfg = lzss.LZSSConfig(symbol_size=symbol_size, window=64,
                          chunk_symbols=512)
    res = roundtrip(data, cfg)
    assert res.ratio > 1.5  # run-heavy data must compress


def test_roundtrip_all_zeros():
    cfg = lzss.LZSSConfig(symbol_size=2, window=128, chunk_symbols=1024)
    res = roundtrip(np.zeros(10_000, np.uint8), cfg)
    assert res.ratio > 20


def test_roundtrip_empty_and_tiny():
    cfg = lzss.LZSSConfig(symbol_size=2, window=32, chunk_symbols=256)
    for n in (1, 2, 3, 5, 255, 256, 257):
        roundtrip(np.arange(n, dtype=np.uint8), cfg)


def test_roundtrip_unaligned_length():
    # n not divisible by S: padding must be invisible after decompress
    cfg = lzss.LZSSConfig(symbol_size=4, window=32, chunk_symbols=256)
    roundtrip(np.arange(1003, dtype=np.int64).view(np.uint8)[:4001], cfg)


def test_selector_backends_agree():
    rng = np.random.default_rng(3)
    data = np.repeat(rng.integers(0, 16, 1000), rng.integers(1, 6, 1000))
    data = data.astype(np.uint16)
    kw = dict(symbol_size=2, window=64, chunk_symbols=512)
    a = lzss.compress(data, lzss.LZSSConfig(backend="xla-scan", **kw))
    b = lzss.compress(data, lzss.LZSSConfig(backend="xla", **kw))
    assert np.array_equal(a.data, b.data)


def test_decoder_variants_agree():
    rng = np.random.default_rng(4)
    data = np.repeat(rng.integers(0, 16, 1000), rng.integers(1, 6, 1000))
    data = data.astype(np.uint16)
    cfg = lzss.LZSSConfig(symbol_size=2, window=64, chunk_symbols=512)
    res = lzss.compress(data, cfg)
    a = lzss.decompress(res.data, decoder="scan")
    b = lzss.decompress(res.data, decoder="parallel")
    assert np.array_equal(a, b)


def test_pallas_matcher_matches_xla_end_to_end():
    rng = np.random.default_rng(5)
    data = np.repeat(rng.integers(0, 32, 800), rng.integers(1, 5, 800))
    data = data.astype(np.uint16)[:2048]
    kw = dict(symbol_size=2, window=32, chunk_symbols=256)
    a = lzss.compress(data, lzss.LZSSConfig(backend="xla", **kw))
    b = lzss.compress(data, lzss.LZSSConfig(backend="pallas-match", **kw))
    assert np.array_equal(a.data, b.data)


def test_ratio_accounting_exact():
    """total_bytes must equal the container's real length."""
    rng = np.random.default_rng(6)
    data = rng.integers(0, 4, 5000).astype(np.uint8)
    cfg = lzss.LZSSConfig(symbol_size=1, window=32, chunk_symbols=512)
    res = lzss.compress(data, cfg)
    assert res.data.size == res.total_bytes
    from repro.core import format as fmt
    h = fmt.parse_header(res.data)
    assert h.total_bytes == res.total_bytes
    assert h.orig_bytes == data.size
