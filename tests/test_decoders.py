"""Decoder backend registry: every registered decoder must reconstruct
byte-identical symbols from every container every compressor backend emits.

Mirrors tests/test_pipeline.py's compressor sweeps on the decode side.  The
fused Pallas decoder executes in interpret mode on CPU, so geometries are
kept small; the integer pipeline makes all comparisons exact."""

import numpy as np
import pytest

from repro.core import lzss, pipeline


def _corpus(seed, n=1200):
    """Run-heavy + noisy segments: matches, literals, cross-chunk variety."""
    rng = np.random.default_rng(seed)
    runs = np.repeat(rng.integers(0, 16, 250), rng.integers(1, 8, 250))
    noise = rng.integers(0, 256, 250)
    return np.concatenate([runs, noise, runs]).astype(np.uint16)[:n]


# ------------------------------------------------------------ registry


def test_registry_lists_all_decoders():
    assert {
        "xla-parallel", "xla-scan", "fused", "fused-mono", "deflate-full",
        "lossy-fz",
    } <= set(lzss.available_decoders())


def test_entropy_pair_registered_both_sides():
    """The entropy subsystem registers 'deflate-full' as a compressor AND a
    decoder, and both declare the method-1 container."""
    from repro.core import format as fmt

    assert "deflate-full" in lzss.available_backends()
    assert "deflate-full" in lzss.available_decoders()
    assert pipeline.container_method("deflate-full") == fmt.METHOD_HUFFMAN
    assert pipeline.container_method("fused-mono") == fmt.METHOD_RAW
    assert pipeline.container_method("auto") == fmt.METHOD_RAW
    with pytest.raises(ValueError, match="unknown backend/decoder"):
        pipeline.container_method("nope")


def test_unknown_decoder_rejected():
    with pytest.raises(ValueError, match="unknown decoder"):
        lzss.LZSSConfig(decoder="nope")
    with pytest.raises(ValueError, match="unknown decoder"):
        pipeline.get_decoder("nope")
    with pytest.raises(ValueError, match="unknown decoder"):
        pipeline.resolve_decoder("nope")


def test_legacy_decoder_aliases_normalize():
    assert lzss.LZSSConfig(decoder="parallel").decoder == "xla-parallel"
    assert lzss.LZSSConfig(decoder="scan").decoder == "xla-scan"
    assert lzss.LZSSConfig().decoder == "auto"  # resolved at dispatch


def test_auto_resolves_to_fused_mono_on_tpu(monkeypatch):
    import jax

    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert pipeline.default_decoder() == "fused-mono"
    assert pipeline.resolve_decoder("auto") == "fused-mono"
    # REPRO_FUSED_MONO=0 audits the mono kernels out of BOTH directions:
    # the decode side falls back to the split fused decoder
    monkeypatch.setenv("REPRO_FUSED_MONO", "0")
    assert pipeline.resolve_decoder("auto") == "fused"
    monkeypatch.delenv("REPRO_FUSED_MONO")
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert pipeline.resolve_decoder("auto") == "xla-parallel"


def test_backend_auto_symmetry(monkeypatch):
    """backend='auto' resolves at dispatch exactly like decoder='auto'."""
    import jax

    assert lzss.LZSSConfig(backend="auto").backend == "auto"
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert pipeline.resolve_backend("auto") == "fused-mono"
    monkeypatch.setenv("REPRO_FUSED_MONO", "0")
    assert pipeline.resolve_backend("auto") == "fused-deflate"
    monkeypatch.delenv("REPRO_FUSED_MONO")
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert pipeline.resolve_backend("auto") == "xla"
    # and the auto config compresses to the same container as the resolved key
    data = _corpus(7, n=600)
    kw = dict(symbol_size=2, window=32, chunk_symbols=64)
    a = lzss.compress(data, lzss.LZSSConfig(backend="auto", **kw))
    b = lzss.compress(data, lzss.LZSSConfig(backend="xla", **kw))
    assert np.array_equal(a.data, b.data)


def test_register_custom_decoder():
    class Echo:
        name = "test-echo-decoder"

        def decode(self, flag_bytes, payload, n_tokens, *, symbol_size):
            return pipeline.get_decoder("xla-parallel").decode(
                flag_bytes, payload, n_tokens, symbol_size=symbol_size
            )

    pipeline.register_decoder(Echo())
    try:
        data = _corpus(0).astype(np.uint8)
        cfg = lzss.LZSSConfig(symbol_size=1, window=16, chunk_symbols=64)
        res = lzss.compress(data, cfg)
        out = lzss.decompress(res.data, decoder="test-echo-decoder")
        assert np.array_equal(out, data)
    finally:
        pipeline._DECODERS.pop("test-echo-decoder", None)


def test_register_decoder_duplicate_raises():
    """Silent overwrite of a registered decoder is a bug (satellite fix)."""

    class Dup:
        name = "test-dup-decoder"

        def decode(self, flag_bytes, payload, n_tokens, *, symbol_size):
            return pipeline.get_decoder("xla-parallel").decode(
                flag_bytes, payload, n_tokens, symbol_size=symbol_size
            )

    pipeline.register_decoder(Dup())
    try:
        with pytest.raises(ValueError, match="already registered"):
            pipeline.register_decoder(Dup())
        # explicit overwrite is the sanctioned replacement path
        replacement = Dup()
        assert pipeline.register_decoder(replacement, overwrite=True) is replacement
        assert pipeline._DECODERS["test-dup-decoder"] is replacement
    finally:
        pipeline._DECODERS.pop("test-dup-decoder", None)


def test_registries_hold_instances_not_classes():
    """register_backend/register_decoder store ready-to-call instances."""
    for b in pipeline._BACKENDS.values():
        assert not isinstance(b, type)
        assert callable(b.kernel1)
    for d in pipeline._DECODERS.values():
        assert not isinstance(d, type)
        assert callable(d.decode)


# ----------------------------- all decoders byte-identical, S x W sweep


@pytest.mark.parametrize("symbol_size", [1, 2, 4])
@pytest.mark.parametrize("level", [1, 2, 3, 4])
def test_all_decoders_identical(symbol_size, level):
    window = lzss.WINDOW_LEVELS[level]
    data = _corpus(symbol_size * 10 + level)
    cfg = lzss.LZSSConfig(
        symbol_size=symbol_size, window=window, chunk_symbols=128
    )
    res = lzss.compress(data, cfg)
    raw = data.view(np.uint8).reshape(-1)
    for decoder in lzss.available_decoders():
        if pipeline.container_method(decoder) != 0:
            # entropy decoders reject raw containers by design
            with pytest.raises(ValueError):
                lzss.decompress(res.data, decoder=decoder)
            continue
        out = lzss.decompress(res.data, decoder=decoder)
        assert np.array_equal(out, raw), f"decoder {decoder}"


# -------------------- every compressor backend x every decoder


@pytest.mark.parametrize("backend", sorted(pipeline._BACKENDS))
@pytest.mark.parametrize("decoder", sorted(pipeline._DECODERS))
def test_compressor_decoder_cross_product(backend, decoder):
    """Method-matched pairs roundtrip byte-identically; an entropy container
    handed to a raw decoder (or vice versa) is a clean ValueError.  The
    lossy-fz backend joins the product in its bit-exact eb=0 mode (f32
    symbols); its eb>0 bound is tests/test_lossy.py's domain."""
    from repro.core import format as fmt

    if pipeline.container_method(backend) == fmt.METHOD_LOSSY:
        data = _corpus(3, n=800).astype(np.float32) * 0.25
        cfg = lzss.LZSSConfig(
            symbol_size=4, window=32, chunk_symbols=64, backend=backend,
            lossy_eb=0.0,
        )
    else:
        data = _corpus(3, n=800)
        cfg = lzss.LZSSConfig(
            symbol_size=2, window=32, chunk_symbols=64, backend=backend
        )
    res = lzss.compress(data, cfg)
    if pipeline.container_method(backend) != pipeline.container_method(decoder):
        with pytest.raises(ValueError):
            lzss.decompress(res.data, decoder=decoder)
        return
    out = lzss.decompress(res.data, decoder=decoder)
    assert np.array_equal(out, data.view(np.uint8).reshape(-1))


def test_batched_decoders_identical():
    """decompress_many agrees across decoders on a ragged batch."""
    rng = np.random.default_rng(5)
    items = [
        np.repeat(rng.integers(0, 8, 60), rng.integers(1, 6, 60)).astype(np.uint8),
        rng.integers(0, 4, 900).astype(np.uint8),
        np.zeros(200, np.uint8),
    ]
    cfg = lzss.LZSSConfig(symbol_size=1, window=32, chunk_symbols=128)
    batch = lzss.compress_many(items, cfg)
    for decoder in lzss.available_decoders():
        if pipeline.container_method(decoder) != 0:
            with pytest.raises(ValueError):
                lzss.decompress_many(batch, decoder=decoder)
            continue
        outs = lzss.decompress_many(batch, decoder=decoder)
        for item, out in zip(items, outs):
            assert np.array_equal(out, item), f"decoder {decoder}"


# --------------------------------------------------- dispatch routing


def test_fused_decoder_routes_through_kernel(monkeypatch):
    """decoder='fused' must enter ops.lz_decode; the XLA decoders must not."""
    from repro.kernels import ops

    calls = {"n": 0}
    real = ops.lz_decode

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(ops, "lz_decode", counting)
    data = _corpus(42)
    # unusual geometry => fresh jit trace, so the python-level kernel entry
    # is observed (a cached trace would bypass the wrapper)
    cfg = lzss.LZSSConfig(symbol_size=2, window=29, chunk_symbols=72)
    res = lzss.compress(data, cfg)
    lzss.decompress(res.data, decoder="xla-parallel")
    lzss.decompress(res.data, decoder="xla-scan")
    assert calls["n"] == 0
    out = lzss.decompress(res.data, decoder="fused")
    assert calls["n"] == 1
    assert np.array_equal(out, data.view(np.uint8).reshape(-1))


# ------------------------------------------------- consumer plumbing


def test_kvblockstore_uses_config_decoder(monkeypatch):
    """restore_many must dispatch the store config's decoder, not a default."""
    from repro.serving import kvcache

    seen = {}
    real = kvcache.lzss.decompress_many

    def spy(batch, decoder="auto", mesh=None, batch_axis=None,
            chunks_per_block=None):
        seen["decoder"] = decoder
        return real(batch, decoder=decoder, mesh=mesh, batch_axis=batch_axis,
                    chunks_per_block=chunks_per_block)

    monkeypatch.setattr(kvcache.lzss, "decompress_many", spy)
    store = kvcache.KVBlockStore(compress=True, decoder="xla-scan")
    assert store.config.decoder == "xla-scan"
    block = np.tile(np.arange(256, dtype=np.uint16), 4)
    store.evict("blk", block)
    out = store.restore("blk")
    assert seen["decoder"] == "xla-scan"
    assert np.array_equal(out, block)


def test_checkpoint_manager_decoder_roundtrip(tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    state = {"w": (np.arange(2048, dtype=np.float32) % 17)}
    mgr = CheckpointManager(str(tmp_path), lz_decoder="fused", lz_chunk=256)
    mgr.save(state, 1)
    out, step = mgr.restore({"w": np.zeros(2048, np.float32)}, 1)
    assert step == 1
    assert np.array_equal(out["w"], state["w"])
