"""AdamW vs a hand-rolled numpy reference; schedule + clipping invariants."""

import numpy as np
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.optim import adamw


def test_adamw_matches_reference():
    tc = TrainConfig(learning_rate=1e-2, warmup_steps=0, total_steps=10,
                     weight_decay=0.1, grad_clip=1e9)
    p = {"w": jnp.array([1.0, -2.0, 3.0], jnp.float32)}
    g = {"w": jnp.array([0.1, 0.2, -0.3], jnp.float32)}
    opt = adamw.init_opt_state(p)
    new_p, new_opt, _ = adamw.adamw_update(p, g, opt, jnp.int32(0), tc)

    # numpy reference (bias-corrected adamw, step t=1)
    lr = 1e-2 * (0.1 + 0.45 * (1 + np.cos(0.0)))  # schedule at step 0
    m = 0.1 * np.array([0.1, 0.2, -0.3])
    v = 0.05 * np.array([0.1, 0.2, -0.3]) ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.95)
    want = np.array([1.0, -2.0, 3.0]) - lr * (
        mh / (np.sqrt(vh) + 1e-8) + 0.1 * np.array([1.0, -2.0, 3.0])
    )
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)


def test_lr_schedule_shape():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.lr_schedule(jnp.int32(s), tc)) for s in range(100)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-9          # end of warmup
    assert lrs[99] < lrs[50] < lrs[11]         # cosine decay
    assert lrs[99] >= 0.1 * 1e-3 - 1e-9        # floor at 10%


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0, 4.0])}  # norm 5
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    np.testing.assert_allclose(
        np.asarray(clipped["a"]), [0.6, 0.8], rtol=1e-6
    )
    unclipped, _ = adamw.clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(unclipped["a"]), [3.0, 4.0])


def test_bf16_params_fp32_moments():
    tc = TrainConfig(grad_clip=1e9)
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    g = {"w": jnp.full((4,), 0.01, jnp.bfloat16)}
    opt = adamw.init_opt_state(p)
    assert opt["m"]["w"].dtype == jnp.float32
    new_p, new_opt, _ = adamw.adamw_update(p, g, opt, jnp.int32(0), tc)
    assert new_p["w"].dtype == jnp.bfloat16
    assert new_opt["v"]["w"].dtype == jnp.float32
