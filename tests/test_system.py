"""End-to-end behaviour: a tiny LM trains (loss decreases), checkpoints
compress + resume bit-exactly, and the fault guard trips on stragglers."""

import numpy as np
import jax
import pytest

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data.pipeline import DataConfig, make_batch_for_step
from repro.launch import steps
from repro.runtime.fault import StepGuard


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = configs.reduced_config(configs.get_config("llama3.2-1b"))
    tc = TrainConfig(total_steps=30, warmup_steps=3, learning_rate=3e-3)
    shape = ShapeConfig("sys", 128, 4, "train")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=4,
                    seed=0)
    return cfg, tc, shape, dc


def test_tiny_lm_loss_decreases(tiny_setup):
    cfg, tc, shape, dc = tiny_setup
    state = steps.init_train_state(cfg, tc, 0)
    jfn = jax.jit(
        lambda s, b: steps.train_step(s, b, cfg=cfg, traincfg=tc)
    )
    losses = []
    for step in range(25):
        batch = make_batch_for_step(dc, step)
        state, metrics = jfn(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::6]
    assert not any(np.isnan(l) for l in losses)


def test_train_resume_bit_exact(tiny_setup, tmp_path):
    cfg, tc, shape, dc = tiny_setup
    jfn = jax.jit(lambda s, b: steps.train_step(s, b, cfg=cfg, traincfg=tc))

    # run A: 6 steps straight
    state_a = steps.init_train_state(cfg, tc, 0)
    for step in range(6):
        state_a, _ = jfn(state_a, make_batch_for_step(dc, step))

    # run B: 3 steps, checkpoint, restore, 3 more (data = f(step) resumes)
    mgr = CheckpointManager(str(tmp_path), compress=True)
    state_b = steps.init_train_state(cfg, tc, 0)
    for step in range(3):
        state_b, _ = jfn(state_b, make_batch_for_step(dc, step))
    mgr.save(state_b, 3)
    restored, start = mgr.restore_latest(jax.eval_shape(lambda: state_b))
    assert start == 3
    for step in range(start, 6):
        restored, _ = jfn(restored, make_batch_for_step(dc, step))

    for a, b in zip(jax.tree.leaves(state_a), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_step_guard_straggler_detection():
    g = StepGuard(threshold=2.0, max_consecutive_slow=2)
    for i in range(10):
        g.observe(i, 0.1)
    assert not g.should_restart
    assert g.observe(10, 0.5)      # 5x EWMA -> straggler
    assert g.observe(11, 0.5)
    assert g.should_restart
    assert g.stats.slow_steps == 2


def test_elastic_plan():
    from repro.launch import mesh as mesh_lib
    from repro.runtime.elastic import plan_remesh

    m1 = mesh_lib.make_host_mesh(data=1, model=1)
    m2 = mesh_lib.make_host_mesh(data=1, model=1, pod=1)
    plan = plan_remesh(m2, m1)
    assert plan.microbatch_scale == 1.0
    assert "remesh" in plan.describe()
