"""Paged-KV capacity tier: block-table attention + eviction/restore.

The exactness contract under test: with a resident-block budget smaller
than the all-layers working set, generated tokens stay bit-identical to the
dense-cache engine, device-resident physical blocks never exceed the
budget, and evicted blocks round-trip through the GPULZ store (raw,
deflate-full, and 8-device sharded restore configs).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ShapeConfig
from repro.launch import steps
from repro.models import model as model_lib, transformer
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import KVBlockStore, PagedKVTracker
from repro.serving.paging import BlockPoolAllocator, PrefetchQueue

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 host devices: run via `make test-serving` "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


@pytest.fixture(scope="module")
def llama():
    cfg = configs.reduced_config(configs.get_config("llama3.2-1b"))
    return cfg, model_lib.init_params(cfg, 0)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(0)
    return rng.integers(0, 256, (2, 8)).astype(np.int32)


@pytest.fixture(scope="module")
def dense_tokens(llama, prompts):
    cfg, params = llama
    eng = ServingEngine(cfg, params, max_len=64)
    return eng.generate(prompts, max_new_tokens=16).tokens


# budget 8 < working set 12 (2 layers x 2 seqs x 3 blocks) but >= the
# per-layer peak of 6: real eviction traffic with exactness preserved
TIGHT = dict(kv_offload=True, block_tokens=8, budget_blocks=8)


# ----------------------------------------------------------- model layers


def test_decode_step_paged_matches_dense_fully_mapped(llama, prompts):
    """Identity-mapped paged decode == dense decode, token for token."""
    cfg, params = llama
    maxlen, bt = 32, 8
    caches = transformer.init_cache(cfg, 2, maxlen)
    paged = transformer.init_paged_cache(cfg, 2, maxlen, block_tokens=bt)
    jd = jax.jit(lambda p, c, t, s: transformer.decode_step(p, cfg, c, t, s))
    jp = jax.jit(
        lambda p, c, t, s: transformer.decode_step_paged(p, cfg, c, t, s)
    )
    td = tp = jnp.asarray(prompts[:, 0])
    for pos in range(12):
        ld, caches = jd(params, caches, td, jnp.int32(pos))
        lp, paged = jp(params, paged, tp, jnp.int32(pos))
        if pos + 1 < prompts.shape[1]:
            td = tp = jnp.asarray(prompts[:, pos + 1])
        else:
            td = jnp.argmax(ld, axis=-1).astype(jnp.int32)
            tp = jnp.argmax(lp, axis=-1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(td), np.asarray(tp))


def test_paged_attention_ignores_unmapped_garbage(llama):
    """Garbage in unmapped pool slots must contribute exactly nothing."""
    cfg, params = llama
    maxlen, bt, b = 32, 8, 2
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, 256, (b,)).astype(np.int32))
    clean = transformer.init_paged_cache(cfg, b, maxlen, block_tokens=bt,
                                         pool_blocks=32)
    dirty = {
        "pool": {
            k: v.at[16:].set(
                jnp.asarray(rng.normal(size=v[16:].shape) * 100, v.dtype)
            )
            for k, v in clean["pool"].items()
        },
        "tables": clean["tables"],
        "extra": clean["extra"],
    }
    l0, _ = transformer.decode_step_paged(params, cfg, clean, toks,
                                          jnp.int32(0))
    l1, _ = transformer.decode_step_paged(params, cfg, dirty, toks,
                                          jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


def test_init_paged_cache_validation(llama):
    cfg, _ = llama
    with pytest.raises(ValueError):
        transformer.init_paged_cache(cfg, 2, 30, block_tokens=8)
    mla = dataclasses.replace(cfg, mixer="mla")
    with pytest.raises(NotImplementedError):
        transformer.init_paged_cache(mla, 2, 32, block_tokens=8)
    quant = dataclasses.replace(cfg, kv_quant=True)
    with pytest.raises(NotImplementedError):
        transformer.init_paged_cache(quant, 2, 32, block_tokens=8)


def test_make_paged_decode_step_twin(llama, prompts):
    """Compiled paged twin vs compiled dense decode: identical tokens."""
    cfg, params = llama
    b, maxlen, bt = 2, 32, 8
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("pg", maxlen, b, "decode")
    jd, _, _, _ = steps.make_decode_step(cfg, mesh, shape)
    jp, _, _, _ = steps.make_paged_decode_step(cfg, mesh, shape,
                                               block_tokens=bt)
    caches = transformer.init_cache(cfg, b, maxlen)
    paged = transformer.init_paged_cache(cfg, b, maxlen, block_tokens=bt)
    td = tp = jnp.asarray(prompts[:, 0])
    for pos in range(12):
        td, caches = jd(params, caches, {"tokens": td, "pos": jnp.int32(pos)})
        tp, paged = jp(params, paged, {"tokens": tp, "pos": jnp.int32(pos)})
        if pos + 1 < prompts.shape[1]:
            td = tp = jnp.asarray(prompts[:, pos + 1])
        np.testing.assert_array_equal(np.asarray(td), np.asarray(tp))


# ----------------------------------------------------------------- engine


def test_engine_paged_bit_identical_under_tight_budget(llama, prompts,
                                                       dense_tokens):
    cfg, params = llama
    eng = ServingEngine(cfg, params, max_len=64, kv_compress=True, **TIGHT)
    r = eng.generate(prompts, max_new_tokens=16)
    np.testing.assert_array_equal(r.tokens, dense_tokens)
    s = eng.paging_stats()
    assert s["working_set_blocks"] > eng.budget_blocks  # budget < working set
    assert s["high_water"] <= eng.budget_blocks  # allocator never over budget
    assert eng.kv_store.stats.evictions > 0
    assert eng.kv_store.stats.restores > 0


def test_engine_paged_deflate_full_roundtrip(llama, prompts, dense_tokens):
    """Eviction->restore through the entropy-coded v2 container."""
    cfg, params = llama
    eng = ServingEngine(cfg, params, max_len=64, kv_compress=True,
                        kv_backend="deflate-full", **TIGHT)
    r = eng.generate(prompts, max_new_tokens=16)
    np.testing.assert_array_equal(r.tokens, dense_tokens)
    assert eng.kv_store.stats.restores > 0
    assert eng.kv_store.stats.restore_dispatches > 0


def test_engine_paged_raw_codec_restore_stats(llama, prompts, dense_tokens):
    """Raw-codec blocks restore with ZERO decompression dispatches."""
    cfg, params = llama
    eng = ServingEngine(cfg, params, max_len=64, kv_compress=False, **TIGHT)
    r = eng.generate(prompts, max_new_tokens=16)
    np.testing.assert_array_equal(r.tokens, dense_tokens)
    s = eng.kv_store.stats
    assert s.restores > 0
    assert s.restore_dispatches == 0
    assert s.eviction_dispatches == 0


def test_engine_paged_prefetch_hits(llama, prompts, dense_tokens):
    """Next-access-group prefetch turns demand restores into early hits."""
    cfg, params = llama
    on = ServingEngine(cfg, params, max_len=64, kv_compress=True, **TIGHT)
    r = on.generate(prompts, max_new_tokens=16)
    np.testing.assert_array_equal(r.tokens, dense_tokens)
    s_on = on.paging_stats()
    assert s_on["prefetch_issued"] > 0
    assert s_on["prefetch_hits"] > 0

    off = ServingEngine(cfg, params, max_len=64, kv_compress=True,
                        kv_prefetch=False, **TIGHT)
    r2 = off.generate(prompts, max_new_tokens=16)
    np.testing.assert_array_equal(r2.tokens, dense_tokens)
    s_off = off.paging_stats()
    assert s_off["prefetch_issued"] == 0
    assert s_off["demand_restores"] > 0
    # prefetch serves restores ahead of the step that demands them
    assert s_on["demand_restores"] < s_off["demand_restores"]


def test_engine_paged_hybrid_swa(prompts):
    """Hybrid attention+SSM with sliding window: dead blocks retire, tokens
    still match the dense ring-buffer cache."""
    cfg = configs.reduced_config(configs.get_config("hymba-1.5b"))
    params = model_lib.init_params(cfg, 0)
    dense = ServingEngine(cfg, params, max_len=64)
    want = dense.generate(prompts, max_new_tokens=12).tokens
    eng = ServingEngine(cfg, params, max_len=64, kv_compress=True,
                        kv_offload=True, block_tokens=8, budget_blocks=6)
    r = eng.generate(prompts, max_new_tokens=12)
    np.testing.assert_array_equal(r.tokens, want)
    assert eng.paging_stats()["high_water"] <= 6


def test_engine_paged_budget_below_peak_raises(llama, prompts):
    cfg, params = llama
    eng = ServingEngine(cfg, params, max_len=64, kv_compress=True,
                        kv_offload=True, block_tokens=8, budget_blocks=4)
    with pytest.raises(ValueError, match="peak per-layer working set"):
        eng.generate(prompts, max_new_tokens=16)


def test_engine_paged_rejects_unsupported_configs(llama):
    cfg, params = llama
    with pytest.raises(ValueError, match="block_tokens"):
        ServingEngine(cfg, params, max_len=60, kv_offload=True,
                      block_tokens=8)
    quant = dataclasses.replace(cfg, kv_quant=True)
    with pytest.raises(NotImplementedError):
        ServingEngine(quant, params, max_len=64, kv_offload=True,
                      block_tokens=8)


@multidevice
def test_engine_paged_sharded_restore_8dev(llama, prompts, dense_tokens):
    """kv_mesh threads the sharded dispatch pair through evict AND restore;
    tokens stay bit-identical to the single-device dense engine."""
    cfg, params = llama
    mesh = jax.make_mesh((8,), ("data",))
    eng = ServingEngine(cfg, params, max_len=64, kv_compress=True,
                        kv_mesh=mesh, kv_batch_axis="data", **TIGHT)
    assert eng.kv_store.config.backend == "sharded"
    assert eng.kv_store.config.decoder == "sharded"
    r = eng.generate(prompts, max_new_tokens=16)
    np.testing.assert_array_equal(r.tokens, dense_tokens)
    assert eng.kv_store.stats.restores > 0


# ------------------------------------------------------- host-side pieces


def test_allocator_lowest_slot_first_and_high_water():
    a = BlockPoolAllocator(4)
    assert [a.alloc() for _ in range(3)] == [0, 1, 2]
    a.free(1)
    assert a.alloc() == 1  # lowest free slot, deterministic trace
    assert a.high_water == 3
    a.free(0)
    assert a.allocated == 2 and a.free_blocks == 2


def test_allocator_exhaustion_and_double_free():
    a = BlockPoolAllocator(2)
    a.alloc(), a.alloc()
    with pytest.raises(RuntimeError, match="budget=2"):
        a.alloc()
    a.free(0)
    with pytest.raises(ValueError, match="double free"):
        a.free(0)


def test_prefetch_queue_dedups_and_drains():
    q = PrefetchQueue()
    q.push(("a", 1)), q.push(("b", 2)), q.push(("a", 1))
    assert len(q) == 2
    assert q.pop_all() == [("a", 1), ("b", 2)]
    assert len(q) == 0


def test_tracker_logical_counter_pins_order():
    """Eviction order is a pure function of the access sequence: no wall
    clock, ties impossible, candidate order fully pinned."""
    tr = PagedKVTracker(block_tokens=4, budget_blocks=1)
    for key in ["a", "b", "c", "d"]:
        tr.touch_block(key)
    tr.touch_block("a")  # a becomes most-recent
    assert tr.eviction_candidates() == ["b", "c", "d"]
    assert tr.candidates(2) == ["b", "c"]
    assert tr.candidates(3, protected={"c"}) == ["b", "d", "a"]
    assert tr.candidates(99) == ["b", "c", "d", "a"]


# ------------------------------------------------ store batching key fix


def test_restore_many_mixed_method_store_groups_by_method():
    """A store holding raw-method v1 AND deflate-full v2 blobs must split
    the restore into per-method batches instead of one mixed
    decompress_many call (regression: PR 7 made mixing a ValueError)."""
    store = KVBlockStore(compress=True, backend="xla")
    rng = np.random.default_rng(7)
    blocks = {}
    for i in range(2):
        blk = np.repeat(rng.integers(0, 255, 512).astype(np.uint8), 4)
        blocks[("v1", i)] = blk
    store.evict_many(list(blocks.items()))
    store.config = dataclasses.replace(store.config, backend="deflate-full")
    for i in range(2):
        blk = np.repeat(rng.integers(0, 255, 512).astype(np.uint8), 4)
        blocks[("v2", i)] = blk
    store.evict_many([(k, v) for k, v in blocks.items() if k[0] == "v2"])
    keys = list(blocks)  # interleaves both methods in one restore round
    outs = store.restore_many(keys)
    for k, got in zip(keys, outs):
        np.testing.assert_array_equal(got, blocks[k])
    assert store.stats.restore_dispatches == 2  # one per method group
