"""Conformance suite: adversarial corpora x dtypes x windows, differential
against the pure-jnp oracle, across the full compressor x decoder product.

The deterministic twin of the hypothesis suite (tests/test_properties.py —
hypothesis is an optional extra, so THIS file is what always runs in CI):

  * ``oracle_container`` rebuilds a container straight from kernels/ref.py
    (scan selection) + the shared XLA emit tail, bypassing the backend
    registry entirely — every registered backend must reproduce its bytes.
  * the corpora are the adversarial shapes the paper's pipeline is most
    likely to get wrong: all-zero (maximal match chains), incompressible
    noise (all-literal worst case, maximal container), period == W repeats
    (matches exactly at the window edge), period == W+1 (just out of
    window), NaN/Inf float runs (bit patterns with every byte populated),
    and a ramp (no matches, low entropy).
  * every compressor x decoder pair must roundtrip bit-exactly.  The
    ``sharded`` entries appear in the product but degenerate to the
    platform backend here (no mesh is configured) — the actual shard_map
    dispatch is only covered by tests/test_sharding.py's 8-device lane.

Container truncation/corruption handling (the ``validate_container``
satellite fix) is regression-tested at the bottom.
"""

import numpy as np
import pytest

from repro.core import encode, format as fmt, lzss, pipeline
from repro.kernels import ref

# dtype label -> (numpy dtype, symbol_size)
DTYPES = {
    "u8": (np.uint8, 1),
    "i16": (np.int16, 2),
    "i32": (np.int32, 4),
    "f32": (np.float32, 4),
}


def _cast(vals, dtype):
    if dtype == np.float32:
        return (np.asarray(vals, np.float64) * 0.37 - 3.0).astype(np.float32)
    info = np.iinfo(dtype)
    return (np.asarray(vals, np.int64) % (int(info.max) + 1)).astype(dtype)


def corpora(dtype, window, n=600, rng=None):
    """Adversarial corpus pool; the single source the property suite fuzzes
    through too (tests/test_properties.py adversarial_case draws n/rng)."""
    if rng is None:
        rng = np.random.default_rng(11)
    out = {
        "all-zero": np.zeros(n, dtype),
        "incompressible": _cast(rng.integers(0, 1 << 31, n), dtype),
        "ramp": _cast(np.arange(n), dtype),
        f"period-{window}": np.tile(
            _cast(rng.integers(0, 1 << 16, window), dtype), -(-n // window)
        )[:n],
        f"period-{window + 1}": np.tile(
            _cast(rng.integers(0, 1 << 16, window + 1), dtype),
            -(-n // (window + 1)),
        )[:n],
    }
    if dtype == np.float32:
        runs = np.ones(n, np.float32)
        runs[n // 8 : n // 3] = np.nan
        runs[n // 2 : n // 2 + n // 8] = np.inf
        runs[-max(1, n // 8) :] = -np.inf
        out["nan-inf-runs"] = runs
    return out


def oracle_container(data, cfg):
    """Container bytes derived from the kernels/ref.py oracle (paper-faithful
    scan selection) + the shared XLA emit tail — no backend registry."""
    raw = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
    s, c = cfg.symbol_size, cfg.chunk_symbols
    nc = -(-(-(-max(raw.size, 1) // s)) // c)
    symbols = lzss._pack_padded(raw, nc, cfg)
    k1 = ref.lz_kernel1(
        symbols, window=cfg.window, min_match=cfg.min_match, symbol_size=s
    )
    k1 = dict(
        k1,
        **encode.token_fields(
            k1["lengths"], k1["emitted"], min_match=cfg.min_match, symbol_size=s
        ),
    )
    buf, total = pipeline.emit_xla(symbols, k1, cfg, raw.size)
    return np.asarray(buf)[: int(total)]


# ------------------------------------------- differential vs the oracle


@pytest.mark.parametrize("dtype_label", sorted(DTYPES))
@pytest.mark.parametrize("level", [1, 4])
def test_backends_match_oracle_bytes(dtype_label, level):
    """Every registered backend reproduces the ref.py oracle container on
    every adversarial corpus (dtype x window-level sweep)."""
    dtype, s = DTYPES[dtype_label]
    window = lzss.WINDOW_LEVELS[level]
    cfg_kw = dict(symbol_size=s, window=window, chunk_symbols=64)
    for corpus_name, data in corpora(dtype, window).items():
        raw = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
        oracle = oracle_container(data, lzss.LZSSConfig(**cfg_kw))
        for backend in lzss.available_backends():
            if pipeline.container_method(backend) == fmt.METHOD_LOSSY:
                # the lossy pair is f32-only and (eb > 0) intentionally not
                # bit-exact — its conformance lives in tests/test_lossy.py
                continue
            got = lzss.compress(data, lzss.LZSSConfig(backend=backend, **cfg_kw))
            if pipeline.container_method(backend) != fmt.METHOD_RAW:
                # entropy backends wrap the oracle sections in a bitstream:
                # bytes differ by design, the decoded symbols must not
                out = lzss.decompress(got.data)
                assert np.array_equal(out, raw), (
                    dtype_label, corpus_name, backend,
                )
                continue
            assert got.total_bytes == oracle.size and np.array_equal(
                got.data, oracle
            ), (dtype_label, corpus_name, backend)


@pytest.mark.parametrize("dtype_label", sorted(DTYPES))
def test_compressor_decoder_product_roundtrips(dtype_label):
    """Full compressor x decoder cross-product (including 'sharded' and the
    entropy pair): method-matched pairs roundtrip bit-exactly, mismatched
    pairs (an entropy container handed to a raw decoder or vice versa) are a
    clean ValueError, never silent garbage."""
    dtype, s = DTYPES[dtype_label]
    cfg_kw = dict(symbol_size=s, window=32, chunk_symbols=64)
    pool = corpora(dtype, 32)
    picks = ["incompressible", "all-zero"]
    if dtype == np.float32:
        picks.append("nan-inf-runs")
    for corpus_name in picks:
        data = pool[corpus_name]
        raw = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
        for backend in lzss.available_backends():
            if pipeline.container_method(backend) == fmt.METHOD_LOSSY:
                continue  # f32-only lossy pair: tests/test_lossy.py
            res = lzss.compress(data, lzss.LZSSConfig(backend=backend, **cfg_kw))
            method = pipeline.container_method(backend)
            for decoder in lzss.available_decoders():
                if pipeline.container_method(decoder) != method:
                    with pytest.raises(ValueError):
                        lzss.decompress(res.data, decoder=decoder)
                    continue
                out = lzss.decompress(res.data, decoder=decoder)
                assert np.array_equal(out, raw), (
                    dtype_label, corpus_name, backend, decoder,
                )


@pytest.mark.parametrize("chunk_symbols", [8, 64, 104, 256])
def test_chunk_geometry_sweep_matches_oracle(chunk_symbols):
    """Chunk size (incl. non-power-of-two, non-lane-multiple) never changes
    bytes vs the oracle for the fused single-kernel backend."""
    rng = np.random.default_rng(3)
    data = np.repeat(rng.integers(0, 7, 400), rng.integers(1, 5, 400)).astype(
        np.uint8
    )[:900]
    cfg_kw = dict(symbol_size=1, window=16, chunk_symbols=chunk_symbols)
    oracle = oracle_container(data, lzss.LZSSConfig(**cfg_kw))
    got = lzss.compress(data, lzss.LZSSConfig(backend="fused-mono", **cfg_kw))
    assert np.array_equal(got.data, oracle)
    assert np.array_equal(lzss.decompress(got.data), data)


# ------------------------------ truncation / corruption (satellite fix)


@pytest.fixture(scope="module")
def small_container():
    cfg = lzss.LZSSConfig(symbol_size=2, window=32, chunk_symbols=64)
    data = np.arange(300, dtype=np.uint8)
    return lzss.compress(data, cfg), data


def test_truncated_blob_raises_with_byte_counts(small_container):
    res, _ = small_container
    with pytest.raises(ValueError, match="truncated container") as ei:
        lzss.decompress(res.data[: res.total_bytes - 7])
    msg = str(ei.value)
    # the error must name BOTH the expected and the actual byte count
    assert str(res.total_bytes) in msg
    assert str(res.total_bytes - 7) in msg


def test_truncated_header_raises(small_container):
    res, _ = small_container
    # every cut inside the header must be a ValueError — including 4/5-byte
    # prefixes that keep a valid magic (regression: those used to index out
    # of bounds in parse_header and surface as IndexError)
    for cut in (0, 3, 4, 5, 20, fmt.HEADER_BYTES - 1):
        with pytest.raises(ValueError):
            lzss.decompress(res.data[:cut])


def test_corrupted_table_raises(small_container):
    res, _ = small_container
    bad = res.data.copy()
    bad[fmt.HEADER_BYTES] = 0xFF  # n_tokens[0] > C
    with pytest.raises(ValueError, match="corrupted container"):
        lzss.decompress(bad)


def test_corrupted_section_totals_raise(small_container):
    res, _ = small_container
    bad = res.data.copy()
    # decrement a nonzero byte of the payload_bytes field: the declared
    # total shrinks, so it no longer matches the per-chunk tables
    lo = 24 + int(np.nonzero(bad[24:32])[0][0])
    bad[lo] -= 1
    with pytest.raises(ValueError, match="corrupted container"):
        lzss.decompress(bad)
    bad = res.data.copy()
    bad[24:32] = 0xFF  # declared total exceeds the blob: truncation error
    with pytest.raises(ValueError, match="truncated container"):
        lzss.decompress(bad)


def test_corrupted_geometry_fields_raise(small_container):
    """Regression: flipped header geometry bytes must not decode to silent
    garbage — symbol_size flips trip the per-chunk token/byte invariant,
    out-of-range window/chunk_symbols/n_chunks trip the field checks."""
    res, _ = small_container  # written with symbol_size=2
    bad = res.data.copy()
    bad[5] = 1  # symbol_size 2 -> 1: psz == 2*ntok no longer fits [n, 2n)?
    # (s=2 chunks have psz == 2*ntok, legal for s=1 only if all-pointer;
    # this corpus has literals, so the totals cross-check still trips via
    # orig_bytes > nc*c*1)
    with pytest.raises(ValueError, match="corrupted container"):
        lzss.decompress(bad)
    bad = res.data.copy()
    bad[6] = 0  # window = 0
    with pytest.raises(ValueError, match="window"):
        lzss.decompress(bad)
    bad = res.data.copy()
    bad[8] = 0x0F  # chunk_symbols no longer a multiple of 8
    with pytest.raises(ValueError, match="chunk_symbols"):
        lzss.decompress(bad)


def test_corrupted_symbol_size_flip_raises():
    """The reviewer repro: symbol_size 1 -> 2 leaves every byte-count total
    intact; only the per-chunk payload/token invariant catches it."""
    data = np.arange(300, dtype=np.uint8)
    res = lzss.compress(
        data, lzss.LZSSConfig(symbol_size=1, window=32, chunk_symbols=64)
    )
    bad = res.data.copy()
    bad[5] = 2
    with pytest.raises(ValueError, match="corrupted container"):
        lzss.decompress(bad)


def test_decompress_many_names_offending_buffer(small_container):
    res, _ = small_container
    with pytest.raises(ValueError, match="buffer 1: truncated container"):
        lzss.decompress_many([res.data, res.data[:-3]])


def test_padded_blob_still_accepted(small_container):
    """Trailing zeros past total_bytes are legal (dispatch buckets pad)."""
    res, data = small_container
    padded = np.concatenate([res.data, np.zeros(123, np.uint8)])
    assert np.array_equal(lzss.decompress(padded), data)
