"""Gradient wire format: quantization bounds + compress/decompress parity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.lzss import LZSSConfig
from repro.optim import grad_compress as gc


def test_quantize_roundtrip_bound():
    rng = np.random.default_rng(0)
    g = rng.normal(size=4096).astype(np.float32) * 0.01
    codes, scale = gc.quantize_u16(jnp.asarray(g))
    back = np.asarray(gc.dequantize_u16(codes, scale))
    # symmetric int16 quantization: error <= scale/2 (+ fp32 rounding)
    assert np.abs(back - g).max() <= float(scale) * 0.5001


@pytest.mark.parametrize("redundant", [True, False])
def test_wire_roundtrip_lossless_budget(redundant):
    """ratio_cap=1 (2 B/elem budget): always lossless w.r.t. u16 codes."""
    rng = np.random.default_rng(1)
    if redundant:
        g = np.repeat(rng.normal(size=512) * 0.1, 16).astype(np.float32)
    else:
        g = rng.normal(size=8192).astype(np.float32)
    cfg = LZSSConfig(symbol_size=2, window=32, chunk_symbols=512)
    wire = gc.compress_leaf(jnp.asarray(g), cfg, ratio_cap=1.0)
    out = np.asarray(gc.decompress_leaf(wire, g.shape, cfg, ratio_cap=1.0))
    codes, scale = gc.quantize_u16(jnp.asarray(g))
    want = np.asarray(gc.dequantize_u16(codes, scale))
    np.testing.assert_allclose(out, want, atol=1e-12)
    nsym = -(-g.size // 512) * 512
    assert wire["payload"].size == nsym * 2


def test_wire_tight_budget_halves_bytes():
    """ratio_cap=2 (1 B/elem): half the bf16 exchange; compressible slabs
    stay u16-lossless, noise slabs degrade to int8."""
    rng = np.random.default_rng(1)
    sparse = jnp.zeros((8192,), jnp.float32).at[::64].set(0.5)
    cfg = LZSSConfig(symbol_size=2, window=32, chunk_symbols=512)
    wire = gc.compress_leaf(sparse, cfg, ratio_cap=2.0)
    assert wire["payload"].size == 8192  # 1 B/elem
    assert bool(jnp.all(wire["used_lz"]))
    out = np.asarray(gc.decompress_leaf(wire, (8192,), cfg, ratio_cap=2.0))
    codes, scale = gc.quantize_u16(sparse)
    want = np.asarray(gc.dequantize_u16(codes, scale))
    np.testing.assert_allclose(out, want, atol=1e-12)  # u16-lossless

    noise = jnp.asarray(rng.normal(size=8192).astype(np.float32))
    wire_n = gc.compress_leaf(noise, cfg, ratio_cap=2.0)
    assert not bool(jnp.all(wire_n["used_lz"]))  # int8 fallback
    out_n = np.asarray(gc.decompress_leaf(wire_n, (8192,), cfg,
                                          ratio_cap=2.0))
    _, scale_n = gc.quantize_u16(noise)
    # int8 fallback error bounded by 128*scale
    assert np.abs(out_n - np.asarray(noise)).max() <= float(scale_n) * 129


def test_wire_uses_lz_on_redundant_grads():
    g = jnp.zeros((65536,), jnp.float32).at[::100].set(0.5)
    wire = gc.compress_leaf(g, ratio_cap=1.0)
    assert bool(jnp.all(wire["used_lz"]))


def test_wire_falls_back_on_noise():
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(size=65536).astype(np.float32))
    wire = gc.compress_leaf(g, ratio_cap=1.0)
    # pure gaussian noise codes don't compress below 2B/elem with LZSS
    assert not bool(jnp.all(wire["used_lz"]))
