"""Single-kernel compressor (backend ``fused-mono``, kernels/lz_fused.py):
byte-identity sweeps, the one-Pallas-launch property, and the tiled output
path for containers larger than one VMEM window.

The S x {W32..255} identity sweep itself lives in tests/test_pipeline.py
(fused-mono rides the same parametrization as fused / fused-deflate); this
file covers what is unique to the mono kernel."""

import jax
import numpy as np
import pytest

from repro.core import format as fmt, lzss
from repro.kernels import lz_fused


def _corpus(seed, n=1500, dtype=np.uint16):
    rng = np.random.default_rng(seed)
    runs = np.repeat(rng.integers(0, 16, n // 4), rng.integers(1, 8, n // 4))
    noise = rng.integers(0, 256, n // 4)
    return np.concatenate([runs, noise, runs]).astype(dtype)[:n]


# ------------------------------------------------ one Pallas launch, total


def _count_pallas_calls(fn, monkeypatch):
    """Invoke ``fn`` while counting every ``pl.pallas_call`` site executed
    (at trace time — callers must use fresh geometry to avoid jit caches)."""
    from jax.experimental import pallas as pl_mod

    calls = {"n": 0}
    real = pl_mod.pallas_call

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(pl_mod, "pallas_call", counting)
    fn()
    return calls["n"]


def test_fused_mono_is_exactly_one_pallas_call(monkeypatch):
    """The whole compressor — matching through blob scatter — must be ONE
    kernel launch; the split fused-deflate pipeline takes three."""
    data = _corpus(21)
    # unusual geometries => fresh jit traces, so kernel entries are observed
    kw = dict(symbol_size=2, window=29, chunk_symbols=72)
    n = _count_pallas_calls(
        lambda: lzss.compress(data, lzss.LZSSConfig(backend="fused-mono", **kw)),
        monkeypatch,
    )
    assert n == 1

    kw = dict(symbol_size=2, window=30, chunk_symbols=72)
    n = _count_pallas_calls(
        lambda: lzss.compress(
            data, lzss.LZSSConfig(backend="fused-deflate", **kw)
        ),
        monkeypatch,
    )
    assert n == 3  # kernel1 + global offsets + deflate-scatter


def test_fused_mono_routes_through_mono_kernel(monkeypatch):
    """backend='fused-mono' must enter ops.lz_fused_mono; the split backends
    must not."""
    from repro.kernels import ops

    calls = {"n": 0}
    real = ops.lz_fused_mono

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(ops, "lz_fused_mono", counting)
    data = _corpus(22)
    kw = dict(symbol_size=2, window=33, chunk_symbols=80)
    lzss.compress(data, lzss.LZSSConfig(backend="xla", **kw))
    lzss.compress(data, lzss.LZSSConfig(backend="fused-deflate", **kw))
    assert calls["n"] == 0
    lzss.compress(data, lzss.LZSSConfig(backend="fused-mono", **kw))
    assert calls["n"] == 1


# ------------------------------------------------------- tiled blob output


def _slide_window_bytes(chunk_symbols, symbol_size, chunks_per_block=8):
    """One output tile of the mono kernel (the per-step DMA window)."""
    return chunks_per_block * chunk_symbols * symbol_size


def test_container_larger_than_one_output_tile_roundtrips():
    """cap > one VMEM output window => the blob is assembled across many
    slide-phase DMA windows; bytes must still match xla exactly."""
    kw = dict(symbol_size=1, window=32, chunk_symbols=128)
    data = _corpus(23, n=48 * 128, dtype=np.uint8)
    cap = fmt.max_compressed_bytes(data.size, 1, 128)
    assert cap > 4 * _slide_window_bytes(128, 1)  # genuinely multi-window
    a = lzss.compress(data, lzss.LZSSConfig(backend="xla", **kw))
    b = lzss.compress(data, lzss.LZSSConfig(backend="fused-mono", **kw))
    assert a.total_bytes == b.total_bytes
    assert np.array_equal(a.data, b.data)
    assert np.array_equal(lzss.decompress(b.data), data)


def test_incompressible_worst_case_fills_the_container():
    """All-literal input drives every clamp in the slide phase (payload ==
    worst case, flag section == worst case) — the staging slide must still
    land every byte and zero the staging region."""
    rng = np.random.default_rng(24)
    data = rng.integers(0, 256, 13 * 64, dtype=np.int64).astype(np.uint8)
    kw = dict(symbol_size=1, window=255, chunk_symbols=64)
    a = lzss.compress(data, lzss.LZSSConfig(backend="xla", **kw))
    b = lzss.compress(data, lzss.LZSSConfig(backend="fused-mono", **kw))
    assert np.array_equal(a.data, b.data)
    assert np.array_equal(lzss.decompress(b.data), data)


@pytest.mark.slow
def test_large_container_tiled_scatter_roundtrips():
    """A container far beyond one output window (the old (1, cap) VMEM-
    resident blob ceiling): 256 KiB through the tiled path, interpret mode."""
    rng = np.random.default_rng(25)
    n = 1 << 18
    data = np.repeat(rng.integers(0, 64, n // 4), 4).astype(np.uint8)[:n]
    kw = dict(symbol_size=2, window=32, chunk_symbols=2048)
    cap = fmt.max_compressed_bytes(n, 2, 2048)
    assert cap > 8 * _slide_window_bytes(2048, 2)
    b = lzss.compress(data, lzss.LZSSConfig(backend="fused-mono", **kw))
    a = lzss.compress(data, lzss.LZSSConfig(backend="xla", **kw))
    assert a.total_bytes == b.total_bytes
    assert np.array_equal(a.data, b.data)
    assert np.array_equal(lzss.decompress(b.data), data)


# ------------------------------------------------------------ API plumbing


def test_fused_mono_batched_paths_identical():
    """compress_many (vmapped compress hook) emits the same containers as
    the per-buffer path (equal sizes => same chunk geometry), and ragged
    batches still roundtrip."""
    rng = np.random.default_rng(26)
    same = [rng.integers(0, 4, 700).astype(np.uint8) for _ in range(3)]
    cfg = lzss.LZSSConfig(
        symbol_size=1, window=32, chunk_symbols=128, backend="fused-mono"
    )
    batch = lzss.compress_many(same, cfg)
    for b, item in enumerate(same):
        assert np.array_equal(batch[b].data, lzss.compress(item, cfg).data)
    ragged = [rng.integers(0, 4, sz).astype(np.uint8) for sz in (700, 1, 2000)]
    outs = lzss.decompress_many(lzss.compress_many(ragged, cfg))
    for item, out in zip(ragged, outs):
        assert np.array_equal(out, item)


def test_auto_prefers_fused_mono_on_tpu(monkeypatch):
    from repro.core import pipeline

    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert pipeline.default_backend() == "fused-mono"
    # explicit fallback to the split pipeline stays available
    monkeypatch.setenv("REPRO_FUSED_MONO", "0")
    assert pipeline.default_backend() == "fused-deflate"
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert pipeline.default_backend() == "xla"


def test_mono_kernel_rejects_unaligned_chunk():
    import jax.numpy as jnp

    with pytest.raises(ValueError, match="multiple of 8"):
        lz_fused.lz_fused_mono_pallas(
            jnp.zeros((1, 12), jnp.int32),
            window=8,
            min_match=2,
            symbol_size=1,
            cap=256,
            sec_flags=56,
            interpret=True,
        )
