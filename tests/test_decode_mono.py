"""Single-launch decoder (``fused-mono``, kernels/lz_decode_mono.py) and the
chunk-geometry autotuner (core/autotune.py).

Covers what is unique to the decode-mono path: the one-Pallas-launch /
zero-gather property (counter tests), symbol identity against the
paper-faithful scan oracle and the reference decoders across the S x W
sweep, golden-corpus blobs decoded through fused-mono, and the autotuner's
cache determinism (second call hits the cache, no re-sweep), corrupted-file
recovery, disabled-mode bit-exactness and geometry validation.  The generic
every-decoder sweeps in tests/test_decoders.py / test_conformance.py pick
``fused-mono`` up automatically via the registry."""

import json
import pathlib

import numpy as np
import pytest

from repro.core import autotune, decode as decode_mod, deflate
from repro.core import format as fmt, lzss, pipeline
from repro.kernels import ops

GOLDEN_DIR = pathlib.Path(__file__).resolve().parent / "golden"


def _corpus(seed, n=1500, dtype=np.uint16):
    rng = np.random.default_rng(seed)
    runs = np.repeat(rng.integers(0, 16, n // 4), rng.integers(1, 8, n // 4))
    noise = rng.integers(0, 256, n // 4)
    return np.concatenate([runs, noise, runs]).astype(dtype)[:n]


# -------------------------------------------- one launch, zero gathers


def _count_pallas_and_gathers(fn, monkeypatch):
    """Run ``fn`` counting pallas_call sites AND deflate.gather_section
    calls executed (at trace time — callers must use fresh geometry so jit
    caches don't swallow the entries)."""
    from jax.experimental import pallas as pl_mod

    calls = {"pallas": 0, "gather": 0}
    real_pc = pl_mod.pallas_call
    real_gs = deflate.gather_section

    def counting_pc(*args, **kwargs):
        calls["pallas"] += 1
        return real_pc(*args, **kwargs)

    def counting_gs(*args, **kwargs):
        calls["gather"] += 1
        return real_gs(*args, **kwargs)

    monkeypatch.setattr(pl_mod, "pallas_call", counting_pc)
    monkeypatch.setattr(deflate, "gather_section", counting_gs)
    fn()
    return calls["pallas"], calls["gather"]


def test_fused_mono_decode_is_exactly_one_pallas_call(monkeypatch):
    """Decode via fused-mono must be ONE kernel launch with the section
    gathers fused in (zero deflate.gather_section calls); the split paths
    issue two HBM-staged gathers each (plus the decode kernel for
    ``fused``) — at least two dispatches where fused-mono has one."""
    data = _corpus(31)
    # unusual geometry => fresh jit traces, so kernel entries are observed
    cfg = lzss.LZSSConfig(symbol_size=2, window=31, chunk_symbols=88)
    res = lzss.compress(data, cfg)  # xla backend: no pallas in compress

    n_pallas, n_gather = _count_pallas_and_gathers(
        lambda: lzss.decompress(res.data, decoder="fused-mono"), monkeypatch
    )
    assert (n_pallas, n_gather) == (1, 0)

    n_pallas, n_gather = _count_pallas_and_gathers(
        lambda: lzss.decompress(res.data, decoder="fused"), monkeypatch
    )
    assert (n_pallas, n_gather) == (1, 2)  # split path: gathers + kernel

    n_pallas, n_gather = _count_pallas_and_gathers(
        lambda: lzss.decompress(res.data, decoder="xla-parallel"), monkeypatch
    )
    assert (n_pallas, n_gather) == (0, 2)


def test_decode_mono_routes_through_kernel(monkeypatch):
    """decoder='fused-mono' must enter ops.lz_decode_mono; the split
    decoders must not."""
    calls = {"n": 0}
    real = ops.lz_decode_mono

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(ops, "lz_decode_mono", counting)
    data = _corpus(32)
    cfg = lzss.LZSSConfig(symbol_size=2, window=34, chunk_symbols=96)
    res = lzss.compress(data, cfg)
    lzss.decompress(res.data, decoder="xla-parallel")
    lzss.decompress(res.data, decoder="fused")
    assert calls["n"] == 0
    out = lzss.decompress(res.data, decoder="fused-mono")
    assert calls["n"] == 1
    assert np.array_equal(out, data.view(np.uint8).reshape(-1))


# ------------------------------------------- symbol identity, S x W sweep


@pytest.mark.parametrize("symbol_size", [1, 2, 4])
@pytest.mark.parametrize("window", [32, 255])
def test_decode_mono_symbol_identity_sweep(symbol_size, window):
    """fused-mono must be symbol-identical to xla-parallel AND the original
    bytes across the S x W grid (small C keeps interpret mode fast)."""
    data = _corpus(symbol_size * 10 + window, n=1200)
    cfg = lzss.LZSSConfig(
        symbol_size=symbol_size, window=window, chunk_symbols=64
    )
    res = lzss.compress(data, cfg)
    raw = data.view(np.uint8).reshape(-1)
    mono = lzss.decompress(res.data, decoder="fused-mono")
    assert np.array_equal(
        mono, lzss.decompress(res.data, decoder="xla-parallel")
    )
    assert np.array_equal(mono, raw)


def test_decode_mono_matches_scan_oracle_on_sections():
    """Kernel-level oracle check: the one-launch kernel's symbols must equal
    the paper-faithful sequential walk (decode_scan) run on the explicitly
    gathered sections of the same container."""
    import jax.numpy as jnp

    data = _corpus(33, n=2000)
    cfg = lzss.LZSSConfig(symbol_size=2, window=64, chunk_symbols=128)
    res = lzss.compress(data, cfg)
    h, n_tokens, payload_sizes = fmt.validate_container(res.data)
    blob = jnp.asarray(res.data).astype(jnp.int32)
    nt = jnp.asarray(n_tokens)
    psz = jnp.asarray(payload_sizes)
    fsz = (nt + 7) // 8
    fcs = jnp.cumsum(fsz)
    pcs = jnp.cumsum(psz)
    sec_flags = fmt.HEADER_BYTES + 8 * h.n_chunks
    flag_bytes = deflate.gather_section(
        blob, sec_flags, fsz, fcs - fsz, (h.chunk_symbols + 7) // 8
    )
    payload = deflate.gather_section(
        blob,
        sec_flags + fcs[-1],
        psz,
        pcs - psz,
        h.chunk_symbols * h.symbol_size,
    )
    want = decode_mod.decode_scan(
        flag_bytes, payload, nt, symbol_size=h.symbol_size
    )
    got = ops.lz_decode_mono(
        blob,
        nt,
        psz,
        symbol_size=h.symbol_size,
        chunk_symbols=h.chunk_symbols,
        n_chunks=h.n_chunks,
    )
    assert np.array_equal(np.asarray(got), np.asarray(want))


def _golden_cases():
    # method-0 (raw) blobs only: method-1 entropy containers route
    # exclusively through the "deflate-full" decoder by design (the
    # mismatch ValueError has its own test in tests/test_decoders.py)
    cases = [
        p
        for p in sorted(GOLDEN_DIR.glob("*.gplz"))
        if fmt.parse_header(np.frombuffer(p.read_bytes(), np.uint8)).method
        == fmt.METHOD_RAW
    ]
    assert cases, f"raw golden cases missing under {GOLDEN_DIR}"
    return cases


@pytest.mark.parametrize("gold", _golden_cases(), ids=lambda p: p.stem)
def test_golden_corpus_decodes_through_fused_mono(gold):
    """The checked-in golden blobs (the pinned wire format) must decode
    through the single-launch path — not just freshly produced containers."""
    inp = gold.with_name(f"{gold.stem}.input.bin")
    data = np.frombuffer(inp.read_bytes(), np.uint8)
    blob = np.frombuffer(gold.read_bytes(), np.uint8)
    assert np.array_equal(lzss.decompress(blob, decoder="fused-mono"), data)


def test_decode_mono_batched_ragged_roundtrip():
    """decompress_many through fused-mono (the vmapped decode_blob hook)
    reconstructs a ragged batch exactly."""
    rng = np.random.default_rng(34)
    items = [
        np.repeat(rng.integers(0, 8, 60), rng.integers(1, 6, 60)).astype(
            np.uint8
        ),
        rng.integers(0, 4, 900).astype(np.uint8),
        np.zeros(200, np.uint8),
    ]
    cfg = lzss.LZSSConfig(symbol_size=1, window=32, chunk_symbols=128)
    batch = lzss.compress_many(items, cfg)
    outs = lzss.decompress_many(batch, decoder="fused-mono")
    for item, out in zip(items, outs):
        assert np.array_equal(out, item)


# ------------------------------------------------------------- autotuner


@pytest.fixture
def tuned_env(tmp_path, monkeypatch):
    """Tuning force-enabled against an isolated cache file."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENABLE_ENV, "1")
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    autotune.reset()
    yield path
    autotune.reset()


def _key(chunk_symbols=64):
    return autotune.TuneKey(
        device_kind=autotune.device_kind(),
        dtype="u16",
        symbol_size=2,
        window=0,
        direction="decompress",
        chunk_symbols=chunk_symbols,
    )


def test_autotune_cache_written_then_hit_no_resweep(tuned_env):
    """First call sweeps and persists; the second (memo) and a fresh-process
    load (reset + reread) both return the same geometry with ZERO further
    measure calls — the determinism contract restore paths rely on."""
    key = _key()
    calls = {"n": 0}

    def measure(c, g):
        calls["n"] += 1
        return 1.0 / (c * g)  # deterministic: biggest candidate wins

    geom = autotune.best_geometry(key, measure)
    n_sweep = len(autotune.candidates(key))
    assert calls["n"] == n_sweep
    assert tuned_env.exists()
    autotune.validate_cache(json.loads(tuned_env.read_text()))

    # second call: in-process memo hit, no re-sweep
    assert autotune.best_geometry(key, measure) == geom
    assert calls["n"] == n_sweep

    # fresh process simulated: memo dropped, the persisted file answers
    autotune.reset()
    assert autotune.best_geometry(key, measure) == geom
    assert calls["n"] == n_sweep


def test_autotune_corrupted_cache_recovers(tuned_env):
    """A truncated/garbage cache file must be treated as empty — re-tuned
    and rewritten valid, never crashed on or trusted."""
    tuned_env.write_text('{"version": 1, "entries": {"k": "garbage"')
    key = _key()
    calls = {"n": 0}

    def measure(c, g):
        calls["n"] += 1
        return 1.0 / (c * g)

    geom = autotune.best_geometry(key, measure)
    assert calls["n"] == len(autotune.candidates(key))  # re-swept
    assert geom in autotune.candidates(key)
    autotune.validate_cache(json.loads(tuned_env.read_text()))  # rewritten


def test_autotune_disabled_is_static_geometry(monkeypatch):
    """REPRO_AUTOTUNE=0 must reproduce the pre-autotuner static geometry —
    and the containers it yields — bit-exactly."""
    data = _corpus(35)
    cfg = lzss.LZSSConfig(symbol_size=2, window=32, chunk_symbols=64)
    baseline = lzss.compress(data, cfg)

    monkeypatch.setenv(autotune.ENABLE_ENV, "0")
    autotune.reset()
    try:
        assert not autotune.enabled()
        assert autotune.best_geometry(_key()) == (
            64,
            autotune.DEFAULT_CHUNKS_PER_BLOCK,
        )
        pinned = lzss.compress(data, cfg)
        assert np.array_equal(pinned.data, baseline.data)
        assert np.array_equal(
            lzss.decompress(pinned.data, decoder="fused-mono"),
            data.view(np.uint8).reshape(-1),
        )
    finally:
        autotune.reset()


def test_autotune_never_sweeps_inside_a_trace(tuned_env):
    """A best_geometry call staged under jit must NOT run the timed sweep:
    block_until_ready no-ops on tracers, so perf_counter would time tracing
    overhead and the persisted 'winner' would be noise governing all future
    runs.  Under a trace an untuned key serves the deterministic fallback,
    unpersisted; a previously (eagerly) tuned key serves its cache hit."""
    import jax
    import jax.numpy as jnp

    key = _key()
    calls = {"n": 0}

    def measure(c, g):
        calls["n"] += 1
        return 1.0 / (c * g)

    got = []

    def traced(x):
        got.append(autotune.best_geometry(key, measure))
        return x

    jax.jit(traced)(jnp.zeros(()))
    assert calls["n"] == 0  # no sweep staged into the trace
    assert got == [autotune.fallback(key)]
    assert not tuned_env.exists()  # nothing persisted

    # an eager call still tunes (the in-trace fallback was not memoized) …
    geom = autotune.best_geometry(key, measure)
    assert calls["n"] == len(autotune.candidates(key))
    # … and a subsequent in-trace call now serves that tuned result
    got.clear()
    jax.jit(traced)(jnp.zeros((2,)))  # new shape => genuine retrace
    assert got == [geom] and calls["n"] == len(autotune.candidates(key))


def test_autotune_sweep_runs_eagerly_from_host_entry_points(tuned_env, monkeypatch):
    """lzss.compress/decompress must resolve tuned geometry OUTSIDE their
    jitted cores: every measure() the sweep runs executes with a clean
    trace state (kernels really run, timings are real), and the winner is
    persisted."""
    import jax

    states = []
    real = autotune._default_measure

    def spying_measure_factory(key):
        m = real(key)

        def measure(c, g):
            states.append(jax.core.trace_state_clean())
            return m(c, g)

        return measure

    monkeypatch.setattr(autotune, "_default_measure", spying_measure_factory)
    data = _corpus(40, n=600)
    cfg = lzss.LZSSConfig(symbol_size=2, window=33, chunk_symbols=64)
    res = lzss.compress(data, cfg)
    assert states and all(states)  # compress-side sweep ran, eagerly
    autotune.validate_cache(json.loads(tuned_env.read_text()))
    assert json.loads(tuned_env.read_text())["entries"]

    states.clear()
    out = lzss.decompress(res.data, decoder="fused-mono")
    assert np.array_equal(out, data.view(np.uint8).reshape(-1))
    assert states and all(states)  # decode-side sweep ran, eagerly


def test_autotune_xla_decoder_skips_decode_sweep(tuned_env, monkeypatch):
    """A pure-XLA decoder never tiles a kernel: resolving geometry for it
    must not burn a sweep (uses_block_geometry=False)."""
    calls = {"n": 0}

    def factory(key):
        calls["n"] += 1
        return lambda c, g: 1.0

    monkeypatch.setattr(autotune, "_default_measure", factory)
    data = _corpus(42, n=500)
    cfg = lzss.LZSSConfig(symbol_size=1, window=32, chunk_symbols=56)
    res = lzss.compress(data, cfg)
    compress_sweeps = calls["n"]
    out = lzss.decompress(res.data, decoder="xla-parallel")
    assert np.array_equal(out, data.view(np.uint8).reshape(-1))
    assert calls["n"] == compress_sweeps  # no decode-direction sweep


def test_autotune_cache_hit_revalidates_vmem_fit(tuned_env):
    """A schema-valid but oversized entry (shared REPRO_AUTOTUNE_CACHE,
    hand-edited file, or a budget change) must never flow into Pallas: the
    hit is re-checked against the VMEM budget, dropped, and re-swept."""
    key = _key()
    tuned_env.write_text(json.dumps({
        "version": autotune.CACHE_VERSION,
        "entries": {key.cache_key(): {
            "chunk_symbols": 64,
            "chunks_per_block": 1 << 20,  # passes validate_cache, cannot fit
            "seconds_per_call": 1e-3,
        }},
    }))
    autotune.validate_cache(json.loads(tuned_env.read_text()))  # schema-valid
    calls = {"n": 0}

    def measure(c, g):
        calls["n"] += 1
        return 1.0 / (c * g)

    geom = autotune.best_geometry(key, measure)
    assert calls["n"] == len(autotune.candidates(key))  # re-swept, not trusted
    assert autotune._fits(*geom, key.symbol_size)
    # the rewritten entry is served on the next fresh-process load
    autotune.reset()
    assert autotune.best_geometry(key, measure) == geom
    assert calls["n"] == len(autotune.candidates(key))


def test_autotune_cache_hit_revalidates_fixed_c(tuned_env):
    """An entry whose chunk_symbols disagrees with a fixed-C key (stale or
    corrupted cache) must be ignored — the call site's shapes are already
    committed to its C."""
    key = _key(chunk_symbols=64)
    tuned_env.write_text(json.dumps({
        "version": autotune.CACHE_VERSION,
        "entries": {key.cache_key(): {
            "chunk_symbols": 2048,  # not this key's C
            "chunks_per_block": 8,
            "seconds_per_call": 1e-3,
        }},
    }))
    c, g = autotune.best_geometry(key, lambda c_, g_: 1.0 / (c_ * g_))
    assert c == 64
    assert (c, g) in autotune.candidates(key)


def test_autotune_default_gating(monkeypatch):
    """Unset env: tuning only on real TPU (interpret timings mean nothing),
    so CPU CI always runs the deterministic fallback."""
    import jax

    monkeypatch.delenv(autotune.ENABLE_ENV, raising=False)
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert not autotune.enabled()
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert autotune.enabled()
    monkeypatch.setenv(autotune.ENABLE_ENV, "0")
    assert not autotune.enabled()


def test_tuned_config_disabled_matches_defaults(monkeypatch):
    monkeypatch.setenv(autotune.ENABLE_ENV, "0")
    autotune.reset()
    try:
        cfg = pipeline.tuned_config(symbol_size=2, window=128)
        assert cfg.chunk_symbols == autotune.DEFAULT_CHUNK_SYMBOLS
        assert cfg.chunks_per_block == autotune.DEFAULT_CHUNKS_PER_BLOCK
        # explicit overrides beat the tuner
        cfg = pipeline.tuned_config(window=64, chunk_symbols=256)
        assert cfg.chunk_symbols == 256 and cfg.window == 64
    finally:
        autotune.reset()


# -------------------------------------------------- geometry validation


def test_config_rejects_oversized_block_geometry():
    """A (chunk_symbols, chunks_per_block) pair that cannot fit the VMEM
    block budget must fail at config time, naming the pair — not as an
    opaque Mosaic allocation error inside Pallas."""
    with pytest.raises(ValueError, match=r"chunk_symbols=65536.*chunks_per_block=32"):
        lzss.LZSSConfig(chunk_symbols=65536, chunks_per_block=32)
    with pytest.raises(ValueError, match="chunks_per_block"):
        lzss.LZSSConfig(chunks_per_block=0)
    with pytest.raises(ValueError, match="chunks_per_block"):
        lzss.LZSSConfig(chunks_per_block=-2)
    # an oversized C is caught even with the default (autotuned) g
    with pytest.raises(ValueError, match="chunk_symbols"):
        lzss.LZSSConfig(chunk_symbols=1 << 22)


def test_pinned_chunks_per_block_is_format_invisible():
    """Block geometry tiles kernel execution only: pinning g must produce
    byte-identical containers and symbols across values — in BOTH
    directions (decode takes the same pin as its own argument)."""
    data = _corpus(36, n=900)
    outs = []
    for g in (1, 4, 8):
        cfg = lzss.LZSSConfig(
            symbol_size=2,
            window=32,
            chunk_symbols=64,
            chunks_per_block=g,
            backend="fused-mono",
        )
        res = lzss.compress(data, cfg)
        outs.append(res.data)
        assert np.array_equal(
            lzss.decompress(res.data, decoder="fused-mono", chunks_per_block=g),
            data.view(np.uint8).reshape(-1),
        )
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[1], outs[2])


# ------------------------------------------- decode-side geometry pinning


def test_decode_pin_reaches_mono_and_split_kernels(monkeypatch):
    """A pinned chunks_per_block must reach the decode kernels — pinning
    only the compress direction would silently hand a reproducibility-
    pinned restore path to the autotuner."""
    seen = {}
    real_mono, real_split = ops.lz_decode_mono, ops.lz_decode

    def spy_mono(*args, **kwargs):
        seen["mono"] = kwargs.get("chunks_per_block")
        return real_mono(*args, **kwargs)

    def spy_split(*args, **kwargs):
        seen["split"] = kwargs.get("chunks_per_block")
        return real_split(*args, **kwargs)

    monkeypatch.setattr(ops, "lz_decode_mono", spy_mono)
    monkeypatch.setattr(ops, "lz_decode", spy_split)
    data = _corpus(41, n=700)
    # unusual geometry => fresh jit traces, so the spies observe the calls
    cfg = lzss.LZSSConfig(symbol_size=2, window=35, chunk_symbols=104)
    res = lzss.compress(data, cfg)
    raw = data.view(np.uint8).reshape(-1)

    out = lzss.decompress(res.data, decoder="fused-mono", chunks_per_block=2)
    assert seen.pop("mono") == 2
    assert np.array_equal(out, raw)

    out = lzss.decompress(res.data, decoder="fused", chunks_per_block=2)
    assert seen.pop("split") == 2
    assert np.array_equal(out, raw)


def test_decode_pin_threads_through_batched_and_checkpoint_restore(
    monkeypatch, tmp_path
):
    """CheckpointManager.lz_chunks_per_block documents pinning 'the Pallas
    kernels' block geometry' — that must include the restore direction,
    through decompress_many and the decode_blob hook."""
    from repro.checkpoint.manager import CheckpointManager

    seen = []
    real = ops.lz_decode_mono

    def spy(*args, **kwargs):
        seen.append(kwargs.get("chunks_per_block"))
        return real(*args, **kwargs)

    monkeypatch.setattr(ops, "lz_decode_mono", spy)
    mgr = CheckpointManager(
        directory=str(tmp_path),
        lz_window=31,
        lz_chunk=112,
        lz_decoder="fused-mono",
        lz_chunks_per_block=2,
    )
    rng = np.random.default_rng(43)
    state = {"w": np.repeat(rng.integers(0, 8, 400), 4).astype(np.float32)}
    mgr.save(state, step=1)
    restored, step = mgr.restore(
        template={"w": np.zeros(1600, np.float32)}, step=1
    )
    assert step == 1
    assert np.array_equal(restored["w"], state["w"])
    assert seen and all(g == 2 for g in seen)
