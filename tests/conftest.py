import os
import sys

# Make `import repro` work without installation (tests run via
# `PYTHONPATH=src pytest tests/`; this is belt-and-braces for bare pytest).
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.abspath(_SRC) not in [os.path.abspath(p) for p in sys.path]:
    sys.path.insert(0, os.path.abspath(_SRC))

# hypothesis is an optional [test] extra (unavailable in the offline CI
# container): property-based tests live in test_properties.py behind
# pytest.importorskip; everything else must run without it.
try:
    from hypothesis import settings
except ImportError:
    settings = None

if settings is not None:
    # CPU-only container: generous deadlines, few examples (jit compile cost).
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
