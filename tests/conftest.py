import os
import sys

# Make `import repro` work without installation (tests run via
# `PYTHONPATH=src pytest tests/`; this is belt-and-braces for bare pytest).
# The repo root rides along so `import benchmarks.*` resolves for the bench
# smoke tests (the Makefile targets use PYTHONPATH=src:. the same way).
_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in [os.path.abspath(p) for p in sys.path]:
        sys.path.insert(0, _p)

# hypothesis is an optional [test] extra (unavailable in the offline CI
# container): property-based tests live in test_properties.py behind
# pytest.importorskip; everything else must run without it.
try:
    from hypothesis import settings
except ImportError:
    settings = None

if settings is not None:
    # CPU-only container: generous deadlines, few examples (jit compile cost).
    settings.register_profile("ci", max_examples=25, deadline=None)
    # The dedicated CI property lane (make test-property): fixed example
    # stream (derandomize) so failures are reproducible across runs, no
    # deadline (interpret-mode kernels + fresh jit traces are slow), and
    # enough examples to walk the dtype x level x corpus grid.
    settings.register_profile(
        "ci-property",
        max_examples=40,
        deadline=None,
        derandomize=True,
        print_blob=True,
    )
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
