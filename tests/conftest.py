import os
import sys

# Make `import repro` work without installation (tests run via
# `PYTHONPATH=src pytest tests/`; this is belt-and-braces for bare pytest).
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.abspath(_SRC) not in [os.path.abspath(p) for p in sys.path]:
    sys.path.insert(0, os.path.abspath(_SRC))

from hypothesis import settings

# CPU-only container: generous deadlines, few examples (jit compile cost).
settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
