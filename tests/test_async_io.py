"""Crash-consistency, fault-injection and concurrency-stress harness for
the async double-buffered checkpoint/KV write path (runtime/async_io.py).

Contracts under test:
  * crash consistency — a simulated process death at EVERY write boundary
    of a save (blob files, manifest, commit marker, the rename itself)
    never yields a restorable-but-corrupt checkpoint: ``steps()`` omits
    the partial step and ``restore_latest`` returns the previous step
    bit-exactly, in both sync and async modes;
  * fault injection — transient EIO retries under the bounded,
    deterministic ``RetryPolicy``; ENOSPC surfaces as a clean
    ``AsyncWriteError`` (a ``RuntimeError``) naming the step and path on
    the next ``save()``/``wait_until_finished()``, never a silent drop;
  * concurrency stress — saves racing GC and a concurrent
    ``restore_latest`` never deadlock and never observe a torn step;
    async-on and sync-on write byte-identical checkpoint directories;
    the engine's async prefetch worker keeps paged decode bit-identical.

Run via ``make test-async`` (CI lane: pytest-timeout + faulthandler so a
deadlock dumps stacks and fails instead of hanging).
"""

import errno
import filecmp
import os
import threading
import time

import numpy as np
import jax
import pytest

from repro.checkpoint.manager import COMMIT_MARKER, CheckpointManager
from repro.runtime.async_io import (
    AsyncBlobWriter,
    AsyncWriteError,
    RetryPolicy,
)
from repro.runtime.fault import (
    FaultSpec,
    FaultyFS,
    HostFS,
    SimulatedCrash,
    StepGuard,
)

FAST_RETRY = RetryPolicy(max_attempts=3, backoff_s=0.001)


def _state(salt: int = 0):
    """Small mixed tree: one compressible f32 leaf (>=1KiB -> .gplz), one
    tiny raw leaf, one scalar."""
    rng = np.random.default_rng(7)
    return {
        "w": (rng.standard_normal((40, 40)) + salt).astype(np.float32),
        "b": np.arange(8, dtype=np.int32) + salt,
        "step": np.int32(salt),
    }


def _template(state):
    return jax.eval_shape(lambda: state)


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_dirs_identical(d1, d2):
    cmp = filecmp.dircmp(d1, d2)
    assert not cmp.left_only and not cmp.right_only, (
        cmp.left_only,
        cmp.right_only,
    )
    match, mismatch, errors = filecmp.cmpfiles(
        d1, d2, cmp.common_files, shallow=False
    )
    assert not mismatch and not errors, (mismatch, errors)
    for sub in cmp.common_dirs:
        _assert_dirs_identical(os.path.join(d1, sub), os.path.join(d2, sub))


# ------------------------------------------------------------ writer units


def test_writer_preserves_op_order(tmp_path):
    order = []

    class SpyFS(HostFS):
        def write_bytes(self, path, data):
            order.append(os.path.basename(path))
            super().write_bytes(path, data)

    w = AsyncBlobWriter(fs=SpyFS())
    w.begin_step(1)
    for name in ("a", "b", "manifest.json", COMMIT_MARKER):
        w.put_write(1, str(tmp_path / name), b"x")
    w.wait_until_finished()
    w.close()
    assert order == ["a", "b", "manifest.json", COMMIT_MARKER]


def test_writer_backpressure_bounds_inflight_steps(tmp_path):
    fs = FaultyFS(
        faults=[FaultSpec(op="write", mode="delay", delay_s=0.05, count=10**9)]
    )
    w = AsyncBlobWriter(fs=fs, max_pending_steps=2)
    for label in (1, 2):
        tmp = tmp_path / f"s{label}.tmp"
        tmp.mkdir()
        blocked = w.begin_step(label)
        assert blocked < 0.04  # window not full: no backpressure yet
        w.put_write(label, str(tmp / "blob"), b"z" * 8)
        w.put_commit(label, str(tmp), str(tmp_path / f"d{label}"))
    # third step must wait for a slot: the double-buffer bound
    t0 = time.monotonic()
    blocked = w.begin_step(3)
    assert blocked > 0.01
    assert time.monotonic() - t0 >= blocked
    assert w.stats()["blocked_s"] >= blocked
    w.wait_until_finished()
    w.close()


def test_retry_policy_deterministic_attempts():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError(errno.EIO, "io")
        return "ok"

    assert FAST_RETRY.run(flaky) == "ok"
    assert len(calls) == 3  # 2 transient failures + 1 success, bounded

    calls.clear()

    def dead():
        calls.append(1)
        raise OSError(errno.EIO, "io")

    with pytest.raises(OSError):
        FAST_RETRY.run(dead)
    assert len(calls) == FAST_RETRY.max_attempts


def test_retry_policy_never_retries_enospc():
    calls = []

    def full():
        calls.append(1)
        raise OSError(errno.ENOSPC, "disk full")

    with pytest.raises(OSError):
        FAST_RETRY.run(full)
    assert len(calls) == 1  # a full disk does not heal by waiting


def test_faultyfs_is_deterministic(tmp_path):
    def run(seed):
        fs = FaultyFS(
            faults=[FaultSpec(op="write", probability=0.3, count=10**9)],
            seed=seed,
        )
        outcomes = []
        for i in range(20):
            try:
                fs.write_bytes(str(tmp_path / f"f{i}"), b"x")
                outcomes.append("ok")
            except OSError:
                outcomes.append("err")
        return outcomes

    assert run(3) == run(3)  # same seed -> same fault sequence
    assert "err" in run(3) and "ok" in run(3)


# ---------------------------------------------------- crash consistency


def _boundary_ops(tmp_path, async_writes):
    """Enumerate the write boundaries of one save by logging a clean run."""
    fs = FaultyFS()
    mgr = CheckpointManager(
        str(tmp_path), compress=True, keep=5, fs=fs,
        async_writes=async_writes, io_retry=FAST_RETRY,
    )
    mgr.save(_state(1), 1)
    mgr.save(_state(2), 2)
    mgr.wait_until_finished()
    # keep EVERY instrumented op touching the step so the count lines up
    # exactly with the replay spec's matching-call counter (op="*")
    ops = [(op, p) for op, p in fs.log if "step_00000002" in p]
    assert any(op == "rename" for op, _ in ops)
    assert any(COMMIT_MARKER in p for _, p in ops)
    return len(ops)


@pytest.mark.parametrize("async_writes", [False, True])
def test_crash_at_every_write_boundary(tmp_path, async_writes):
    """Injected abort at each boundary of step 2's save: step 1 must stay
    the restorable latest, bit-exact; step 2 must never be listed."""
    n_ops = _boundary_ops(tmp_path / "clean", async_writes)
    assert n_ops >= 5  # makedirs + blobs + manifest + marker + rename
    for nth in range(1, n_ops + 1):
        d = tmp_path / f"crash_{int(async_writes)}_{nth}"
        fs = FaultyFS(faults=[FaultSpec(
            op="*", nth=nth, mode="crash", partial=0.5,
            path_substr="step_00000002",
        )])
        mgr = CheckpointManager(
            str(d), compress=True, keep=5, fs=fs,
            async_writes=async_writes, io_retry=FAST_RETRY,
        )
        mgr.save(_state(1), 1)
        mgr.wait_until_finished()
        with pytest.raises(SimulatedCrash):
            # async surfaces the crash at the wait barrier; sync raises
            # from save() itself — either way it must escape untouched
            mgr.save(_state(2), 2)
            mgr.wait_until_finished()
        assert fs.faults[0].hits == 1
        # reader-side view after the "reboot": fresh manager, healthy fs
        reborn = CheckpointManager(str(d), compress=True, keep=5)
        assert reborn.steps() == [1]
        restored, step = reborn.restore_latest(_template(_state(1)))
        assert step == 1
        _assert_tree_equal(restored, _state(1))


def test_crashed_async_step_is_partial_on_disk(tmp_path):
    """The crash really does tear the file: partial bytes, no marker, no
    published dir — the boundary sweep is not vacuous."""
    fs = FaultyFS(faults=[FaultSpec(
        op="write", nth=3, mode="crash", partial=0.5,
        path_substr="step_00000002",
    )])
    mgr = CheckpointManager(
        str(tmp_path), compress=True, keep=5, fs=fs,
        async_writes=True, io_retry=FAST_RETRY,
    )
    mgr.save(_state(1), 1)
    mgr.wait_until_finished()
    with pytest.raises(SimulatedCrash):
        mgr.save(_state(2), 2)
        mgr.wait_until_finished()
    leftover = tmp_path / "step_00000002.tmp"
    assert leftover.is_dir()  # never renamed
    assert not (leftover / COMMIT_MARKER).exists()


# ------------------------------------------------------- fault injection


def test_transient_eio_retries_then_succeeds(tmp_path):
    spec = FaultSpec(op="write", nth=1, count=2, error=errno.EIO,
                     path_substr="step_00000001")
    fs = FaultyFS(faults=[spec])
    mgr = CheckpointManager(
        str(tmp_path), compress=True, fs=fs,
        async_writes=True, io_retry=FAST_RETRY,
    )
    mgr.save(_state(1), 1)
    mgr.wait_until_finished()  # both transient hits absorbed by retry
    assert spec.hits == 2
    restored, step = mgr.restore_latest(_template(_state(1)))
    assert step == 1
    _assert_tree_equal(restored, _state(1))


def test_exhausted_retries_fail_the_step(tmp_path):
    spec = FaultSpec(op="write", nth=1, count=FAST_RETRY.max_attempts,
                     error=errno.EIO, path_substr="step_00000002")
    fs = FaultyFS(faults=[spec])
    mgr = CheckpointManager(
        str(tmp_path), compress=True, fs=fs,
        async_writes=True, io_retry=FAST_RETRY,
    )
    mgr.save(_state(1), 1)
    mgr.save(_state(2), 2)
    with pytest.raises(AsyncWriteError):
        mgr.wait_until_finished()
    assert spec.hits == FAST_RETRY.max_attempts
    assert mgr.steps() == [1]


def test_enospc_surfaces_on_next_save_naming_step_and_path(tmp_path):
    fs = FaultyFS(faults=[FaultSpec(
        op="write", nth=1, count=10**9, error=errno.ENOSPC,
        path_substr="step_00000002",
    )])
    mgr = CheckpointManager(
        str(tmp_path), compress=True, fs=fs,
        async_writes=True, io_retry=FAST_RETRY,
    )
    mgr.save(_state(1), 1)
    mgr.save(_state(2), 2)  # fails in the background — no raise here
    with pytest.raises(AsyncWriteError) as exc_info:
        for _ in range(5):  # surfaced on the NEXT save, not silently dropped
            mgr.save(_state(3), 3)
            mgr.wait_until_finished()
        pytest.fail("background ENOSPC never surfaced")
    msg = str(exc_info.value)
    assert "step 2" in msg and "step_00000002" in msg
    assert isinstance(exc_info.value, RuntimeError)
    # the error was surfaced once and cleared: the writer keeps working
    mgr.save(_state(3), 3)
    mgr.wait_until_finished()
    assert mgr.steps() == [1, 3]
    restored, step = mgr.restore_latest(_template(_state(3)))
    assert step == 3
    _assert_tree_equal(restored, _state(3))


def test_failed_step_never_blocks_later_saves(tmp_path):
    """A dead step's tmp dir is swept by GC once nothing owns it."""
    fs = FaultyFS(faults=[FaultSpec(
        op="write", nth=1, count=10**9, error=errno.ENOSPC,
        path_substr="step_00000002",
    )])
    mgr = CheckpointManager(
        str(tmp_path), compress=True, keep=2, fs=fs,
        async_writes=True, io_retry=FAST_RETRY,
    )
    for s in (1, 2, 3, 4, 5):
        try:
            mgr.save(_state(s), s)
        except AsyncWriteError:
            pass
    try:
        mgr.wait_until_finished()
    except AsyncWriteError:
        pass
    assert mgr.steps() == [4, 5]
    assert not (tmp_path / "step_00000002").exists()
    assert not (tmp_path / "step_00000002.tmp").exists()


# ------------------------------------------------------------ GC contract


def test_gc_ignores_and_sweeps_markerless_dir(tmp_path):
    """Regression for the latent _gc race: a step dir without its commit
    marker (hand-planted here, a torn publish in the wild) is never
    listed, never restored, never counts toward retention — and is swept
    as debris by the next GC."""
    mgr = CheckpointManager(str(tmp_path), compress=True, keep=2)
    mgr.save(_state(1), 1)
    mgr.save(_state(2), 2)
    # hand-plant a marker-less (= uncommitted) step dir newer than both
    fake = tmp_path / "step_00000005"
    fake.mkdir()
    (fake / "manifest.json").write_text("{\"step\": 5, \"leaves\": []}")
    assert mgr.steps() == [1, 2]  # never listed
    restored, step = mgr.restore_latest(_template(_state(2)))
    assert step == 2  # never restored
    _assert_tree_equal(restored, _state(2))
    mgr.save(_state(3), 3)  # keep=2 -> GC runs
    # the markerless dir neither blocked GC of step 1 nor survived it,
    # and it never consumed a retention slot
    assert mgr.steps() == [2, 3]
    assert not fake.exists()


def test_gc_never_deletes_inflight_async_step(tmp_path):
    """Saves outpacing a slow disk: GC (running per commit on the worker)
    must never touch a registered-but-uncommitted step, and retention must
    converge once the writer drains."""
    fs = FaultyFS(faults=[FaultSpec(
        op="write", mode="delay", delay_s=0.01, count=10**9,
    )])
    mgr = CheckpointManager(
        str(tmp_path), compress=True, keep=2, fs=fs,
        async_writes=True, io_retry=FAST_RETRY, io_max_pending=2,
    )
    for s in (1, 2, 3, 4):
        mgr.save(_state(s), s)
    mgr.wait_until_finished()
    assert mgr.steps() == [3, 4]
    restored, step = mgr.restore_latest(_template(_state(4)))
    assert step == 4
    _assert_tree_equal(restored, _state(4))
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


# ------------------------------------------------- async/sync equivalence


def test_async_and_sync_checkpoints_byte_identical(tmp_path):
    """Same state, same config: async-on and sync-on must produce
    byte-identical checkpoint directories (same files, same bytes)."""
    sync_dir, async_dir = tmp_path / "sync", tmp_path / "async"
    ms = CheckpointManager(str(sync_dir), compress=True, keep=3)
    ma = CheckpointManager(
        str(async_dir), compress=True, keep=3, async_writes=True
    )
    for s in (1, 2):
        ms.save(_state(s), s)
        ma.save(_state(s), s)
    ma.wait_until_finished()
    assert ms.steps() == ma.steps() == [1, 2]
    for s in (1, 2):
        _assert_dirs_identical(
            str(sync_dir / f"step_{s:08d}"),
            str(async_dir / f"step_{s:08d}"),
        )


# ------------------------------------------------------------- StepGuard


def test_stepguard_accounts_io_backpressure_separately():
    g = StepGuard(threshold=3.0, max_consecutive_slow=2)
    for i in range(5):
        g.observe(i, 0.10)
    # a huge writer stall is an io_stall, NOT a compute straggler
    slow = g.observe(5, 0.10, io_wait_s=1.0)
    assert not slow
    assert g.stats.io_stalls == 1
    assert g.stats.io_wait_steps == 1
    assert g.stats.io_wait_s == pytest.approx(1.0)
    assert not g.should_restart
    # compute EWMA untouched by io waits: a genuinely slow step still flags
    assert g.observe(6, 1.0) is True


def test_stepguard_heartbeat_carries_io_fields(tmp_path):
    hb = tmp_path / "hb.json"
    g = StepGuard(heartbeat_path=str(hb))
    g.observe(0, 0.05, io_wait_s=0.02)
    import json

    data = json.loads(hb.read_text())
    assert data["io_wait_s"] == pytest.approx(0.02)
    assert "io_stalls" in data


# ------------------------------------------------------------ stress lane


@pytest.mark.stress
@pytest.mark.timeout(300)
def test_saves_race_gc_and_concurrent_restore(tmp_path):
    """N async saves racing worker-side GC while a reader thread hammers
    restore_latest: every observed restore is a committed step restored
    bit-exactly, and nothing deadlocks (pytest-timeout is the net)."""
    fs = FaultyFS(faults=[FaultSpec(
        op="write", mode="delay", delay_s=0.002, count=10**9,
    )])
    mgr = CheckpointManager(
        str(tmp_path), compress=True, keep=2, fs=fs,
        async_writes=True, io_retry=FAST_RETRY,
    )
    template = _template(_state(0))
    stop = threading.Event()
    seen, errors = [], []

    def reader():
        while not stop.is_set():
            try:
                restored, step = mgr.restore_latest(template)
                if step >= 0:
                    seen.append(step)
                    _assert_tree_equal(restored, _state(step))
            except Exception as exc:  # noqa: BLE001 - collected for assert
                errors.append(exc)

    th = threading.Thread(target=reader, daemon=True)
    th.start()
    n = 8
    for s in range(1, n + 1):
        mgr.save(_state(s), s)
    mgr.wait_until_finished()
    stop.set()
    th.join(timeout=60)
    assert not th.is_alive()
    assert not errors, errors
    assert mgr.steps() == [n - 1, n]
    restored, step = mgr.restore_latest(template)
    assert step == n
    _assert_tree_equal(restored, _state(n))
    # steps observed mid-race were all committed ones, in save order
    assert all(e >= 0 for e in seen)
    assert seen == sorted(seen)


@pytest.mark.stress
@pytest.mark.timeout(300)
def test_writer_survives_seeded_chaos(tmp_path):
    """Seeded random EIO chaos under retry: either a save round completes
    and restores bit-exactly, or the failure surfaces as AsyncWriteError —
    never a hang, never a torn restorable step."""
    fs = FaultyFS(
        faults=[FaultSpec(op="write", probability=0.10, error=errno.EIO,
                          count=10**9)],
        seed=11,
    )
    mgr = CheckpointManager(
        str(tmp_path), compress=True, keep=3, fs=fs,
        async_writes=True,
        io_retry=RetryPolicy(max_attempts=4, backoff_s=0.0005),
    )
    failures = 0
    for s in range(1, 9):
        try:
            mgr.save(_state(s), s)
        except AsyncWriteError:
            failures += 1
    try:
        mgr.wait_until_finished()
    except AsyncWriteError:
        failures += 1
    committed = mgr.steps()
    assert committed, "chaos must not wipe out every step"
    restored, step = mgr.restore_latest(_template(_state(0)))
    assert step == committed[-1]
    _assert_tree_equal(restored, _state(step))


# -------------------------------------------- engine async prefetch (KV)


@pytest.fixture(scope="module")
def llama():
    from repro import configs
    from repro.models import model as model_lib

    cfg = configs.reduced_config(configs.get_config("llama3.2-1b"))
    return cfg, model_lib.init_params(cfg, 0)


@pytest.mark.stress
@pytest.mark.timeout(600)
def test_engine_async_prefetch_bit_identical(llama):
    """Paged decode with the background prefetch/restore worker stays
    bit-identical to BOTH the dense engine and the sync prefetch path
    under real eviction pressure, and the worker actually ran."""
    from repro.serving.engine import ServingEngine

    cfg, params = llama
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, 256, (2, 8)).astype(np.int32)
    tight = dict(kv_offload=True, block_tokens=8, budget_blocks=8,
                 kv_compress=True, max_len=64)
    dense = ServingEngine(cfg, params, max_len=64)
    dense_toks = dense.generate(prompts, max_new_tokens=12).tokens
    sync_eng = ServingEngine(cfg, params, **tight)
    sync_toks = sync_eng.generate(prompts, max_new_tokens=12).tokens
    async_eng = ServingEngine(cfg, params, async_prefetch=True, **tight)
    async_toks = async_eng.generate(prompts, max_new_tokens=12).tokens
    np.testing.assert_array_equal(dense_toks, sync_toks)
    np.testing.assert_array_equal(dense_toks, async_toks)
    stats = async_eng.paging_stats()
    assert stats["async_prefetch"] is True
    assert stats["async_prefetch_batches"] > 0
    assert stats["prefetch_hits"] == sync_eng.paging_stats()["prefetch_hits"]
