"""Smoke-exercise the benchmark sweep entry points at tiny sizes.

`make bench-smoke` runs the full CLI drivers; these tests call the sweep
functions directly so the suite catches API drift (renamed config fields,
registry keys, JSON schema) without paying interpret-mode compile costs for
the fused *compressor* (the fused decoder is cheap enough to include).
"""

import json

import numpy as np
import pytest

fig9 = pytest.importorskip("benchmarks.fig9_throughput")
fig10 = pytest.importorskip("benchmarks.fig10_decode")


def _tiny_corpus(nbytes=4096):
    rng = np.random.default_rng(0)
    half = np.repeat(rng.integers(0, 9, nbytes // 4), 2).astype(np.uint16)
    return half.view(np.uint8).reshape(-1)[:nbytes]


def test_fig9_backend_sweep_smoke(tmp_path):
    out = tmp_path / "BENCH_pipeline.json"
    rec = fig9.backend_sweep(
        _tiny_corpus(), backends=("xla",), sweep_nbytes=2048,
        out_json=str(out), dataset="smoke",
    )
    assert out.exists()
    disk = json.loads(out.read_text())
    assert disk["benchmark"] == rec["benchmark"] == "fig9_backend_sweep"
    assert "xla" in disk["backends"]
    assert disk["backends"]["xla"]["seconds_per_call"] > 0


def test_fig10_decoder_sweep_smoke(tmp_path):
    out = tmp_path / "BENCH_decode.json"
    rec = fig10.decoder_sweep(
        _tiny_corpus(), decoders=("xla-parallel", "fused"),
        sweep_nbytes=2048, out_json=str(out), dataset="smoke",
    )
    assert out.exists()
    disk = json.loads(out.read_text())
    assert disk["benchmark"] == rec["benchmark"] == "fig10_decoder_sweep"
    assert {"xla-parallel", "fused"} <= set(disk["decoders"])
    assert "fused_over_xla_parallel" in disk
    for entry in disk["decoders"].values():
        assert entry["gb_per_s"] > 0
