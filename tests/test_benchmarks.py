"""Smoke-exercise the benchmark sweep entry points at tiny sizes, and guard
the tracked benchmark artifacts.

`make bench-smoke` runs the full CLI drivers; the smoke tests call the sweep
functions directly so the suite catches API drift (renamed config fields,
registry keys, JSON schema) without paying interpret-mode compile costs for
the fused *compressor* (the fused decoder is cheap enough to include).

The `*_artifact_schema` tests (also reachable via `make check-bench`)
validate the *committed* BENCH_pipeline.json / BENCH_decode.json at the repo
root: a smoke-size run accidentally written there (instead of /tmp, where
`make bench-smoke` points) fails CI instead of silently clobbering the perf
record.
"""

import json
import pathlib

import numpy as np
import pytest

fig8 = pytest.importorskip("benchmarks.fig8_ratio")
fig9 = pytest.importorskip("benchmarks.fig9_throughput")
fig10 = pytest.importorskip("benchmarks.fig10_decode")
fig_lossy = pytest.importorskip("benchmarks.fig_lossy")


def _lossless(keys):
    """The registry keys the lossless sweeps cover (the method-2 lossy-fz
    pair has its own bound-axis sweep: fig_lossy.py / BENCH_lossy.json)."""
    from repro.core import format as fmt, pipeline

    return {k for k in keys if pipeline.container_method(k) != fmt.METHOD_LOSSY}

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# The tracked perf records are measured on >= 64 KiB corpus slices; the
# bench-smoke targets use 8 KiB sweeps.  Anything below this floor at the
# repo root is a smoke artifact that escaped /tmp.
MIN_TRACKED_SWEEP_NBYTES = 1 << 16

# Same idea for the paged-KV sweep: the tracked BENCH_kv.json decodes a
# real horizon; bench-kv-smoke runs a dozen tokens and points at /tmp.
MIN_TRACKED_KV_NEW_TOKENS = 32
MIN_TRACKED_KV_BATCH = 4


def _tiny_corpus(nbytes=4096):
    rng = np.random.default_rng(0)
    half = np.repeat(rng.integers(0, 9, nbytes // 4), 2).astype(np.uint16)
    return half.view(np.uint8).reshape(-1)[:nbytes]


def test_fig9_backend_sweep_smoke(tmp_path):
    out = tmp_path / "BENCH_pipeline.json"
    rec = fig9.backend_sweep(
        _tiny_corpus(), backends=("xla",), sweep_nbytes=2048,
        out_json=str(out), dataset="smoke",
    )
    assert out.exists()
    disk = json.loads(out.read_text())
    assert disk["benchmark"] == rec["benchmark"] == "fig9_backend_sweep"
    assert "xla" in disk["backends"]
    assert disk["backends"]["xla"]["seconds_per_call"] > 0


def test_fig8_ratio_sweep_smoke(tmp_path):
    out = tmp_path / "BENCH_ratio.json"
    rec = fig8.ratio_sweep(
        _tiny_corpus(), backends=("xla", "fused-mono", "deflate-full"),
        sweep_nbytes=2048, out_json=str(out), dataset="smoke",
    )
    assert out.exists()
    disk = json.loads(out.read_text())
    assert disk["benchmark"] == rec["benchmark"] == "fig8_ratio_sweep"
    assert {"xla", "fused-mono", "deflate-full"} <= set(disk["backends"])
    for entry in disk["backends"].values():
        assert entry["ratio"] > 0
        assert entry["total_bytes"] > 0
    # generic gain keys: one per non-baseline backend in the sweep
    assert "xla_over_fused_mono" in disk
    assert "deflate_full_over_fused_mono" in disk
    # raw backends emit byte-identical containers, so their gain is exactly 1
    assert disk["xla_over_fused_mono"] == pytest.approx(1.0)


def test_fig10_decoder_sweep_smoke(tmp_path):
    out = tmp_path / "BENCH_decode.json"
    rec = fig10.decoder_sweep(
        _tiny_corpus(),
        decoders=("xla-parallel", "fused", "fused-mono", "deflate-full"),
        sweep_nbytes=2048, out_json=str(out), dataset="smoke",
    )
    assert out.exists()
    disk = json.loads(out.read_text())
    assert disk["benchmark"] == rec["benchmark"] == "fig10_decoder_sweep"
    assert {"xla-parallel", "fused", "fused-mono", "deflate-full"} <= set(
        disk["decoders"]
    )
    # generic speedup keys: one per non-baseline decoder in the sweep
    assert "fused_over_xla_parallel" in disk
    assert "fused_mono_over_xla_parallel" in disk
    for entry in disk["decoders"].values():
        assert entry["gb_per_s"] > 0


# --------------------------- tracked-artifact guards (make check-bench)


def _tracked(name):
    path = REPO_ROOT / name
    assert path.exists(), f"tracked perf record {name} missing from repo root"
    return json.loads(path.read_text())


def _check_timing_entry(name, entry):
    assert entry["seconds_per_call"] > 0, name
    assert entry["gb_per_s"] > 0, name
    assert entry["nbytes"] >= MIN_TRACKED_SWEEP_NBYTES, (
        f"{name}: nbytes={entry['nbytes']} looks like a bench-smoke run "
        f"written to the repo root (smoke artifacts belong in /tmp; see "
        f"the Makefile bench-smoke target)"
    )


def test_bench_pipeline_artifact_schema():
    rec = _tracked("BENCH_pipeline.json")
    assert rec["benchmark"] == "fig9_backend_sweep"
    assert isinstance(rec["platform"], str)
    assert isinstance(rec["interpret_mode"], bool)
    assert {"xla", "fused", "fused-deflate", "fused-mono"} <= set(
        rec["backends"]
    )
    for name, entry in rec["backends"].items():
        _check_timing_entry(f"backends[{name}]", entry)
    assert rec["fused_over_xla"] > 0
    assert rec["fused_deflate_over_xla"] > 0
    assert rec["fused_mono_over_xla"] > 0


def test_bench_decode_artifact_schema():
    from repro.core import lzss

    rec = _tracked("BENCH_decode.json")
    assert rec["benchmark"] == "fig10_decoder_sweep"
    assert isinstance(rec["platform"], str)
    assert isinstance(rec["interpret_mode"], bool)
    assert rec["ratio"] > 1  # the sweep corpus actually compresses
    # one entry per registered lossless decoder: a decoder added to the
    # registry but missing from the tracked sweep means BENCH_decode.json
    # went stale (>= not ==: test-registered custom decoders may come and go)
    assert set(rec["decoders"]) >= _lossless(lzss.available_decoders()), (
        "BENCH_decode.json is missing registered decoders; regenerate via "
        "benchmarks/fig10_decode.py (default --decoders all)"
    )
    for name, entry in rec["decoders"].items():
        _check_timing_entry(f"decoders[{name}]", entry)
    for name in rec["decoders"]:
        if name != fig10.BASELINE:
            assert rec[fig10.ratio_key(name)] > 0, name
    assert rec["fused_over_xla_parallel"] > 0
    assert rec["fused_mono_over_xla_parallel"] > 0


def test_bench_ratio_artifact_schema():
    from repro.core import lzss

    rec = _tracked("BENCH_ratio.json")
    assert rec["benchmark"] == "fig8_ratio_sweep"
    assert isinstance(rec["platform"], str)
    assert isinstance(rec["interpret_mode"], bool)
    # one entry per registered lossless backend: a backend added to the
    # registry but missing from the tracked sweep means BENCH_ratio.json
    # went stale (>= not ==: test-registered custom backends come and go)
    assert set(rec["backends"]) >= _lossless(lzss.available_backends()), (
        "BENCH_ratio.json is missing registered backends; regenerate via "
        "benchmarks/fig8_ratio.py (default --backends all)"
    )
    for name, entry in rec["backends"].items():
        assert entry["ratio"] > 1, f"backends[{name}]: corpus must compress"
        assert 0 < entry["total_bytes"] <= entry["orig_bytes"] * 2, name
        assert entry["nbytes"] >= MIN_TRACKED_SWEEP_NBYTES, (
            f"backends[{name}]: nbytes={entry['nbytes']} looks like a "
            f"bench-smoke run written to the repo root (smoke artifacts "
            f"belong in /tmp; see the Makefile bench-ratio-smoke target)"
        )
    # the headline the sweep exists for: the canonical-Huffman second stage
    # must strictly beat the LZSS-only container on the tracked corpus
    assert rec[fig8.ratio_key("deflate-full")] > 1, (
        "deflate-full ratio regressed to (or below) the LZSS-only baseline"
    )


def test_fig_lossy_sweep_smoke(tmp_path):
    rng = np.random.default_rng(1)
    f32 = np.cumsum(rng.normal(size=2048).astype(np.float32)) * 0.01
    out = tmp_path / "BENCH_lossy.json"
    rec = fig_lossy.lossy_sweep(
        f32.view(np.uint8), ebs=(1e-3, 0.0), sweep_nbytes=4096,
        out_json=str(out), dataset="smoke",
    )
    assert out.exists()
    disk = json.loads(out.read_text())
    assert disk["benchmark"] == rec["benchmark"] == "fig_lossy_sweep"
    assert set(disk["ebs"]) == {"0.001", "0"}
    assert disk["ebs"]["0"]["max_abs_err"] == 0.0
    assert disk["eb_0.001_over_lossless"] > 0


def test_bench_lossy_artifact_schema():
    """The tracked BENCH_lossy.json: every row certified within its bound
    (the sweep asserts before writing; this guards the committed record),
    measured on a real (non-smoke) slice, bit-exact reference row present."""
    rec = _tracked("BENCH_lossy.json")
    assert rec["benchmark"] == "fig_lossy_sweep"
    assert isinstance(rec["platform"], str)
    assert isinstance(rec["interpret_mode"], bool)
    rows = rec["ebs"]
    assert len(rows) >= 3, "sweep must cover several bounds"
    assert "0" in rows, "the bit-exact eb=0 reference row is required"
    for key, entry in rows.items():
        assert entry["bound_ok"] is True, key
        assert entry["ratio"] > 0 and entry["total_bytes"] > 0, key
        assert entry["compress_seconds_per_call"] > 0, key
        assert entry["decode_seconds_per_call"] > 0, key
        if entry["eb"] == 0.0:
            assert entry["max_abs_err"] == 0.0
        else:
            assert entry["max_abs_err"] <= np.float32(entry["eb"]), key
        assert entry["nbytes"] >= MIN_TRACKED_SWEEP_NBYTES, (
            f"ebs[{key}]: nbytes={entry['nbytes']} looks like a "
            f"bench-lossy-smoke run written to the repo root (smoke "
            f"artifacts belong in /tmp; see the Makefile bench-lossy-smoke "
            f"target)"
        )
    # the point of the frontend: a loosened bound must buy ratio over the
    # bit-exact reference on the tracked corpus
    loosest = max(
        (e for e in rows.values() if e["eb"] > 0), key=lambda e: e["eb"]
    )
    assert loosest["ratio"] > rows["0"]["ratio"], (
        "lossy ratio at the loosest bound regressed to (or below) the "
        "bit-exact reference"
    )


def test_bench_kv_artifact_schema():
    rec = _tracked("BENCH_kv.json")
    assert rec["benchmark"] == "kv_paging_sweep"
    assert isinstance(rec["platform"], str)
    assert isinstance(rec["interpret_mode"], bool)
    assert rec["new_tokens"] >= MIN_TRACKED_KV_NEW_TOKENS, (
        f"new_tokens={rec['new_tokens']} looks like a bench-kv-smoke run "
        f"written to the repo root (smoke artifacts belong in /tmp; see "
        f"the Makefile bench-kv-smoke target)"
    )
    assert rec["batch"] >= MIN_TRACKED_KV_BATCH
    assert rec["working_set_blocks"] > rec["peak_layer_blocks"] > 0
    assert rec["dense"]["tokens_per_s"] > 0
    budgets = rec["budgets"]
    assert len(budgets) >= 3, "sweep must cover several resident budgets"
    # the sweep must include real capacity pressure (budget < working set,
    # so eviction+restore actually ran) ...
    tight = [e for e in budgets
             if e["budget_blocks"] < rec["working_set_blocks"]]
    assert tight, "no budget below the working set: paging never exercised"
    for e in tight:
        assert e["evictions"] > 0 and e["restores"] > 0
        assert e["eviction_ratio"] > 0
        # batched dispatch: rounds, not one jit call per block
        assert e["eviction_dispatches"] <= e["evictions"]
        assert e["restore_dispatches"] <= e["restores"]
    # ... and every point must have stayed bit-identical to the dense cache
    for e in budgets:
        assert e["exact"] is True, f"budget={e['budget_blocks']} diverged"
        assert e["tokens_per_s"] > 0
        assert 0 < e["high_water"] <= e["budget_blocks"], (
            f"budget={e['budget_blocks']}: allocator exceeded the budget"
        )
        assert e["prefetch_hits"] <= e["prefetch_issued"]


def test_kv_paging_sweep_smoke(tmp_path):
    kv_paging = pytest.importorskip("benchmarks.kv_paging")
    out = tmp_path / "BENCH_kv.json"
    rec = kv_paging.paging_sweep(
        budgets=[4], batch=2, max_len=16, block_tokens=8, prompt_tokens=4,
        new_tokens=6, out_json=str(out),
    )
    assert out.exists()
    disk = json.loads(out.read_text())
    assert disk["benchmark"] == rec["benchmark"] == "kv_paging_sweep"
    (entry,) = disk["budgets"]
    assert entry["exact"] is True
    assert entry["evictions"] > 0  # budget 4 < working set 8: real pressure


def test_autotune_cache_artifact_schema(tmp_path):
    """The autotune cache validator rides check-bench with the other
    artifact guards: a schema drift that would silently invalidate every
    persisted tuning entry (or crash loads) fails here first."""
    from repro.core import autotune

    # a cache produced by the real writer must validate
    entry = {
        "chunk_symbols": 2048,
        "chunks_per_block": 8,
        "seconds_per_call": 1e-3,
        "device_kind": "cpu",
        "direction": "decompress",
        "swept": 3,
    }
    good = {"version": autotune.CACHE_VERSION, "entries": {"k": entry}}
    autotune.validate_cache(good)
    # and the validator actually rejects, not rubber-stamps
    for bad in (
        [],
        {"version": 999, "entries": {}},
        {"version": autotune.CACHE_VERSION, "entries": []},
        {
            "version": autotune.CACHE_VERSION,
            "entries": {"k": dict(entry, chunks_per_block=0)},
        },
        {
            "version": autotune.CACHE_VERSION,
            "entries": {"k": dict(entry, seconds_per_call=-1)},
        },
    ):
        with pytest.raises(ValueError):
            autotune.validate_cache(bad)
    # a corrupted on-disk file is recovered from, never trusted or fatal
    p = tmp_path / "autotune.json"
    p.write_text("{broken json")
    assert autotune._load_cache(str(p))["entries"] == {}
