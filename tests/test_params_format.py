"""Parameter selector (paper §3.2.3) + container format invariants."""

import numpy as np
import pytest

from repro.core import format as fmt, lzss
from repro.core.params import ParamSelector, dtype_symbol_size, select_params


def test_dtype_symbol_size():
    assert dtype_symbol_size(np.uint16) == 2
    assert dtype_symbol_size(np.float32) == 4
    assert dtype_symbol_size(np.uint8) == 1
    assert dtype_symbol_size(np.float64) == 4  # falls back to 4


def test_selector_keeps_multibyte_on_compressible():
    rng = np.random.default_rng(0)
    data = np.repeat(rng.integers(0, 8, 2000), 8).astype(np.uint16)
    sel = ParamSelector(dtype=np.uint16, level=3)
    sel.observe(data)
    assert sel.mean_ratio > 1.5
    assert sel.current_config().symbol_size == 2  # stays multi-byte


def test_selector_falls_back_to_bytes_on_noise():
    rng = np.random.default_rng(1)
    noise = rng.integers(0, 2**31, 4000).astype(np.int32)
    sel = ParamSelector(dtype=np.int32, level=3)
    sel.observe(noise)
    assert sel.mean_ratio < 1.5
    assert sel.current_config().symbol_size == 1  # paper's fallback rule


def test_selector_window_levels():
    cfg = select_params(np.zeros(4096, np.uint16), level=1)
    assert cfg.window <= 64  # level 1 = fast
    cfg4 = ParamSelector(dtype=np.uint16, level=4).current_config()
    assert cfg4.window == 255


def test_header_roundtrip_fields():
    data = np.arange(5000, dtype=np.int64).view(np.uint8)[:9999]
    cfg = lzss.LZSSConfig(symbol_size=2, window=77, chunk_symbols=256)
    res = lzss.compress(data, cfg)
    h = fmt.parse_header(res.data)
    assert h.symbol_size == 2
    assert h.window == 77
    assert h.chunk_symbols == 256
    assert h.orig_bytes == 9999
    assert h.total_bytes == res.total_bytes
    n_tok, pay = fmt.parse_tables(res.data, h)
    assert n_tok.shape == (h.n_chunks,)
    assert int(pay.sum()) == h.payload_bytes


def test_bad_magic_rejected():
    with pytest.raises(ValueError, match="magic"):
        fmt.parse_header(np.zeros(64, np.uint8))


def test_max_compressed_bytes_is_worst_case():
    rng = np.random.default_rng(2)
    data = rng.integers(0, 256, 10000).astype(np.uint8)  # incompressible
    for s in (1, 2, 4):
        cfg = lzss.LZSSConfig(symbol_size=s, window=255, chunk_symbols=256)
        res = lzss.compress(data, cfg)
        cap = fmt.max_compressed_bytes(data.size, s, 256)
        assert res.total_bytes <= cap
