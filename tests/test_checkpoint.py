"""Checkpoint manager: bit-exact restore, compression, atomicity, CRC
fallback, retention, elastic template restore."""

import glob
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager


@pytest.fixture
def state():
    k = jax.random.PRNGKey(0)
    return {
        "params": {
            "w": jax.random.normal(k, (64, 64), jnp.float32),
            "e": (jax.random.normal(k, (128, 32)) * 0.01).astype(jnp.bfloat16),
        },
        "opt": {"m": jnp.zeros((64, 64), jnp.float32)},
        "step": jnp.int32(7),
    }


def test_save_restore_bit_exact(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path), compress=True)
    mgr.save(state, 1)
    restored, step = mgr.restore_latest(jax.eval_shape(lambda: state))
    assert step == 1
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compression_helps_on_structured_state(tmp_path):
    # optimizer moments start at zero: hugely compressible
    state = {"m": jnp.zeros((512, 512), jnp.float32)}
    mgr = CheckpointManager(str(tmp_path), compress=True)
    mgr.save(state, 1)
    assert mgr.stats(1)["ratio"] > 20


def test_crc_detects_corruption_and_falls_back(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path), compress=True, keep=5)
    mgr.save(state, 1)
    mgr.save(state, 2)
    files = sorted(
        glob.glob(os.path.join(str(tmp_path), "step_00000002", "*.gplz")),
        key=os.path.getsize,
    )
    with open(files[-1], "r+b") as f:
        f.seek(os.path.getsize(files[-1]) // 2)
        f.write(b"\xa5" * 32)
    restored, step = mgr.restore_latest(jax.eval_shape(lambda: state))
    assert step == 1  # fell back past the damaged step


def test_retention_gc(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path), compress=False, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(state, s)
    assert mgr.steps() == [3, 4]


def test_no_tmp_dirs_left(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path), compress=False)
    mgr.save(state, 1)
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_save_batches_dtype_classes(tmp_path, state, monkeypatch):
    """Leaves of a dtype class go through one batched dispatch, not one
    compress() call per leaf."""
    from repro.checkpoint import manager as mgr_mod
    from repro.core import lzss

    calls = {"many": 0, "single": 0}
    real_many = lzss.compress_many

    def counting_many(arrays, cfg):
        calls["many"] += 1
        return real_many(arrays, cfg)

    def forbidden_single(*a, **k):
        calls["single"] += 1
        raise AssertionError("save() must use the batched pipeline API")

    monkeypatch.setattr(mgr_mod.lzss, "compress_many", counting_many)
    monkeypatch.setattr(mgr_mod.lzss, "compress", forbidden_single)
    mgr = CheckpointManager(str(tmp_path), compress=True)
    mgr.save(state, 1)
    assert calls["single"] == 0
    # state has 3 compressible leaves (2 f32 in one geometry bucket + 1 bf16)
    # -> at most one dispatch per (symbol_size, bucket) group
    assert 1 <= calls["many"] <= 3
    restored, step = mgr.restore_latest(jax.eval_shape(lambda: state))
    assert step == 1
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
