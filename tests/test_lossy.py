"""Error-bounded lossy frontend (``lossy-fz``): the bound is a hard invariant.

The method-2 container subsystem (core/lossy.py + core/bitshuffle.py) rides
the same backend/decoder registries as the lossless pipeline; this suite pins
its contract:

  * quant mode (``lossy_eb > 0``): ``max |x' - x| <= eb`` for every finite
    element — strictly, on every adversarial corpus the lossless conformance
    suite uses — and NaN/±inf elements round-trip bit-exactly through the
    outlier section.
  * lossless mode (``lossy_eb == 0``): bit-exact reconstruction, NaN
    payloads included.
  * a lossy blob handed to a lossless decoder (and vice versa) is a clean
    ValueError naming the method byte — mirroring the method-1 entropy
    routing — never silent garbage.
  * ``decompress`` needs container bytes only: the bound and all decode
    geometry are parsed from the header/metadata, no side-channel state.

The hypothesis twin of the bound property lives in tests/test_properties.py
(optional extra); THIS file is what always runs in the CI ``lossy`` lane.
"""

import numpy as np
import pytest

import test_conformance as conf  # same-dir pytest import
from repro.core import bitshuffle, format as fmt, lzss, pipeline

EB_SWEEP = [1e-2, 1e-4]


def lossy_cfg(eb, inner="auto", window=64, chunk_symbols=256, **kw):
    return lzss.LZSSConfig(
        symbol_size=4, window=window, chunk_symbols=chunk_symbols,
        backend="lossy-fz", lossy_eb=eb, lossy_inner=inner, **kw,
    )


def assert_within_bound(x: np.ndarray, raw_out: np.ndarray, eb: float):
    """The format's guarantee: finite elements within eb, non-finite exact."""
    rec = raw_out.view(np.float32)
    assert rec.size == x.size
    fin = np.isfinite(x)
    np.testing.assert_array_equal(
        rec[~fin].view(np.uint32), x[~fin].view(np.uint32),
        err_msg="non-finite elements must round-trip bit-exactly",
    )
    if fin.any():
        err = np.max(np.abs(rec[fin] - x[fin]))
        assert err <= np.float32(eb), f"max err {err} > eb {eb}"
    return rec


def smooth_field(n=700, seed=0):
    rng = np.random.default_rng(seed)
    return (
        np.cumsum(rng.normal(size=n)).astype(np.float32) * 0.03
        + np.sin(np.linspace(0, 20, n)).astype(np.float32)
    )


# ------------------------------------------------------------- registry


def test_lossy_pair_registered_both_sides():
    assert "lossy-fz" in lzss.available_backends()
    assert "lossy-fz" in lzss.available_decoders()
    assert pipeline.container_method("lossy-fz") == fmt.METHOD_LOSSY


def test_config_validation():
    with pytest.raises(ValueError, match="lossy_eb"):
        lzss.LZSSConfig(symbol_size=4, backend="lossy-fz")  # no bound
    with pytest.raises(ValueError, match="finite bound"):
        lzss.LZSSConfig(symbol_size=4, backend="lossy-fz", lossy_eb=-1.0)
    with pytest.raises(ValueError, match="finite bound"):
        lzss.LZSSConfig(symbol_size=4, backend="lossy-fz", lossy_eb=np.inf)
    with pytest.raises(ValueError, match="f32"):
        lzss.LZSSConfig(symbol_size=2, backend="lossy-fz", lossy_eb=1e-3)
    with pytest.raises(ValueError, match="lossy_eb is only consulted"):
        lzss.LZSSConfig(symbol_size=4, backend="xla", lossy_eb=1e-3)
    with pytest.raises(ValueError, match="not a lossless"):
        lzss.LZSSConfig(symbol_size=4, backend="lossy-fz", lossy_eb=1e-3,
                        lossy_inner="lossy-fz")
    with pytest.raises(ValueError, match="pair it with backend='lossy-fz'"):
        lzss.LZSSConfig(symbol_size=4, decoder="lossy-fz")
    # decoder='auto' pins to the pair's decoder so round-trips self-route
    assert lossy_cfg(1e-3).decoder == "lossy-fz"


# ------------------------------------------- the bound, corpus x eb sweep


@pytest.mark.parametrize("eb", EB_SWEEP)
def test_bound_on_adversarial_corpora(eb):
    """max |x' - x| <= eb on every corpus of the lossless conformance pool
    (incl. nan-inf runs), reinterpreted as f32 element streams."""
    for name, data in conf.corpora(np.float32, 64).items():
        x = np.ascontiguousarray(data, np.float32)
        res = lzss.compress(x, lossy_cfg(eb))
        rec = assert_within_bound(x, lzss.decompress(res.data), eb)
        assert rec.dtype == np.float32, name


def test_bound_on_smooth_field_and_it_compresses():
    x = smooth_field(4096)
    res = lzss.compress(x, lossy_cfg(1e-3, chunk_symbols=1024))
    assert_within_bound(x, lzss.decompress(res.data), 1e-3)
    # the point of the frontend: smooth f32 fields compress well
    assert res.total_bytes < x.nbytes / 2, res.total_bytes


def test_eb_zero_bit_exact():
    """Lossless passthrough mode: bit-exact, NaN payloads included."""
    x = smooth_field(500)
    x[7] = np.nan
    x[8] = np.float32(np.uint32(0x7FC12345).view(np.float32))  # NaN payload
    x[9:12] = [np.inf, -np.inf, 0.0]
    res = lzss.compress(x, lossy_cfg(0.0))
    out = lzss.decompress(res.data)
    np.testing.assert_array_equal(out, x.view(np.uint8))
    h = fmt.parse_header(np.asarray(res.data))
    assert h.lossy_mode == fmt.LOSSY_MODE_LOSSLESS


def test_outlier_saturation_edge_cases():
    """All-outlier input, eb larger than the data range, denormals."""
    eb = 1e-3
    # every element saturates the i16 delta range -> all-outlier container
    rng = np.random.default_rng(5)
    x = (rng.normal(size=600) * 1e9).astype(np.float32)
    res = lzss.compress(x, lossy_cfg(eb))
    rec = assert_within_bound(x, lzss.decompress(res.data), eb)
    np.testing.assert_array_equal(rec, x)  # outliers are stored exactly
    h = fmt.parse_header(np.asarray(res.data))
    # >=: the first zero-padding element after a saturated tail value can
    # itself saturate the delta chain and join the outlier section
    assert h.n_outliers >= x.size
    # eb larger than the whole data range: everything quantizes to 0
    x = rng.uniform(-0.4, 0.4, 512).astype(np.float32)
    res = lzss.compress(x, lossy_cfg(1.0))
    assert_within_bound(x, lzss.decompress(res.data), 1.0)
    # denormal floats are within eb of 0 for any eb > 0
    x = np.full(512, 1e-42, np.float32)
    x[::7] = -4e-44
    res = lzss.compress(x, lossy_cfg(eb))
    assert_within_bound(x, lzss.decompress(res.data), eb)


def test_header_metadata_and_static_params():
    eb = 2.5e-3
    x = smooth_field(300)
    x[13] = np.inf
    res = lzss.compress(x, lossy_cfg(eb))
    blob = np.asarray(res.data)
    h = fmt.parse_header(blob)
    assert h.method == fmt.METHOD_LOSSY
    assert h.version == fmt.VERSION
    assert h.symbol_size == 4
    assert h.lossy_mode == fmt.LOSSY_MODE_QUANT
    # the stored bound is the f32 rounding of the configured one
    assert np.uint32(h.lossy_eb_bits).view(np.float32) == np.float32(eb)
    assert h.inner_method == pipeline.container_method(
        pipeline.resolve_backend("auto")
    )
    assert h.n_outliers >= 1  # the inf at least
    fmt.validate_container(blob, h)
    dec = pipeline.get_decoder("lossy-fz")
    assert dec.static_params(h) == (h.lossy_mode, h.inner_method)


@pytest.mark.parametrize("eb", [0.0, 1e-3])
def test_deflate_full_inner_stage(eb):
    """The inner lossless stage is pluggable: entropy-coded inner container."""
    x = smooth_field(900, seed=3)
    x[50:54] = np.nan
    res = lzss.compress(x, lossy_cfg(eb, inner="deflate-full"))
    h = fmt.parse_header(np.asarray(res.data))
    assert h.inner_method == fmt.METHOD_HUFFMAN
    out = lzss.decompress(res.data)
    if eb == 0.0:
        np.testing.assert_array_equal(out, x.view(np.uint8))
    else:
        assert_within_bound(x, out, eb)


# --------------------------------------------------- method-byte routing


def test_lossy_blob_rejected_by_lossless_decoders():
    """Satellite: a lossy container fed to a lossless decoder is a clean
    ValueError naming the method byte, mirroring the entropy routing."""
    res = lzss.compress(smooth_field(300), lossy_cfg(1e-3))
    for decoder in lzss.available_decoders():
        if decoder in ("lossy-fz", "sharded"):
            continue
        with pytest.raises(ValueError, match="method byte 2"):
            lzss.decompress(res.data, decoder=decoder)
    # 'auto' routes by the method byte instead of raising
    assert_within_bound(
        smooth_field(300), lzss.decompress(res.data, decoder="auto"), 1e-3
    )


def test_lossless_blob_rejected_by_lossy_decoder():
    data = np.arange(1200, dtype=np.uint8)
    for backend in ("xla", "deflate-full"):
        res = lzss.compress(
            data, lzss.LZSSConfig(symbol_size=1, window=32,
                                  chunk_symbols=256, backend=backend)
        )
        # the raw container names the lossy decoder's method restriction;
        # the entropy container names its own method byte first — either
        # way the mismatch is explicit, never silent garbage
        with pytest.raises(ValueError, match="method-[12]"):
            lzss.decompress(res.data, decoder="lossy-fz")


# ----------------------------------------------------- batched dispatch


def test_batched_roundtrip_ragged():
    eb = 1e-3
    items = [smooth_field(n, seed=n) for n in (300, 1500, 64)]
    items[1][7:9] = [np.inf, np.nan]
    cfg = lossy_cfg(eb)
    batch = lzss.compress_many(items, cfg)
    # the largest item sets the batch's padded chunk geometry, so ITS
    # container is byte-identical to per-item compression (smaller items
    # pad up to the shared geometry, same as the lossless batched path)
    solo = lzss.compress(items[1], cfg)
    assert batch[1].total_bytes == solo.total_bytes
    np.testing.assert_array_equal(
        np.asarray(batch[1].data)[: batch[1].total_bytes],
        np.asarray(solo.data)[: solo.total_bytes],
    )
    outs = lzss.decompress_many([r.data for r in batch])
    for item, out in zip(items, outs):
        assert_within_bound(item, out, eb)


def test_decompress_many_rejects_mixed_and_inhomogeneous_batches():
    x = smooth_field(300)
    lossy = lzss.compress(x, lossy_cfg(1e-3))
    raw = lzss.compress(
        x.view(np.uint8),
        lzss.LZSSConfig(symbol_size=4, window=64, chunk_symbols=256),
    )
    with pytest.raises(ValueError, match="homogeneous"):
        lzss.decompress_many([lossy.data, raw.data])
    # same method but different static decode params is also inhomogeneous
    other = lzss.compress(x, lossy_cfg(1e-3, inner="deflate-full"))
    with pytest.raises(ValueError, match="homogeneous lossy batch"):
        lzss.decompress_many([lossy.data, other.data])
    # an explicit lossless decoder on a lossy batch names the method byte
    with pytest.raises(ValueError, match="method byte 2"):
        lzss.decompress_many([lossy.data], decoder="fused-mono")
    with pytest.raises(ValueError, match="method-2"):
        lzss.decompress_many([raw.data], decoder="lossy-fz")


# --------------------------------------- corruption / truncation guards


@pytest.fixture(scope="module")
def lossy_container():
    x = smooth_field(600, seed=9)
    x[11] = np.inf
    res = lzss.compress(x, lossy_cfg(1e-3))
    return np.asarray(res.data)[: res.total_bytes].copy(), x


def test_truncated_lossy_blob_raises(lossy_container):
    blob, _ = lossy_container
    for cut in (1, 9, blob.size // 2):
        with pytest.raises(ValueError):
            lzss.decompress(blob[:-cut])


def test_corrupted_lossy_metadata_raises(lossy_container):
    blob, _ = lossy_container
    h = fmt.parse_header(blob)
    sec_meta = h.sec_meta
    bad = blob.copy()
    bad[sec_meta + 4] = 7  # lossy mode byte out of range
    with pytest.raises(ValueError, match="lossy mode"):
        lzss.decompress(bad)
    bad = blob.copy()
    bad[sec_meta : sec_meta + 4] = 0  # quant mode with eb bits == 0
    with pytest.raises(ValueError, match="error bound"):
        lzss.decompress(bad)


def test_padded_lossy_blob_still_accepted(lossy_container):
    blob, x = lossy_container
    padded = np.concatenate([blob, np.zeros(257, np.uint8)])
    assert_within_bound(x, lzss.decompress(padded), 1e-3)


# -------------------------------------------------- bitshuffle substage


def test_bitshuffle_wire_layout():
    """Plane b's byte j packs bit b of units 8j..8j+7, unit 8j in the LSB —
    the fixed method-2 wire layout."""
    units = np.zeros(bitshuffle.BLOCK_UNITS, np.uint16)
    units[8 * 3 + 5] = 1 << 11  # bit 11 of unit 29 -> plane 11, byte 3, bit 5
    out = np.asarray(bitshuffle.shuffle(units, impl="xla"))
    assert out.size == bitshuffle.BLOCK_BYTES
    expect = np.zeros_like(out)
    expect[11 * bitshuffle.PLANE_BYTES + 3] = 1 << 5
    np.testing.assert_array_equal(out, expect)


def test_bitshuffle_roundtrip_and_pallas_parity():
    rng = np.random.default_rng(2)
    units = rng.integers(0, 1 << 16, 2 * bitshuffle.BLOCK_UNITS).astype(
        np.uint16
    )
    xla = np.asarray(bitshuffle.shuffle(units, impl="xla"))
    np.testing.assert_array_equal(
        np.asarray(bitshuffle.unshuffle(xla, impl="xla")), units
    )
    # the Pallas kernels (interpret mode off-TPU) are byte-identical
    pal = np.asarray(bitshuffle.shuffle(units, impl="pallas"))
    np.testing.assert_array_equal(pal, xla)
    np.testing.assert_array_equal(
        np.asarray(bitshuffle.unshuffle(pal, impl="pallas")), units
    )
    with pytest.raises(ValueError, match="multiple"):
        bitshuffle.shuffle(units[:100])
    with pytest.raises(ValueError, match="impl"):
        bitshuffle.shuffle(units, impl="cuda")


# ------------------------------------------------------------ consumers


def test_kv_store_lossy_codec():
    from repro.serving.kvcache import KVBlockStore

    eb = 1e-3
    store = KVBlockStore(lossy_eb=eb)
    block = smooth_field(64 * 32).reshape(64, 32)
    block[3, 7] = np.nan
    store.evict_many([("a", block)])
    rec = store.restore_many(["a"])[0]
    assert rec.shape == block.shape and rec.dtype == np.float32
    assert_within_bound(block.reshape(-1), rec.reshape(-1).view(np.uint8), eb)
    assert store.stats.eviction_ratio > 1.0
    # non-f32 blocks cannot carry the bound: clean rejection, data kept
    with pytest.raises(ValueError, match="float32 blocks only"):
        store.evict_many([("b", np.zeros((8, 8), np.float16))])


def test_kv_store_mixed_codec_rounds_restore_in_separate_groups():
    from repro.serving.kvcache import KVBlockStore

    lossless = KVBlockStore()
    ints = (smooth_field(1024) * 100).astype(np.int16).reshape(32, 32)
    lossless.evict_many([("i", ints)])
    lossy = KVBlockStore(lossy_eb=1e-3)
    f32 = smooth_field(1024, seed=4).reshape(32, 32)
    lossy.evict_many([("f", f32)])
    # emulate a store whose codec changed between eviction rounds
    lossless._store["f"] = lossy._store.pop("f")
    out = lossless.restore_many(["i", "f"])
    np.testing.assert_array_equal(out[0], ints)
    assert np.max(np.abs(out[1] - f32)) <= 1e-3
    assert lossless.stats.restore_dispatches == 2


def test_grad_exchange_lossy_wire():
    import jax.numpy as jnp

    from repro.optim import grad_compress

    eb = 1e-4
    lcfg = grad_compress.lossy_grad_config(eb)
    assert lcfg.backend == "lossy-fz" and lcfg.lossy_eb == eb
    g = jnp.asarray(smooth_field(4096, seed=6) * 0.01)
    wire = grad_compress.compress_leaf(g, ratio_cap=1.0, lossy_eb=eb)
    out = grad_compress.decompress_leaf(
        wire, g.shape, ratio_cap=1.0, lossy_eb=eb
    )
    used_lz = np.asarray(wire["used_lz"])
    assert used_lz.all(), "smooth gradients must fit the lossy wire budget"
    err = float(np.max(np.abs(np.asarray(out) - np.asarray(g))))
    assert err <= eb, err


def test_checkpoint_lossy_f32_leaves(tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    eb = 1e-3
    state = {
        "w": smooth_field(8192, seed=8).reshape(128, 64),
        "emb": (smooth_field(2048, seed=9) * 50).astype(np.int16),
    }
    mgr = CheckpointManager(str(tmp_path), lz_lossy_eb=eb)
    mgr.save(state, 1)
    restored, step = mgr.restore_latest(state)
    assert step == 1
    np.testing.assert_array_equal(restored["emb"], state["emb"])  # lossless
    err = np.max(np.abs(restored["w"] - state["w"]))
    assert restored["w"].dtype == np.float32 and err <= eb
    # lossy leaves CRC the stored blob: corruption still fails the restore
    import json

    d = tmp_path / "step_00000001"
    man = json.loads((d / "manifest.json").read_text())
    entry = {e["name"]: e for e in man["leaves"]}["w"]
    assert entry["lossy"] is True
    blob_path = d / entry["file"]
    buf = bytearray(blob_path.read_bytes())
    buf[len(buf) // 2] ^= 0xFF
    blob_path.write_bytes(bytes(buf))
    with pytest.raises(IOError, match="CRC mismatch"):
        mgr.restore(state, 1)
