"""Golden-blob conformance corpus: the on-disk container format is pinned.

Every case compresses a tiny deterministic input (per dtype x S x W) and
compares the container byte-for-byte against the blob checked in under
``tests/golden/``.  Any silent change to the wire format — header layout,
table encoding, section order, token encoding, entropy metadata — fails
here with an explicit "bump the format version" message instead of shipping
containers old readers can't parse.

Two generations are pinned:

  * ``tests/golden/*.gplz`` — current-VERSION blobs: the method-0 cases and
    the method-1 (``deflate-full``) entropy cases.
  * ``tests/golden/v1/*.gplz`` — the frozen VERSION-1 corpus from before
    the entropy format bump.  These are never regenerated: they guard that
    this reader keeps decoding already-shipped version-1 containers.

Regenerate the current corpus (ONLY after an intentional format change,
together with a ``core/format.py`` ``VERSION`` bump):

    PYTHONPATH=src python tests/test_golden.py --regen
"""

import pathlib

import numpy as np
import pytest

from repro.core import format as fmt, lzss

GOLDEN_DIR = pathlib.Path(__file__).resolve().parent / "golden"
V1_DIR = GOLDEN_DIR / "v1"

REGEN_HINT = (
    "container bytes changed for a checked-in golden input — the on-disk "
    "format drifted. If this is intentional, bump VERSION in core/format.py "
    "and regenerate the corpus: PYTHONPATH=src python tests/test_golden.py "
    "--regen. If not, the change is a wire-format regression."
)


def _u8_runs(rng):
    return np.repeat(rng.integers(0, 12, 80), rng.integers(1, 6, 80)).astype(
        np.uint8
    )[:256]


def _i16_deltas(rng):
    steps = rng.integers(-3, 4, 160).cumsum().astype(np.int16)
    return np.concatenate([steps, steps[:48]])


def _i32_ramp(rng):
    base = (np.arange(72, dtype=np.int32) * 9973) % 1024
    return np.concatenate([base, base[:24], rng.integers(0, 1 << 20, 16)]).astype(
        np.int32
    )


def _f32_waves(rng):
    x = np.sin(np.linspace(0.0, 4.0, 96)).astype(np.float32)
    x[20:28] = np.nan
    x[40:44] = np.inf
    x[44:48] = -np.inf
    return np.concatenate([x, x[:32]])


def _u8_noise(rng):
    return rng.integers(0, 256, 200).astype(np.uint8)


# name -> (input builder, symbol_size, window, chunk_symbols); seeds fixed
# per case so the corpus is reproducible bit-for-bit
CASES = {
    "u8_s1_w32_c64": (_u8_runs, 1, 32, 64),
    "u8_s1_w255_c64": (_u8_noise, 1, 255, 64),
    "i16_s2_w64_c64": (_i16_deltas, 2, 64, 64),
    "i16_s2_w128_c128": (_i16_deltas, 2, 128, 128),
    "i32_s4_w128_c64": (_i32_ramp, 4, 128, 64),
    "f32_s4_w64_c64": (_f32_waves, 4, 64, 64),
    "f32_s4_w255_c128": (_f32_waves, 4, 255, 128),
}

# method-1 cases: same builders, compressed through the entropy backend —
# pins the VERSION-2 metadata layout (codebooks, bit counts, gap arrays,
# bitstream packing) byte-for-byte
ENTROPY_CASES = {
    "u8_s1_w32_c64_deflate": (_u8_runs, 1, 32, 64),
    "i16_s2_w128_c128_deflate": (_i16_deltas, 2, 128, 128),
    "f32_s4_w64_c64_deflate": (_f32_waves, 4, 64, 64),
}

# method-2 cases: the f32 base input through the error-bounded lossy-fz
# pair — pins the lossy metadata block, the bitshuffle wire layout, the
# inner container placement and the outlier section byte-for-byte.  The
# quantized encoder chain is f32-deterministic by design (core/lossy.py
# ``_rcp``), so the bytes are stable across platforms like every other case.
# name -> (builder, s, w, c, eb); eb=0 pins the lossless passthrough mode.
LOSSY_CASES = {
    "f32_s4_w64_c64_lossy": (_f32_waves, 4, 64, 64, 1e-3),
    "f32_s4_w64_c64_lossy_eb0": (_f32_waves, 4, 64, 64, 0.0),
}

ALL_CASES = {**CASES, **ENTROPY_CASES, **LOSSY_CASES}


def _case_base(name):
    """Derived cases reuse their base case's input byte-for-byte."""
    for suffix in ("_deflate", "_lossy", "_lossy_eb0"):
        if name.endswith(suffix):
            return name[: -len(suffix)]
    return name


def _case_cfg(name):
    if name in LOSSY_CASES:
        _, s, w, c, eb = LOSSY_CASES[name]
        # inner stage pinned to 'xla' (all method-0 backends are
        # byte-identical, but the pin keeps the cfg platform-independent)
        return lzss.LZSSConfig(
            symbol_size=s, window=w, chunk_symbols=c, backend="lossy-fz",
            lossy_eb=eb, lossy_inner="xla",
        )
    _, s, w, c = ALL_CASES[name]
    backend = "deflate-full" if name in ENTROPY_CASES else "xla"
    return lzss.LZSSConfig(
        symbol_size=s, window=w, chunk_symbols=c, backend=backend
    )


def _golden_paths(name, root=GOLDEN_DIR):
    return root / f"{name}.input.bin", root / f"{name}.gplz"


def _load_case(name, root=GOLDEN_DIR):
    """Checked-in input bytes + golden container bytes.

    The inputs are stored on disk too (not regenerated from the builders at
    test time): np.sin and Generator bit-streams are not guaranteed stable
    across numpy versions/platforms, and an input drift would masquerade as
    a format regression."""
    inp, gold = _golden_paths(name, root)
    for path in (inp, gold):
        assert path.exists(), (
            f"golden file {path.name} missing — regenerate the corpus: "
            f"PYTHONPATH=src python tests/test_golden.py --regen"
        )
    return (
        np.frombuffer(inp.read_bytes(), np.uint8),
        np.frombuffer(gold.read_bytes(), np.uint8),
    )


@pytest.mark.parametrize("name", sorted(ALL_CASES))
def test_golden_blob_is_stable(name):
    data, golden = _load_case(name)
    res = lzss.compress(data, _case_cfg(name))
    assert res.data.size == golden.size and np.array_equal(res.data, golden), (
        f"{name}: {REGEN_HINT}"
    )


@pytest.mark.parametrize("name", sorted(ALL_CASES))
def test_golden_blob_decodes_to_input(name):
    """The checked-in bytes (not just freshly produced ones) must decode —
    this is what guards real backward readability of shipped containers."""
    data, golden = _load_case(name)
    h = fmt.parse_header(golden)
    assert h.version == fmt.VERSION
    assert h.symbol_size == ALL_CASES[name][1]
    assert h.window == ALL_CASES[name][2]
    if name in LOSSY_CASES:
        eb = LOSSY_CASES[name][4]
        assert h.method == fmt.METHOD_LOSSY
        out = np.asarray(lzss.decompress(golden))
        if eb == 0.0:
            assert h.lossy_mode == fmt.LOSSY_MODE_LOSSLESS
            assert np.array_equal(out, data)
        else:
            assert h.lossy_mode == fmt.LOSSY_MODE_QUANT
            x, rec = data.view(np.float32), out.view(np.float32)
            fin = np.isfinite(x)
            assert np.array_equal(
                rec[~fin].view(np.uint32), x[~fin].view(np.uint32)
            )
            assert np.max(np.abs(rec[fin] - x[fin])) <= np.float32(eb)
        return
    want_method = (
        fmt.METHOD_HUFFMAN if name in ENTROPY_CASES else fmt.METHOD_RAW
    )
    assert h.method == want_method
    assert np.array_equal(lzss.decompress(golden), data)


@pytest.mark.parametrize("name", sorted(CASES))
def test_version1_golden_blob_still_decodes(name):
    """Frozen VERSION-1 blobs (pre-entropy format) must keep decoding:
    version 1 stays in SUPPORTED_VERSIONS and parses as method 0."""
    data, golden = _load_case(name, root=V1_DIR)
    h = fmt.parse_header(golden)
    assert h.version == 1
    assert h.method == fmt.METHOD_RAW
    assert np.array_equal(lzss.decompress(golden), data)


def test_version_mismatch_raises_naming_versions():
    """A blob declaring a version this reader doesn't speak is a ValueError
    naming BOTH the container's version and the supported set — the
    regression guard for the VERSION-2 bump."""
    _, golden = _load_case(sorted(CASES)[0])
    bad = golden.copy()
    bad[4] = 3
    with pytest.raises(ValueError) as ei:
        fmt.parse_header(bad)
    msg = str(ei.value)
    assert "3" in msg and str(fmt.SUPPORTED_VERSIONS) in msg
    with pytest.raises(ValueError):
        lzss.decompress(bad)


def _regen(only=None):
    GOLDEN_DIR.mkdir(exist_ok=True)
    for name in sorted(ALL_CASES):
        if only and name not in only:
            continue
        build = ALL_CASES[name][0]
        # seeds must not depend on PYTHONHASHSEED: derive from the name
        # bytes; derived cases reuse their base case's input byte-for-byte —
        # from disk when the base input is already checked in (builder
        # bit-streams are not guaranteed stable across numpy versions, so
        # rebuilding could silently drift a derived case off its base)
        base = _case_base(name)
        base_inp = GOLDEN_DIR / f"{base}.input.bin"
        if base != name and base_inp.exists():
            raw = np.frombuffer(base_inp.read_bytes(), np.uint8)
        else:
            seed = int.from_bytes(base.encode(), "little") % (1 << 32)
            data = build(np.random.default_rng(seed))
            raw = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
        res = lzss.compress(raw, _case_cfg(name))
        inp, gold = _golden_paths(name)
        inp.write_bytes(bytes(raw))
        gold.write_bytes(bytes(res.data))
        print(f"wrote {gold} ({res.total_bytes} bytes, ratio {res.ratio:.2f})")


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        sys.exit("usage: python tests/test_golden.py --regen")
