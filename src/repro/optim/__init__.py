from repro.optim.adamw import (
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
    lr_schedule,
)
from repro.optim.grad_compress import (
    GRAD_LZ,
    compress_leaf,
    decompress_leaf,
    dequantize_u16,
    lossy_grad_config,
    pod_exchange_compressed,
    quantize_u16,
)

__all__ = [
    "adamw_update",
    "clip_by_global_norm",
    "global_norm",
    "init_opt_state",
    "lr_schedule",
    "GRAD_LZ",
    "compress_leaf",
    "decompress_leaf",
    "dequantize_u16",
    "lossy_grad_config",
    "pod_exchange_compressed",
    "quantize_u16",
]
