"""Cross-pod gradient exchange with GPULZ compression (the paper's
inter-node-communication use case, in-graph).

Topology assumption: the pod axis is the slow link (inter-pod DCN/ICI).
Within a pod, gradients reduce in bf16 (XLA-inserted collectives).  Across
pods, each leaf is:

  1. quantized to uint16 codes with a per-leaf symmetric scale
     (+ optional error-feedback accumulator),
  2. GPULZ-compressed in-graph through the pipeline's batched entry point
     (``pipeline.compress_many_chunks`` — all slabs in one dispatch, symbols
     ARE the codes, S=2), pinned pod-local via ``sharding.batch.shard_vmap``
     (shard_map over the pod axis: each pod compresses the shard it already
     owns), into a buffer **capped at the raw-int16 size** so the exchange is
     never worse than 2 bytes/element (2x smaller than bf16+fp32-master
     exchanges, more when the codes compress),
  3. all-gathered over the pod axis (the only inter-pod traffic),
  4. decoded in-graph (tables parsed straight from the received blob) and
     averaged.

When the compressed stream does not fit the cap (incompressible gradients)
the raw uint16 codes are sent instead, signalled by a one-hot flag — the
exchange stays fixed-shape either way, which is what fixed-topology
collectives require.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import format as fmt, pipeline
from repro.core.pipeline import LZSSConfig

# backend/decoder default to "auto": the in-graph compress emits through
# the single-kernel fused-mono compressor (Kernels I+II+III in ONE Pallas
# launch) and the decode dispatches the fused Pallas decoder on TPU;
# unfused xla / xla-parallel elsewhere (core/pipeline.py registry).
# Resolution happens at dispatch time, so importing this module never
# initializes the JAX platform.
GRAD_LZ = LZSSConfig(symbol_size=2, window=32, chunk_symbols=2048,
                     backend="auto")
MIN_COMPRESS_SIZE = 65_536  # leaves below this exchange raw (graph economy)


def quantize_u16(x):
    """Symmetric uint16 quantization.  Returns (codes int32 in [0,65535], scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-30) / 32767.0
    codes = jnp.clip(jnp.round(x32 / scale), -32767, 32767).astype(jnp.int32)
    return codes + 32768, scale


def dequantize_u16(codes, scale):
    return (codes.astype(jnp.float32) - 32768.0) * scale


def _pad_to_chunks(codes_flat, c):
    n = codes_flat.shape[0]
    nc = -(-n // c)
    pad = nc * c - n
    return jnp.pad(codes_flat, (0, pad)), nc


SLAB_SYMBOLS = 1 << 24  # 16M symbols (32 MB) per slab: int32-offset safe,
                        # and slabs compress in parallel (vmap)


def _slab_geometry(n: int, cfg: LZSSConfig):
    c = cfg.chunk_symbols
    slab = min(SLAB_SYMBOLS, -(-n // c) * c)
    slab = -(-slab // c) * c
    n_slabs = -(-n // slab)
    return slab, n_slabs


def _cap_bytes(slab: int, ratio_cap: float) -> int:
    """Wire budget per slab: raw-int16 bytes / ratio_cap (>= 1 B/elem)."""
    return max(slab, int(slab * 2 / max(ratio_cap, 1.0)))


def _compress_slabs(padded, cfg, ratio_cap):
    """(n_slabs, slab) int32 codes -> ((n_slabs, cap) u8 payloads, used_lz).

    One batched pipeline dispatch compresses every slab.  Budget =
    2/ratio_cap bytes/element.  If a slab's LZSS container fits, its exchange
    is lossless w.r.t. the uint16 codes; otherwise it degrades to the codes'
    high bytes (int8 precision — error feedback recommended, see
    CompressionConfig).
    """
    n_slabs, slab = padded.shape
    c = cfg.chunk_symbols
    cap = _cap_bytes(slab, ratio_cap)
    blobs, totals = pipeline.compress_many_chunks(
        padded.reshape(n_slabs, slab // c, c), cfg,
        jnp.full((n_slabs,), slab * 2, jnp.int32),
    )
    used_lz = totals <= cap
    if cap >= slab * 2:  # budget fits raw u16: lossless fallback
        fb = jnp.stack(
            [padded & 0xFF, padded >> 8], axis=2
        ).reshape(n_slabs, -1)[:, :cap]
    else:                # tight budget: int8 fallback (high bytes)
        fb = jnp.pad(padded >> 8, ((0, 0), (0, max(0, cap - slab))))[:, :cap]
    payload = jnp.where(
        used_lz[:, None], blobs[:, :cap].astype(jnp.int32), fb
    )
    return payload.astype(jnp.uint8), used_lz


def lossy_grad_config(eb: float, cfg: LZSSConfig = GRAD_LZ) -> LZSSConfig:
    """The error-bounded gradient exchange config (``lossy-fz``, S=4).

    Gradients are f32 element streams to the lossy frontend; the configured
    ``cfg.backend`` becomes the *inner* lossless stage.  Optimizer state and
    checkpoints never use this — they stay lossless (the lossy-gradients /
    lossless-state split of ``CompressionConfig.lossy_eb``).
    """
    inner = "auto" if cfg.backend in ("lossy-fz", "sharded") else cfg.backend
    return dataclasses.replace(
        cfg, symbol_size=4, backend="lossy-fz", decoder="auto",
        lossy_eb=float(eb), lossy_inner=inner,
    )


def _lossy_method_params(lcfg: LZSSConfig) -> tuple:
    """The static (mode, inner_method) pin — known from the config alone,
    so the in-graph decode needs no host-side header parse."""
    mode = (
        fmt.LOSSY_MODE_QUANT if float(lcfg.lossy_eb) > 0.0
        else fmt.LOSSY_MODE_LOSSLESS
    )
    inner = pipeline.container_method(
        pipeline.resolve_backend(lcfg.lossy_inner)
    )
    return (mode, inner)


def _compress_slabs_lossy(g_padded, codes_padded, lcfg, ratio_cap):
    """(n_slabs, slab) f32 grads -> ((n_slabs, cap) u8 payloads, used_lz).

    Same wire budget as the u16 path (2/ratio_cap bytes/element), but a slab
    that fits carries an error-bounded lossy-fz container: max |g' - g| <= eb
    per element (exact for non-finite elements).  A slab whose container
    exceeds the budget degrades to the u16 quantization codes (used_lz=False
    — error scale/2, NOT eb-bounded), keeping the exchange fixed-shape.
    """
    n_slabs, slab = g_padded.shape
    c = lcfg.chunk_symbols
    cap = _cap_bytes(slab, ratio_cap)
    bits = lax.bitcast_convert_type(g_padded.astype(jnp.float32), jnp.int32)
    blobs, totals = pipeline.compress_many_chunks(
        bits.reshape(n_slabs, slab // c, c), lcfg,
        jnp.full((n_slabs,), slab * 4, jnp.int32),
    )
    used_lz = totals <= cap
    if cap >= slab * 2:  # budget fits raw u16 codes
        fb = jnp.stack(
            [codes_padded & 0xFF, codes_padded >> 8], axis=2
        ).reshape(n_slabs, -1)[:, :cap]
    else:                # tight budget: int8 fallback (high bytes)
        fb = jnp.pad(
            codes_padded >> 8, ((0, 0), (0, max(0, cap - slab)))
        )[:, :cap]
    payload = jnp.where(
        used_lz[:, None], blobs[:, :cap].astype(jnp.int32), fb
    )
    return payload.astype(jnp.uint8), used_lz


def _decompress_slabs_lossy(payload, used_lz, slab, lcfg, scale):
    """Inverse of _compress_slabs_lossy -> (n_slabs, slab) f32 gradients."""
    n_slabs, cap = payload.shape
    c = lcfg.chunk_symbols
    nc = slab // c
    # method-2 containers carry no per-chunk tables (held zero); the decode
    # hook reads everything it needs from the blob at static offsets
    zeros = jnp.zeros((n_slabs, nc), jnp.int32)
    syms = pipeline.decompress_many_chunks(
        payload, zeros, zeros,
        symbol_size=4, chunk_symbols=c, n_chunks=nc, decoder="lossy-fz",
        chunks_per_block=lcfg.chunks_per_block,
        method_params=_lossy_method_params(lcfg),
    ).reshape(n_slabs, -1)
    g_lz = lax.bitcast_convert_type(syms, jnp.float32)
    # fallback slabs carry u16 quantization codes (same wire layout as the
    # legacy path's fallback branches)
    p32 = payload.astype(jnp.int32)
    if cap >= slab * 2:
        pairs = p32[:, : slab * 2].reshape(n_slabs, -1, 2)
        codes_fb = pairs[..., 0] | (pairs[..., 1] << 8)
    else:
        hi = jnp.pad(p32, ((0, 0), (0, max(0, slab - cap))))[:, :slab]
        codes_fb = (hi << 8) | 128
    g_fb = dequantize_u16(codes_fb, scale)
    return jnp.where(used_lz[:, None], g_lz, g_fb)


def _decompress_slabs(payload, used_lz, slab, cfg):
    """Inverse of _compress_slabs -> (n_slabs, slab) int32 codes."""
    n_slabs, cap = payload.shape
    c = cfg.chunk_symbols
    nc = slab // c
    # The container's header + tables always fit inside the cap prefix
    # (48 + 8*nc << slab <= cap), so the payload buffer parses in place —
    # no worst-case zero-padding; the section gathers are bounds-checked.
    p32 = payload.astype(jnp.int32)
    n_tokens, payload_sizes = jax.vmap(
        lambda b: fmt.parse_tables_jax(b, nc)
    )(p32)
    syms_lz = pipeline.decompress_many_chunks(
        payload, n_tokens, payload_sizes,
        symbol_size=2, chunk_symbols=c, n_chunks=nc, decoder=cfg.decoder,
        chunks_per_block=cfg.chunks_per_block,
    ).reshape(n_slabs, -1)
    if cap >= slab * 2:  # lossless raw-u16 fallback
        pairs = p32[:, : slab * 2].reshape(n_slabs, -1, 2)
        syms_raw = pairs[..., 0] | (pairs[..., 1] << 8)
    else:                # int8 fallback: centre of the low byte
        hi = jnp.pad(p32, ((0, 0), (0, max(0, slab - cap))))[:, :slab]
        syms_raw = (hi << 8) | 128
    return jnp.where(used_lz[:, None], syms_lz, syms_raw)


def compress_leaf(g, cfg: LZSSConfig = GRAD_LZ, ratio_cap: float = 2.0,
                  lossy_eb=None):
    """Gradient leaf -> fixed-size wire format.

    Returns dict: payload (uint8, 2/ratio_cap bytes/elem), used_lz (bool per
    slab), scale (f32).  Large leaves are slab-split (int32-offset safety +
    parallel compression); slabs whose LZSS container exceeds the budget
    degrade to int8 precision (used_lz=False).

    ``lossy_eb`` (``CompressionConfig.lossy_eb``) switches fitting slabs to
    the error-bounded ``lossy-fz`` path at the SAME wire budget: max
    |g' - g| <= eb per element instead of the u16 quantization's scale/2.
    Fallback slabs still carry the u16 codes either way.
    """
    n = g.size
    codes, scale = quantize_u16(g.reshape(-1))
    slab, n_slabs = _slab_geometry(n, cfg)
    padded = jnp.pad(codes, (0, n_slabs * slab - n)).reshape(n_slabs, slab)
    if lossy_eb is None:
        payload, used_lz = _compress_slabs(padded, cfg, ratio_cap)
    else:
        gp = jnp.pad(
            g.reshape(-1).astype(jnp.float32), (0, n_slabs * slab - n)
        ).reshape(n_slabs, slab)
        payload, used_lz = _compress_slabs_lossy(
            gp, padded, lossy_grad_config(lossy_eb, cfg), ratio_cap
        )
    return {
        "payload": payload.reshape(-1),
        "used_lz": used_lz,
        "scale": scale,
    }


def decompress_leaf(wire, shape, cfg: LZSSConfig = GRAD_LZ,
                    ratio_cap: float = 2.0, lossy_eb=None):
    """Inverse of compress_leaf -> fp32 gradient leaf."""
    n = 1
    for s in shape:
        n *= s
    slab, n_slabs = _slab_geometry(n, cfg)
    cap = _cap_bytes(slab, ratio_cap)
    payload = wire["payload"].reshape(n_slabs, cap)
    if lossy_eb is not None:
        g = _decompress_slabs_lossy(
            payload, wire["used_lz"], slab,
            lossy_grad_config(lossy_eb, cfg), wire["scale"],
        ).reshape(-1)[:n]
        return g.reshape(shape)
    codes = _decompress_slabs(
        payload, wire["used_lz"], slab, cfg
    ).reshape(-1)[:n]
    return dequantize_u16(codes, wire["scale"]).reshape(shape)


def pod_exchange_compressed(grad_stack, mesh, compress: bool = True,
                            cfg: LZSSConfig = GRAD_LZ,
                            ratio_cap: float = 2.0, lossy_eb=None):
    """Average pod-stacked gradients; the pod-axis collective carries only
    compressed bytes.

    ``grad_stack`` leaves have a leading (n_pods,) dim sharded over "pod"
    (produced by vmap-ing the grad computation over a pod-split batch).  Each
    pod's slice is compressed *where it lives* — ``sharding.batch.shard_vmap``
    pins the per-pod compression inside ``shard_map(pod)``, so the
    partitioner cannot choose to replicate the raw gradient first and
    compress everywhere (which would put uncompressed bytes on the slow
    inter-pod links).  The fixed-size wire is then replicated across the pod
    axis (an all-gather of compressed bytes — the only inter-pod traffic),
    and every pod decodes all slices locally and averages.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.sharding import batch as shbatch

    n_pods = mesh.shape["pod"]
    rep = lambda x: jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*([None] * x.ndim)))
    )
    # per-pod view: compression must stay pod-local, so a sharded batch
    # config (mesh= / "sharded" keys) resolves to its single-device inner
    # dispatch here — nesting shard_map(pod) inside shard_map would be wrong
    local_cfg = shbatch.unsharded(cfg)

    def exchange_leaf(g):
        shape = g.shape[1:]
        size = 1
        for s in shape:
            size *= s
        if not compress or size < MIN_COMPRESS_SIZE:
            return jnp.mean(rep(g).astype(jnp.float32), axis=0).astype(g.dtype)
        wire = shbatch.shard_vmap(
            lambda x: compress_leaf(x, local_cfg, ratio_cap, lossy_eb),
            mesh, "pod",
        )(g)
        wire = jax.tree.map(rep, wire)  # <- compressed pod all-gather
        acc = 0.0
        for k in range(n_pods):
            wk = jax.tree.map(lambda x: x[k], wire)
            acc = acc + decompress_leaf(
                wk, shape, local_cfg, ratio_cap, lossy_eb
            )
        return (acc / n_pods).astype(g.dtype)

    return jax.tree.map(exchange_leaf, grad_stack)
