"""Sharded AdamW + LR schedule + global-norm clipping.

Optimizer state (m, v) is fp32 and — under ``TrainConfig.zero_opt_state`` —
additionally sharded over the data axis (ZeRO-style), which is what lets the
236B MoE config fit 16 GB/chip (DESIGN.md §5).  Params update in their own
dtype (master-less AdamW with fp32 moments, the common large-scale setup).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lr_schedule(step, cfg):
    """Linear warmup -> cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.learning_rate * (0.1 + 0.45 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree)
        )
    )


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(params, grads, opt_state, step, cfg):
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    lr = lr_schedule(step, cfg)
    b1, b2, wd = cfg.beta1, cfg.beta2, cfg.weight_decay
    t = step.astype(jnp.float32) + 1.0
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + 1e-8) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
