"""Fault-tolerant checkpointing with GPULZ-compressed shards.

Layout:  <dir>/step_<N>/
             manifest.json       tree structure, shapes, dtypes, per-leaf CRC
             <leaf-id>.gplz      GPULZ container  (or .raw if compression off)
         <dir>/step_<N>.tmp...   staging dir, atomically renamed on success

Fault-tolerance properties:
  * atomic publish (tmp dir + os.rename) — a crash mid-save never corrupts
    the latest checkpoint;
  * every leaf CRC-checked on restore; a damaged step is skipped and the
    previous valid step restored (``restore_latest``);
  * checkpoints are mesh-agnostic: leaves are stored as full logical arrays
    and re-device_put under the *target* mesh's shardings on restore —
    elastic restarts onto a different mesh shape are free (runtime/elastic.py);
  * symbol size picked per dtype (S=4 fp32/int32, S=2 bf16/f16/int16), the
    paper's multi-byte rule.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import zlib

import jax
import numpy as np

from repro.core import lzss


def _symbol_size(dtype: np.dtype) -> int:
    return {4: 4, 2: 2, 1: 1}.get(np.dtype(dtype).itemsize, 4)


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", k)) for k in path) for path, _ in flat]
    return names, [leaf for _, leaf in flat], treedef


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    compress: bool = True
    keep: int = 3
    lz_window: int = 64
    lz_chunk: int = 4096

    # ------------------------------------------------------------- save

    def save(self, state, step: int) -> str:
        os.makedirs(self.directory, exist_ok=True)
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        names, leaves, _ = _leaf_paths(state)
        manifest = {"step": step, "leaves": []}
        for name, leaf in zip(names, leaves):
            arr = np.asarray(jax.device_get(leaf))
            raw = arr.tobytes()
            entry = {
                "name": name,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(raw),
                "nbytes": len(raw),
            }
            fname = name.replace("/", ".") or "scalar"
            if self.compress and len(raw) >= 1024:
                s = _symbol_size(arr.dtype)
                cfg = lzss.LZSSConfig(
                    symbol_size=s, window=self.lz_window,
                    chunk_symbols=self.lz_chunk,
                )
                res = lzss.compress(np.frombuffer(raw, np.uint8), cfg)
                entry["codec"] = "gpulz"
                entry["stored_bytes"] = res.total_bytes
                path = os.path.join(tmp, fname + ".gplz")
                res.data.tofile(path)
            else:
                entry["codec"] = "raw"
                entry["stored_bytes"] = len(raw)
                path = os.path.join(tmp, fname + ".raw")
                with open(path, "wb") as f:
                    f.write(raw)
            entry["file"] = os.path.basename(path)
            manifest["leaves"].append(entry)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    # ---------------------------------------------------------- restore

    def steps(self):
        if not os.path.isdir(self.directory):
            return []
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _load_step(self, template, step: int, shardings=None):
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_name = {e["name"]: e for e in manifest["leaves"]}
        names, leaves, treedef = _leaf_paths(template)
        sh_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None
            else [None] * len(leaves)
        )
        out = []
        for name, tmpl, sh in zip(names, leaves, sh_leaves):
            e = by_name[name]
            path = os.path.join(d, e["file"])
            if e["codec"] == "gpulz":
                blob = np.fromfile(path, np.uint8)
                raw = lzss.decompress(blob).tobytes()
            else:
                with open(path, "rb") as f:
                    raw = f.read()
            if zlib.crc32(raw) != e["crc32"]:
                raise IOError(f"CRC mismatch for {name} at step {step}")
            arr = np.frombuffer(raw, e["dtype"]).reshape(e["shape"])
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]

    def restore(self, template, step: int, shardings=None):
        return self._load_step(template, step, shardings)

    def restore_latest(self, template, shardings=None):
        """Walk back from the newest step until one restores cleanly."""
        for step in reversed(self.steps()):
            try:
                return self._load_step(template, step, shardings)
            except Exception as exc:  # damaged shard/manifest — try older
                print(f"[ckpt] step {step} unusable ({exc}); trying older")
        return None, -1

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"),
                ignore_errors=True,
            )

    def stats(self, step: int) -> dict:
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        orig = sum(e["nbytes"] for e in manifest["leaves"])
        stored = sum(e["stored_bytes"] for e in manifest["leaves"])
        return {
            "orig_bytes": orig,
            "stored_bytes": stored,
            "ratio": orig / max(1, stored),
        }
