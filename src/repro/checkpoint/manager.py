"""Fault-tolerant checkpointing with GPULZ-compressed shards.

Layout:  <dir>/step_<N>/
             manifest.json       tree structure, shapes, dtypes, per-leaf CRC
             COMMIT              commit marker, written LAST before publish
             <leaf-id>.gplz      GPULZ container  (or .raw if compression off)
         <dir>/step_<N>.tmp...   staging dir, atomically renamed on success

Fault-tolerance properties:
  * atomic publish (tmp dir + os.rename) — a crash mid-save never corrupts
    the latest checkpoint;
  * commit-marker discipline: blobs -> manifest -> ``COMMIT`` -> rename, in
    that order.  ``steps()`` lists only marker-bearing dirs, so a
    half-written step (crash at ANY boundary, or a hand-planted marker-less
    dir) is never restorable, never counts toward retention, and never
    blocks GC of older complete steps — ``_gc`` removes such debris once no
    writer owns it.  (Pre-marker-era checkpoints are treated as
    uncommitted debris: re-save them.)
  * ``async_writes=True`` hands every byte to a double-buffered background
    writer (``runtime/async_io.AsyncBlobWriter``): ``save`` overlaps each
    dtype-class compression dispatch with the previous group's host write
    and returns before the step is durable.  A background failure surfaces
    on the NEXT ``save``/``wait_until_finished`` as an ``AsyncWriteError``
    naming the step and path; an in-flight step is never GC'd; writer
    backpressure is exported for ``StepGuard`` accounting.  With async off
    (default) the write path is host-synchronous exactly as before and the
    on-disk bytes are identical either way;
  * every write goes through the ``runtime/fault.HostFS`` seam under a
    ``RetryPolicy`` (transient-EIO retry with backoff; ENOSPC fails fast),
    so the crash/fault harness can inject failures at exact boundaries;
  * every leaf CRC-checked on restore; a damaged step is skipped and the
    previous valid step restored (``restore_latest``);
  * checkpoints are mesh-agnostic: leaves are stored as full logical arrays
    and re-device_put under the *target* mesh's shardings on restore —
    elastic restarts onto a different mesh shape are free (runtime/elastic.py);
  * symbol size picked per dtype (S=4 fp32/int32, S=2 bf16/f16/int16), the
    paper's multi-byte rule;
  * leaves of a dtype class are compressed together: one batched pipeline
    dispatch (``lzss.compress_many``) per (symbol size, chunk-count bucket)
    group instead of one ``compress()`` call per leaf;
  * with ``lz_mesh=...`` that dispatch is shard-mapped over the mesh's batch
    axis (``sharding/batch.py``, the ``"sharded"`` registry pair).  Blobs are
    byte-identical to the single-device dispatch, so checkpoints stay
    mesh-agnostic: a step written on an 8-device mesh restores on 2 devices
    (or 1) — ``runtime/elastic.py`` re-points ``lz_mesh`` at the
    restore-side mesh.
"""

from __future__ import annotations

import dataclasses
import json
import os
import zlib

import jax
import numpy as np

from repro.core import lzss
from repro.runtime.async_io import AsyncBlobWriter, RetryPolicy
from repro.runtime.fault import HostFS

COMMIT_MARKER = "COMMIT"


def _symbol_size(dtype: np.dtype) -> int:
    return {4: 4, 2: 2, 1: 1}.get(np.dtype(dtype).itemsize, 4)


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", k)) for k in path) for path, _ in flat]
    return names, [leaf for _, leaf in flat], treedef


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    compress: bool = True
    keep: int = 3
    lz_window: int = 64
    lz_chunk: int = 4096
    lz_backend: str = "auto"   # compressor registry key; "auto" = the
                               # single-kernel fused-mono pipeline on TPU
    lz_decoder: str = "auto"   # decode registry key; "auto" = the single-
                               # launch fused-mono decoder on TPU (restores
                               # decode straight from the stored blobs)
    lz_chunks_per_block: object = None  # kernel block geometry for BOTH
                               # save and restore kernels; None = the
                               # core/autotune.py chooser per device
    lz_mesh: object = None     # shard each per-dtype-class batched dispatch
                               # over this mesh ("sharded" registry pair);
                               # blobs on disk stay byte-identical, so a
                               # checkpoint written on one mesh restores on
                               # any other (runtime/elastic.py re-points
                               # lz_mesh at the restore-side mesh)
    lz_batch_axis: object = None
    lz_lossy_eb: object = None  # error-bounded lossy compression of f32
                               # leaves (lossy-fz codec: each restored
                               # element within eb of the saved value,
                               # non-finite exact); every other dtype — and
                               # all leaves when None — stays lossless.
                               # Lossy leaves CRC the stored blob instead of
                               # the raw bytes (the raw bytes are not
                               # reproduced bit-exactly by design).
    async_writes: bool = False  # hand blob/manifest/commit writes to the
                               # double-buffered background writer; save()
                               # returns before the step is durable and a
                               # write failure surfaces on the NEXT save /
                               # wait_until_finished (AsyncWriteError)
    fs: object = None          # runtime/fault.HostFS seam (FaultyFS in the
                               # crash/fault-injection harness)
    writer: object = None      # injectable AsyncBlobWriter; lazily built
    io_retry: object = None    # runtime/async_io.RetryPolicy for host
                               # writes in BOTH modes (transient-EIO retry)
    io_max_pending: int = 2    # async double-buffer depth: how many steps
                               # may be in flight before save() blocks

    def __post_init__(self):
        if self.fs is None:
            self.fs = HostFS()
        if self.io_retry is None:
            self.io_retry = RetryPolicy()
        # backpressure of the most recent async save() (seconds the call
        # blocked waiting for writer queue room) — StepGuard's io signal
        self.last_save_io_wait_s = 0.0

    # ------------------------------------------------------------- save

    def _get_writer(self) -> AsyncBlobWriter:
        if self.writer is None:
            self.writer = AsyncBlobWriter(
                fs=self.fs, max_pending_steps=self.io_max_pending,
                retry=self.io_retry,
            )
        return self.writer

    def wait_until_finished(self):
        """Block until every async write has landed; re-raise any
        background failure (AsyncWriteError naming step and path)."""
        if self.writer is not None:
            self.writer.wait_until_finished()

    def writer_stats(self) -> dict:
        return self.writer.stats() if self.writer is not None else {}

    def _lz_config(self, symbol_size: int, lossy: bool = False) -> "lzss.LZSSConfig":
        # "auto" backend/decoder resolve per-platform at dispatch time;
        # with a mesh they map to the shard-mapped "sharded" pair instead
        backend, decoder = self.lz_backend, self.lz_decoder
        if lossy:
            # configured backend becomes the lossy container's inner
            # lossless stage (mirrors optim/grad_compress.lossy_grad_config)
            inner = "auto" if backend in ("lossy-fz", "sharded") else backend
            if self.lz_mesh is not None:
                decoder = "sharded" if decoder == "auto" else decoder
            return lzss.LZSSConfig(
                symbol_size=4, window=self.lz_window,
                chunk_symbols=self.lz_chunk,
                chunks_per_block=self.lz_chunks_per_block,
                backend="lossy-fz", decoder=decoder,
                lossy_eb=float(self.lz_lossy_eb), lossy_inner=inner,
                mesh=self.lz_mesh, batch_axis=self.lz_batch_axis,
            )
        if self.lz_mesh is not None:
            backend = "sharded" if backend == "auto" else backend
            decoder = "sharded" if decoder == "auto" else decoder
        return lzss.LZSSConfig(
            symbol_size=symbol_size, window=self.lz_window,
            chunk_symbols=self.lz_chunk,
            chunks_per_block=self.lz_chunks_per_block, backend=backend,
            decoder=decoder, mesh=self.lz_mesh,
            batch_axis=self.lz_batch_axis,
        )

    def save(self, state, step: int) -> str:
        """Write one step.  Sync mode publishes before returning; async
        mode enqueues blobs group by group — the NEXT group's compression
        dispatch overlaps the previous group's host write — and returns
        once the commit op is queued (the step publishes in the
        background, in enqueue order)."""
        fs = self.fs
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        writer = None
        self.last_save_io_wait_s = 0.0
        if self.async_writes:
            # begin_step re-raises any prior background failure (the
            # surfaced-on-next-save contract) and blocks while the
            # double-buffer window is full (measured backpressure)
            writer = self._get_writer()
            self.last_save_io_wait_s = writer.begin_step(step)
        fs.makedirs(self.directory, exist_ok=True)
        if fs.exists(tmp):
            fs.rmtree(tmp)
        fs.makedirs(tmp)

        if writer is None:
            def emit(fname: str, data) -> None:
                path = os.path.join(tmp, fname)
                self.io_retry.run(lambda: fs.write_bytes(path, data))
        else:
            def emit(fname: str, data) -> None:
                writer.put_write(step, os.path.join(tmp, fname), data)

        names, leaves, _ = _leaf_paths(state)
        manifest = {"step": step, "leaves": []}
        entries, raws = [], []
        groups: dict = {}  # (S, chunk-count bucket) -> leaf indices
        for i, (name, leaf) in enumerate(zip(names, leaves)):
            arr = np.asarray(jax.device_get(leaf))
            raw = arr.tobytes()
            raws.append(raw)
            fname = name.replace("/", ".") or "scalar"
            entries.append({
                "name": name,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(raw),
                "nbytes": len(raw),
                "file": fname,
            })
            if self.compress and len(raw) >= 1024:
                lossy = (
                    self.lz_lossy_eb is not None
                    and arr.dtype == np.float32
                )
                s = _symbol_size(arr.dtype)
                nsym = -(-len(raw) // s)
                nc = -(-nsym // self.lz_chunk)
                # bucket by chunk count so a tiny leaf is never padded to a
                # huge leaf's geometry inside the shared batch
                bucket = 1 << max(0, nc - 1).bit_length()
                groups.setdefault((s, bucket, lossy), []).append(i)
            else:
                entries[i]["codec"] = "raw"
                entries[i]["stored_bytes"] = len(raw)
                entries[i]["file"] = fname + ".raw"
                emit(entries[i]["file"], raw)
        # one batched compression dispatch per dtype-class group; in async
        # mode each group's blobs are queued as soon as its dispatch
        # returns, so group k's host writes overlap group k+1's compression
        for (s, _bucket, lossy), idxs in groups.items():
            batch = lzss.compress_many(
                [np.frombuffer(raws[i], np.uint8) for i in idxs],
                self._lz_config(s, lossy=lossy),
            )
            for j, i in enumerate(idxs):
                res = batch[j]
                entries[i]["codec"] = "gpulz"
                entries[i]["stored_bytes"] = res.total_bytes
                entries[i]["file"] += ".gplz"
                if lossy:
                    # the restored bytes differ from `raw` by design, so the
                    # raw CRC cannot gate restore; CRC the stored container
                    # instead (still catches disk corruption before decode)
                    entries[i]["lossy"] = True
                    entries[i]["crc32"] = zlib.crc32(res.data.tobytes())
                emit(entries[i]["file"], res.data.tobytes())
        manifest["leaves"] = entries
        emit("manifest.json", json.dumps(manifest).encode())
        # the commit marker is written LAST: a crash at any earlier
        # boundary leaves a marker-less dir that steps()/restore/GC treat
        # as nonexistent debris
        emit(COMMIT_MARKER, b"")
        if writer is None:
            if fs.exists(final):
                fs.rmtree(final)
            self.io_retry.run(lambda: fs.rename(tmp, final))
            self._gc()
        else:
            writer.put_commit(step, tmp, final, after=self._gc)
        return final

    # ---------------------------------------------------------- restore

    def steps(self):
        """Committed steps only: a dir without its COMMIT marker (crash
        debris, a hand-planted partial, an in-flight async publish) is
        never listed and therefore never restorable."""
        fs = self.fs
        if not fs.isdir(self.directory):
            return []
        out = []
        for d in fs.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if not fs.exists(
                    os.path.join(self.directory, d, COMMIT_MARKER)
                ):
                    continue
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _load_step(self, template, step: int, shardings=None):
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_name = {e["name"]: e for e in manifest["leaves"]}
        names, leaves, treedef = _leaf_paths(template)
        sh_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None
            else [None] * len(leaves)
        )
        # batched restore: one decompression dispatch per container geometry
        blobs, geom_groups = {}, {}
        for name in names:
            e = by_name[name]
            if e["codec"] != "gpulz":
                continue
            blob = np.fromfile(os.path.join(d, e["file"]), np.uint8)
            if e.get("lossy") and zlib.crc32(blob.tobytes()) != e["crc32"]:
                # lossy leaves CRC the stored container (the raw bytes are
                # not reproduced bit-exactly); verify before decode
                raise IOError(f"CRC mismatch for {name} at step {step}")
            h = lzss.fmt.parse_header(blob)
            blobs[name] = blob
            # version + method byte join the batching key so a checkpoint
            # holding both lossless and lossy-fz leaves never lands a
            # mixed-method batch in one decompress_many call; lossy blobs
            # additionally split on their static decode params
            geom_groups.setdefault(
                (h.version, h.method, h.symbol_size, h.chunk_symbols,
                 h.n_chunks, h.lossy_mode, h.inner_method), []
            ).append(name)
        decompressed = {}
        # an explicitly non-sharded lz_decoder + lz_mesh means compress-side
        # sharding only: restore single-device rather than conflicting
        sharded = self.lz_decoder in ("auto", "sharded")
        method_only = {
            lzss.fmt.METHOD_HUFFMAN: "deflate-full",
            lzss.fmt.METHOD_LOSSY: "lossy-fz",
        }
        for gkey, group in geom_groups.items():
            decoder = self.lz_decoder
            if decoder not in ("auto", "sharded") and decoder != \
                    method_only.get(gkey[1]) and (
                        decoder in method_only.values()
                        or gkey[1] in method_only
                    ):
                # decoder/method mismatch (e.g. a raw-method decoder pinned
                # while this group is lossy): fall back per group — the
                # container's method byte routes to the right decoder
                decoder = "auto"
            raws = lzss.decompress_many(
                [blobs[n] for n in group], decoder=decoder,
                mesh=self.lz_mesh if sharded else None,
                batch_axis=self.lz_batch_axis if sharded else None,
                # the pin governs restore kernels too, not just save
                chunks_per_block=self.lz_chunks_per_block,
            )
            decompressed.update(
                {n: r.tobytes() for n, r in zip(group, raws)}
            )
        out = []
        for name, tmpl, sh in zip(names, leaves, sh_leaves):
            e = by_name[name]
            if e["codec"] == "gpulz":
                raw = decompressed[name]
            else:
                with open(os.path.join(d, e["file"]), "rb") as f:
                    raw = f.read()
            if not e.get("lossy") and zlib.crc32(raw) != e["crc32"]:
                raise IOError(f"CRC mismatch for {name} at step {step}")
            arr = np.frombuffer(raw, e["dtype"]).reshape(e["shape"])
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]

    def restore(self, template, step: int, shardings=None):
        return self._load_step(template, step, shardings)

    def restore_latest(self, template, shardings=None):
        """Walk back from the newest step until one restores cleanly."""
        for step in reversed(self.steps()):
            try:
                return self._load_step(template, step, shardings)
            except Exception as exc:  # damaged shard/manifest — try older
                print(f"[ckpt] step {step} unusable ({exc}); trying older")
        return None, -1

    def _gc(self):
        """Retention GC, commit-marker- and in-flight-aware.

        * only COMMITTED steps count toward ``keep`` (a half-written step
          never blocks GC of older complete ones);
        * a step the async writer still owns — registered but not yet
          renamed — is never deleted, nor is its staging dir;
        * marker-less ``step_*`` dirs and stale ``*.tmp`` dirs (crash
          debris) are swept once no writer owns them.

        Runs on the worker thread after each async commit (FIFO queue =
        the rename happened-before this GC) and inline after sync saves.
        """
        fs = self.fs
        if not fs.isdir(self.directory):
            return
        inflight = (
            self.writer.in_flight() if self.writer is not None else set()
        )
        protected = set()
        for s in inflight:
            protected.add(f"step_{s:08d}")
            protected.add(f"step_{s:08d}.tmp")
        for s in self.steps()[: -self.keep]:
            name = f"step_{s:08d}"
            if name in protected:
                continue
            fs.rmtree(os.path.join(self.directory, name), ignore_errors=True)
        for d in fs.listdir(self.directory):
            if not d.startswith("step_") or d in protected:
                continue
            path = os.path.join(self.directory, d)
            if not fs.isdir(path):
                continue
            if d.endswith(".tmp") or not fs.exists(
                os.path.join(path, COMMIT_MARKER)
            ):
                fs.rmtree(path, ignore_errors=True)

    def stats(self, step: int) -> dict:
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        orig = sum(e["nbytes"] for e in manifest["leaves"])
        stored = sum(e["stored_bytes"] for e in manifest["leaves"])
        return {
            "orig_bytes": orig,
            "stored_bytes": stored,
            "ratio": orig / max(1, stored),
        }
