"""Surrogates for the paper's six evaluation datasets.

The TPC-H / SDRBench files are not redistributable offline, so each surrogate
is generated to match the *statistical character* that drives LZSS behaviour
(symbol width, smoothness -> quant-code redundancy, run structure).  The
paper's measured ratios at the default config (C=2048, S=2, W=128) are kept
next to each generator as calibration targets; benchmarks print both.

  dataset        paper CR (S=2, W=128, C=2048)   type
  hurr-quant     4.91                            uint16 quant codes
  hacc-quant     1.97                            uint16 quant codes
  nyx-quant      7.19                            uint16 quant codes
  tpch-int32     1.34                            int32 columns
  tpch-string    2.43                            utf-8 text
  rtm-float32    2.84                            float32 field
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import quant

PAPER_RATIOS_DEFAULT = {
    "hurr-quant": 4.91,
    "hacc-quant": 1.97,
    "nyx-quant": 7.19,
    "tpch-int32": 1.34,
    "tpch-string": 2.43,
    "rtm-float32": 2.84,
}


def _quant_codes(field: np.ndarray, rel_eb: float, ndim: int) -> np.ndarray:
    eb = quant.relative_error_bound(field, rel_eb)
    q = quant.quantize(jnp.asarray(field), error_bound=eb, ndim=ndim)
    return np.asarray(q.codes)


def _hurr_raw_field(n: int, seed: int = 0) -> np.ndarray:
    """The smooth weather field both hurr surrogates derive from."""
    side = int(np.sqrt(n))
    rng = np.random.default_rng(seed)
    y, x = np.mgrid[0:side, 0:side].astype(np.float32) / side
    return (
        np.sin(6 * np.pi * x) * np.cos(4 * np.pi * y) * 30
        + np.cumsum(rng.normal(0, 0.1, (side, side)).astype(np.float32),
                    axis=1)
    )


def hurr_quant(nbytes: int = 1 << 22, seed: int = 0) -> np.ndarray:
    """Weather-field quant codes: smooth 2D with fronts (moderate runs)."""
    n = nbytes // 2
    field = _hurr_raw_field(n, seed)
    return _quant_codes(field, 1e-3, 2).reshape(-1)[:n]


def hurr_field(nbytes: int = 1 << 22, seed: int = 0) -> np.ndarray:
    """The hurr surrogate's pre-quantization float32 field — the natural
    input for the error-bounded lossy frontend (benchmarks/fig_lossy.py),
    where quantization happens INSIDE the codec against a caller-chosen
    bound instead of up front at a fixed one."""
    n = nbytes // 4
    field = _hurr_raw_field(n, seed).reshape(-1)
    pad = np.zeros(max(0, n - field.size), np.float32)
    return np.concatenate([field, pad])[:n].view(np.uint8)


def hacc_quant(nbytes: int = 1 << 22, seed: int = 1) -> np.ndarray:
    """Cosmology-particle quant codes: rough, short-run redundancy (the
    paper's lowest-ratio quant dataset, ~2x at S=2/W=128)."""
    n = nbytes // 2
    rng = np.random.default_rng(seed)
    # particle coords: ~half the samples sit in tiny clusters (short runs of
    # equal codes), the rest jump randomly — short-run redundancy only
    base = rng.uniform(0, 1, n).astype(np.float32)
    repeat = rng.random(n) < 0.68
    repeat[0] = False
    idx = np.where(repeat, 0, np.arange(n))
    idx = np.maximum.accumulate(idx)   # forward-fill to cluster anchors
    field = base[idx]
    return _quant_codes(field, 1e-3, 1)[:n]


def nyx_quant(nbytes: int = 1 << 22, seed: int = 2) -> np.ndarray:
    """Cosmology-grid quant codes: very smooth 3D -> long runs."""
    n = nbytes // 2
    side = max(8, int(round(n ** (1 / 3))))
    z, y, x = np.mgrid[0:side, 0:side, 0:side].astype(np.float32) / side
    field = (
        np.sin(2 * np.pi * x) * np.sin(2 * np.pi * y) * np.sin(2 * np.pi * z)
    ) * 100 + 3 * x * y
    return _quant_codes(field, 1e-3, 3).reshape(-1)[:n]


def tpch_int32(nbytes: int = 1 << 22, seed: int = 3) -> np.ndarray:
    """Business columns: keys/dates/quantities, low run redundancy."""
    n = nbytes // 4
    rng = np.random.default_rng(seed)
    cols = [
        rng.integers(1, 200_000, n // 4),          # orderkey-ish (random)
        rng.integers(0, 2526, n // 4) + 728_000,   # dates (narrow range)
        rng.integers(1, 51, n // 4),               # quantity (small ints)
        (rng.integers(90_000, 105_000, n // 4)),   # extended price
    ]
    arr = np.concatenate(cols).astype(np.int32)
    return arr.view(np.uint8).reshape(-1)[: n * 4].view(np.uint8)


_WORDS = (
    "the of and to in a is that for it as was with be by on not he i this are "
    "or his from at which but have an had they you were their one all we can "
    "her has there been if more when will would who so no said what up its "
    "about into than them only other time new some could these two may then do"
).split()


def tpch_string(nbytes: int = 1 << 22, seed: int = 4) -> np.ndarray:
    """Comment-style text: zipfian words, repeated phrases."""
    rng = np.random.default_rng(seed)
    ranks = np.minimum(rng.zipf(1.5, nbytes // 4), len(_WORDS)) - 1
    words = [_WORDS[r] for r in ranks]
    text = " ".join(words).encode()[:nbytes]
    return np.frombuffer(text, np.uint8)


def rtm_float32(nbytes: int = 1 << 22, seed: int = 5) -> np.ndarray:
    """Seismic wavefield: raw float32 — quiet zones (exact zeros) between
    repeating source wavelets, like pre-stack RTM snapshots (~2.9x at S=4)."""
    n = nbytes // 4
    rng = np.random.default_rng(seed)
    wavelet = (np.sin(np.linspace(0, 4 * np.pi, 48))
               * np.hanning(48) * 100).astype(np.float32)
    out = np.zeros(n, np.float32)
    pos = 0
    while pos + 64 < n:
        amp = np.float32(2.0 ** rng.integers(-2, 3))  # exact-pow2 scaling
        out[pos : pos + 48] = wavelet * amp           # keeps bit patterns
        pos += 48 + int(rng.integers(16, 96))         # quiet gap
    return out.view(np.uint8)


DATASETS = {
    "hurr-quant": (hurr_quant, np.uint16),
    "hurr-field": (hurr_field, np.float32),
    "hacc-quant": (hacc_quant, np.uint16),
    "nyx-quant": (nyx_quant, np.uint16),
    "tpch-int32": (tpch_int32, np.int32),
    "tpch-string": (tpch_string, np.uint8),
    "rtm-float32": (rtm_float32, np.float32),
}


def load(name: str, nbytes: int = 1 << 22) -> np.ndarray:
    gen, _ = DATASETS[name]
    out = gen(nbytes)
    return np.ascontiguousarray(out).view(np.uint8).reshape(-1)
