"""Deterministic, resumable data pipeline.

Production posture: every batch is a pure function of (seed, step), so
restart-from-checkpoint resumes the stream exactly (no iterator state to
persist), and each host materializes only its addressable shard
(``make_batch_for_step`` -> host-local numpy -> device_put with the batch
sharding).  Sources: synthetic LM token streams (zipfian n-gram mixture, so
compression/benchmark paths see realistic redundancy) or a memory-mapped
token file.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"   # synthetic | mmap
    path: str = ""              # for mmap


def _rng_for(cfg: DataConfig, step: int):
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xC0FFEE])
    )


def synthetic_tokens(cfg: DataConfig, step: int) -> np.ndarray:
    """Zipf-ish LM stream with repeated n-grams (so LZ paths see structure)."""
    rng = _rng_for(cfg, step)
    b, t = cfg.global_batch, cfg.seq_len
    # zipf over a capped vocab; repeat phrases to create spatial redundancy
    base = rng.zipf(1.3, size=(b, t)).astype(np.int64)
    toks = (base % cfg.vocab_size).astype(np.int32)
    span = min(32, t // 2)
    if span:
        for _ in range(max(1, t // 256)):
            src = rng.integers(0, t - span + 1)
            dst = rng.integers(0, t - span + 1)
            toks[:, dst : dst + span] = toks[:, src : src + span]
    return toks


def mmap_tokens(cfg: DataConfig, step: int) -> np.ndarray:
    data = np.memmap(cfg.path, dtype=np.int32, mode="r")
    b, t = cfg.global_batch, cfg.seq_len
    n_batches = max(1, (data.size - 1) // (b * t))
    off = (step % n_batches) * b * t
    return np.array(data[off : off + b * t]).reshape(b, t)


def make_batch_for_step(cfg: DataConfig, step: int) -> dict:
    toks = (
        synthetic_tokens(cfg, step)
        if cfg.source == "synthetic"
        else mmap_tokens(cfg, step)
    )
    return {"tokens": toks}


class Prefetcher:
    """One-step lookahead prefetch (compute/data overlap on real systems)."""

    def __init__(self, cfg: DataConfig, start_step: int, shardings=None):
        self.cfg = cfg
        self.shardings = shardings
        self._next_step = start_step
        self._buf = self._load(start_step)

    def _load(self, step):
        batch = make_batch_for_step(self.cfg, step)
        if self.shardings is not None:
            batch = {
                k: jax.device_put(v, self.shardings[k])
                for k, v in batch.items()
            }
        return batch

    def next(self):
        out = self._buf
        self._next_step += 1
        self._buf = self._load(self._next_step)
        return out
