"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (host) devices exist — for tests."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
