"""Batched serving driver (reference engine over decode_step).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --batch 4 --prompt-len 16 --new-tokens 32 --kv-compress
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro import configs
from repro.launch import steps
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--kv-compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = configs.reduced_config(cfg)
    from repro.configs.base import TrainConfig

    params = steps.init_train_state(cfg, TrainConfig(), args.seed)["params"]
    engine = ServingEngine(cfg, params, max_len=args.max_len,
                           kv_compress=args.kv_compress)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(
        0, cfg.vocab_size, size=(args.batch, args.prompt_len)
    ).astype(np.int32)
    t0 = time.time()
    result = engine.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"generated {result.tokens.shape} in {dt:.2f}s "
          f"({result.steps * args.batch / dt:.1f} tok/s)")
    print("first sequence:", result.tokens[0][: args.prompt_len + 8].tolist())
    if args.kv_compress and engine.kv_store.stats.evictions:
        print("kv eviction ratio:", engine.kv_store.stats.eviction_ratio)


if __name__ == "__main__":
    main()
