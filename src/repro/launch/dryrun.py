import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede every other import (jax pins the device
count at first init).  For each cell this driver:

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds the step function (train / prefill / decode) with its shardings,
  3. ``.lower(**ShapeDtypeStruct inputs).compile()`` — no allocation,
  4. records memory_analysis(), cost_analysis(), and the HLO collective
     schedule into results/dryrun/<cell>.json for the roofline report.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.configs.base import TrainConfig, CompressionConfig
from repro.launch import mesh as mesh_lib, roofline, steps
from repro.models import model as model_lib


def _mem_dict(compiled):
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _compile_step(cfg, shape, mesh, traincfg, compressed, unroll=False):
    """Build + lower + compile one step; returns the compiled object."""
    if shape.kind == "train":
        tc = traincfg
        if unroll:
            import dataclasses

            tc = dataclasses.replace(traincfg, unroll_layers=True)
        jfn, _, _ = steps.make_train_step(cfg, tc, mesh, shape,
                                          compressed=compressed)
        return jfn.lower(
            steps.abstract_train_state(cfg, tc),
            model_lib.input_specs(cfg, shape),
        ).compile()
    if shape.kind == "prefill":
        jfn, _, _ = steps.make_prefill_step(cfg, mesh, shape, unroll=unroll)
        return jfn.lower(
            model_lib.abstract_params(cfg), model_lib.input_specs(cfg, shape)
        ).compile()
    jfn, _, _, _ = steps.make_decode_step(cfg, mesh, shape)
    ab_cache = model_lib.abstract_cache(cfg, shape.global_batch, shape.seq_len)
    return jfn.lower(
        model_lib.abstract_params(cfg), ab_cache,
        model_lib.input_specs(cfg, shape),
    ).compile()


def _cost_of(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = roofline.collective_bytes(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
        "hlo_bytes": len(hlo),
    }


def _extrapolated_cost(cfg, shape, mesh, traincfg, compressed):
    """Exact per-step cost via unrolled L=1 / L=2 lowers.

    XLA's cost_analysis counts while-loop bodies once (not x trip count), so
    the scanned production module under-reports.  Costs are linear in layer
    count, so two small unrolled lowers give base + L x marginal exactly.
    Attention is lowered un-blocked during these lowers (identical FLOPs,
    loop-free counting).
    """
    import dataclasses

    from repro.models import attention as attn_mod

    attn_mod.UNROLL_BLOCKS = True  # python q-block loop: exact counting
    try:
        costs = {}
        for nl in (1, 2):
            c = dataclasses.replace(cfg, num_layers=nl,
                                    global_attn_layers=())
            compiled = _compile_step(c, shape, mesh, traincfg, compressed,
                                     unroll=True)
            costs[nl] = _cost_of(compiled)
            del compiled
    finally:
        attn_mod.UNROLL_BLOCKS = False
    out = {}
    for key in ("flops", "bytes"):
        marginal = costs[2][key] - costs[1][key]
        out[key] = costs[1][key] + (cfg.num_layers - 1) * marginal
    coll = {}
    for k, v1 in costs[1]["coll"].items():
        v2 = costs[2]["coll"][k]
        # clamp: a collective that only appears in the L-independent base
        # must not extrapolate negative
        coll[k] = max(v1 + (cfg.num_layers - 1) * (v2 - v1), 0)
    coll["total"] = sum(
        coll[k] for k in coll if k not in ("total", "count")
    )
    out["coll"] = coll
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               compressed: bool = False, microbatches: int = 1,
               remat: str = "full", zero_opt: bool = True,
               fsdp: str = "on", seq_parallel: bool = False,
               kv_quant: bool = False):
    """Lower+compile one cell; returns the result record (no allocation).

    Two artifacts per cell:
      * the production scanned module — compile proof + memory analysis;
      * an unrolled L=1/L=2 cost extrapolation — exact FLOPs/bytes/coll.
    Decode cells are already unrolled; their direct costs are exact.
    """
    cfg = configs.get_config(arch)
    if kv_quant:
        import dataclasses

        cfg = dataclasses.replace(cfg, kv_quant=True)
    shape = configs.get_shape(shape_name)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    traincfg = TrainConfig(
        microbatches=microbatches,
        remat=remat,
        zero_opt_state=zero_opt,
        fsdp=fsdp,
        seq_parallel=seq_parallel,
        compression=CompressionConfig(grad_cross_pod=compressed),
    )
    t0 = time.time()
    compiled = _compile_step(cfg, shape, mesh, traincfg, compressed)
    t_compile = time.time() - t0
    direct = _cost_of(compiled)
    mem = _mem_dict(compiled)
    del compiled

    if shape.kind == "decode":
        cost = direct
    else:
        cost = _extrapolated_cost(cfg, shape, mesh, traincfg, compressed)
        cost["hlo_bytes"] = direct["hlo_bytes"]

    mesh_name = "2x16x16" if multi_pod else "16x16"
    rl = roofline.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=cost["flops"],
        bytes_per_device=cost["bytes"],
        coll_bytes_per_device=float(cost["coll"]["total"]),
        model_flops=roofline.model_flops_for(cfg, shape),
        coll_breakdown=cost["coll"],
    )
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips,
        "compressed_grads": compressed,
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "flops_per_device": rl.flops_per_device,
        "bytes_per_device": rl.bytes_per_device,
        "collectives": rl.coll_breakdown,
        "roofline": rl.row(),
        "hlo_bytes": cost.get("hlo_bytes", direct["hlo_bytes"]),
        "direct_scanned_cost": {
            "flops": direct["flops"], "bytes": direct["bytes"],
            "coll_total": direct["coll"]["total"],
        },
    }
    return record, None


def run_cell(arch, shape_name, multi_pod, out_dir, compressed=False,
             skip_existing=False, **kw):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    suffix = "__lzgrad" if compressed else ""
    for k, v in sorted(kw.items()):
        defaults = {"microbatches": 1, "remat": "full", "zero_opt": True,
                    "fsdp": "on", "seq_parallel": False, "kv_quant": False}
        if k in defaults and v != defaults[k]:
            suffix += f"__{k}-{v}"
    path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    )
    if skip_existing and os.path.exists(path):
        print(f"[skip] {path}")
        return True
    if not configs.cell_is_runnable(arch, shape_name):
        print(f"[skip-by-design] {arch} x {shape_name} (full attention @500k)")
        return True
    try:
        record, compiled = lower_cell(arch, shape_name, multi_pod,
                                      compressed=compressed, **kw)
        del compiled
    except Exception as e:
        print(f"[FAIL] {arch} x {shape_name} x {mesh_name}: {e}")
        traceback.print_exc()
        return False
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    r = record["roofline"]
    print(
        f"[ok] {arch:24s} {shape_name:12s} {mesh_name:8s} "
        f"compile={record['compile_s']:7.1f}s "
        f"comp={r['compute_s']:.3e}s mem={r['memory_s']:.3e}s "
        f"coll={r['collective_s']:.3e}s dom={r['dominant']:10s} "
        f"frac={r['roofline_fraction']:.3f}"
    )
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--compressed-grads", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--fsdp", default="on", choices=["on", "off", "auto"])
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = (
        list(configs.all_cells())
        if args.all
        else [(args.arch, args.shape)]
    )
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_fail = 0
    for arch, shape_name in cells:
        for mp in meshes:
            ok = run_cell(
                arch, shape_name, mp, args.out,
                compressed=args.compressed_grads,
                skip_existing=args.skip_existing,
                microbatches=args.microbatches,
                remat=args.remat,
                fsdp=args.fsdp,
                seq_parallel=args.seq_parallel,
                kv_quant=args.kv_quant,
            )
            n_fail += 0 if ok else 1
    print(f"done; failures={n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
