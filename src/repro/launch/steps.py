"""Step functions (train / prefill / decode) + their sharded jit builders.

These are the objects the dry-run lowers and the launcher executes:

  * ``train_step``            — fwd+bwd+AdamW, optional microbatch accumulation
                                (per-microbatch grads reduce inside the scan —
                                latency-hiding-scheduler friendly).
  * ``train_step_compressed`` — same, but the pod-axis gradient exchange is
                                GPULZ-compressed inside shard_map(pod) —
                                the paper's communication use case.
  * ``prefill_step`` / ``decode_step`` — serving paths.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import model as model_lib, transformer
from repro.optim import adamw, grad_compress
from repro.sharding import rules


# ------------------------------------------------------------- train state


def init_train_state(cfg, traincfg, seed: int = 0):
    params = model_lib.init_params(cfg, seed)
    return {
        "params": params,
        "opt": adamw.init_opt_state(params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_train_state(cfg, traincfg):
    return jax.eval_shape(functools.partial(init_train_state, cfg, traincfg))


def train_state_shardings(cfg, traincfg, mesh):
    axes = model_lib.param_axes(cfg)
    p_sh = rules.params_shardings(axes, mesh)
    ab = model_lib.abstract_params(cfg)
    if traincfg.zero_opt_state:
        o_sh = rules.zero_shardings(axes, ab, mesh)
    else:
        o_sh = p_sh
    return {
        "params": p_sh,
        "opt": {"m": o_sh, "v": o_sh},
        "step": NamedSharding(mesh, P()),
    }


def batch_shardings(cfg, shape, mesh):
    bs = rules.batch_spec(mesh, shape.global_batch)
    specs = model_lib.input_specs(cfg, shape)
    out = {}
    for k, s in specs.items():
        if k == "pos":
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = NamedSharding(mesh, P(*(list(bs) + [None] * (len(s.shape) - 1))))
    return out


# ------------------------------------------------------------------- train


def _compute_specs(cfg, traincfg):
    """FSDP per-layer weight-gather specs (None when FSDP is off)."""
    if not fsdp_decision(cfg, traincfg):
        return None
    axes = model_lib.param_axes(cfg)
    return {"layers": rules.compute_specs_tree(axes["layers"], drop_leading=1)}


def _grads_and_metrics(params, cfg, traincfg, batch):
    specs = _compute_specs(cfg, traincfg)
    loss_fn = lambda p, b: transformer.loss_fn(
        p, cfg, b, remat=traincfg.remat, unroll=traincfg.unroll_layers,
        compute_specs=specs,
    )
    if traincfg.microbatches > 1:
        m = traincfg.microbatches
        micro = jax.tree.map(
            lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch
        )

        def acc(carry, mb):
            g_acc, l_acc = carry
            (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb
            )
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            return (g_acc, l_acc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
        (g, loss), _ = jax.lax.scan(acc, (zeros, 0.0), micro)
        g = jax.tree.map(lambda x: x / m, g)
        return g, {"loss": loss / m}
    (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    return g, metrics


def train_step(state, batch, *, cfg, traincfg):
    grads, metrics = _grads_and_metrics(state["params"], cfg, traincfg, batch)
    new_p, new_opt, opt_metrics = adamw.adamw_update(
        state["params"], grads, state["opt"], state["step"], traincfg
    )
    metrics = {**metrics, **opt_metrics}
    return (
        {"params": new_p, "opt": new_opt, "step": state["step"] + 1},
        metrics,
    )


def train_step_compressed(state, batch, *, cfg, traincfg, mesh):
    """Train step with GPULZ-compressed pod-axis gradient exchange.

    Per-pod gradients come from vmap over a pod-split batch dim (no cross-pod
    reduction in the backward pass); the only inter-pod traffic is the
    all-gather of the fixed-size compressed wire inside
    ``pod_exchange_compressed``.
    """
    n_pods = mesh.shape["pod"]

    def pod_grads(mb):
        return _grads_and_metrics(state["params"], cfg, traincfg, mb)

    # "auto" backend/decoder resolve per-platform inside the pipeline
    # (on TPU: the single-kernel fused-mono compressor + fused Pallas
    # decoder)
    lz_cfg = dataclasses.replace(
        grad_compress.GRAD_LZ,
        backend=traincfg.compression.lz_backend,
        decoder=traincfg.compression.lz_decoder,
    )
    batch_pods = jax.tree.map(
        lambda x: x.reshape(n_pods, x.shape[0] // n_pods, *x.shape[1:]), batch
    )
    batch_pods = jax.lax.with_sharding_constraint(
        batch_pods,
        jax.tree.map(
            lambda x: NamedSharding(
                mesh, P(*(("pod", "data") + (None,) * (x.ndim - 2)))
            ),
            batch_pods,
        ),
    )
    grad_stack, metrics = jax.vmap(pod_grads, spmd_axis_name="pod")(batch_pods)
    grads = grad_compress.pod_exchange_compressed(
        grad_stack, mesh,
        compress=traincfg.compression.grad_cross_pod,
        cfg=lz_cfg,
        ratio_cap=traincfg.compression.grad_ratio_cap,
        # error-bounded lossy gradients (optimizer state stays lossless:
        # adamw_update below sees only the reconstructed f32 gradients)
        lossy_eb=traincfg.compression.lossy_eb,
    )
    new_p, new_opt, opt_metrics = adamw.adamw_update(
        state["params"], grads, state["opt"], state["step"], traincfg
    )
    metrics = jax.tree.map(jnp.mean, metrics)
    metrics = {**metrics, **opt_metrics}
    return (
        {"params": new_p, "opt": new_opt, "step": state["step"] + 1},
        metrics,
    )


# ------------------------------------------------------------------ serve


def prefill_step(params, batch, *, cfg, unroll=False, compute_specs=None):
    return transformer.prefill(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        unroll=unroll, compute_specs=compute_specs,
    )


def decode_step(params, caches, batch, *, cfg):
    logits, caches = transformer.decode_step(
        params, cfg, caches, batch["tokens"], batch["pos"]
    )
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tok, caches


def paged_decode_step(params, paged, batch, *, cfg):
    logits, paged = transformer.decode_step_paged(
        params, cfg, paged, batch["tokens"], batch["pos"]
    )
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tok, paged


# ----------------------------------------------------------- jit builders


FSDP_AUTO_THRESHOLD = 25e9  # params; above this, weights must shard on data


def fsdp_decision(cfg, traincfg) -> bool:
    if traincfg.fsdp == "on":
        return True
    if traincfg.fsdp == "off":
        return False
    return cfg.param_count(padded=True) > FSDP_AUTO_THRESHOLD


def _set_mesh_context(mesh, batch_axes=None, fsdp=True, seq_parallel=False):
    """Install the mesh so bare-PartitionSpec sharding hints resolve."""
    try:
        jax.sharding.set_mesh(mesh)
    except Exception:
        pass
    if batch_axes is None:
        batch_axes = rules.batch_axes(mesh)
    shards = 1
    for a in batch_axes:
        if a in mesh.shape:
            shards *= mesh.shape[a]
    rules.set_activation_batch_axes(batch_axes, data_shards=shards)
    rules.set_fsdp(fsdp)
    rules.set_seq_parallel(seq_parallel)


def make_train_step(cfg, traincfg, mesh, shape, compressed: bool = False):
    """Returns (jitted_fn, state_shardings, batch_shardings)."""
    # compressed step vmaps over the pod axis (spmd_axis_name supplies it);
    # inner activation constraints then use "data" only.
    _set_mesh_context(
        mesh,
        batch_axes=("data",) if compressed else None,
        fsdp=fsdp_decision(cfg, traincfg),
        seq_parallel=traincfg.seq_parallel,
    )
    st_sh = train_state_shardings(cfg, traincfg, mesh)
    b_sh = batch_shardings(cfg, shape, mesh)
    if compressed:
        fn = functools.partial(
            train_step_compressed, cfg=cfg, traincfg=traincfg, mesh=mesh
        )
        # shard_map handles its own specs; jit still pins the boundary
        jfn = jax.jit(
            fn, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None)
        )
    else:
        fn = functools.partial(train_step, cfg=cfg, traincfg=traincfg)
        jfn = jax.jit(
            fn,
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, None),
            donate_argnums=(0,),
        )
    return jfn, st_sh, b_sh


def cache_shardings(cfg, batch_size, mesh):
    """KV/state caches: batch on data axis, heads on model axis."""
    bs = rules.batch_spec(mesh, batch_size)
    b0 = bs if len(bs) else P(None)

    def one(path_leaf):
        leaf = path_leaf
        nd = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
        if nd >= 3:  # (B, T, heads-ish, ...) or (B, H, N, P)
            spec = list(b0) + [None, "model"] + [None] * (nd - 3)
            return NamedSharding(mesh, P(*spec))
        if nd >= 1:
            return NamedSharding(mesh, P(*([None] * nd)))
        return NamedSharding(mesh, P())

    return one


def make_decode_step(cfg, mesh, shape):
    _set_mesh_context(mesh)
    b = shape.global_batch
    ab_cache = model_lib.abstract_cache(cfg, b, shape.seq_len)
    sh_fn = cache_shardings(cfg, b, mesh)
    cache_sh = jax.tree.map(sh_fn, ab_cache)
    p_sh = rules.params_shardings(model_lib.param_axes(cfg), mesh)
    bs = rules.batch_spec(mesh, b)
    b_sh = {
        "tokens": NamedSharding(mesh, bs),
        "pos": NamedSharding(mesh, P()),
    }
    fn = functools.partial(decode_step, cfg=cfg)
    jfn = jax.jit(
        fn,
        in_shardings=(p_sh, cache_sh, b_sh),
        out_shardings=(b_sh["tokens"], cache_sh),
        donate_argnums=(1,),
    )
    return jfn, p_sh, cache_sh, b_sh


def make_paged_decode_step(cfg, mesh, shape, *, block_tokens,
                           pool_blocks=None):
    """Compiled twin of decode over the paged (block-table) KV cache.

    The physical pool shards its KV-head axis on "model" (same head split as
    the dense cache); block tables are tiny int32 host-authored state and
    stay replicated.  Paged state is donated so the pool updates in place.
    """
    _set_mesh_context(mesh)
    b = shape.global_batch
    ab = model_lib.abstract_paged_cache(
        cfg, b, shape.seq_len, block_tokens=block_tokens,
        pool_blocks=pool_blocks,
    )
    pool_sh = NamedSharding(mesh, P(None, None, "model", None))
    repl = NamedSharding(mesh, P())
    paged_sh = {
        "pool": {"k": pool_sh, "v": pool_sh},
        "tables": repl,
        "extra": jax.tree.map(lambda _: repl, ab["extra"]),
    }
    p_sh = rules.params_shardings(model_lib.param_axes(cfg), mesh)
    bs = rules.batch_spec(mesh, b)
    b_sh = {
        "tokens": NamedSharding(mesh, bs),
        "pos": NamedSharding(mesh, P()),
    }
    fn = functools.partial(paged_decode_step, cfg=cfg)
    jfn = jax.jit(
        fn,
        in_shardings=(p_sh, paged_sh, b_sh),
        out_shardings=(b_sh["tokens"], paged_sh),
        donate_argnums=(1,),
    )
    return jfn, p_sh, paged_sh, b_sh


def make_prefill_step(cfg, mesh, shape, unroll=False):
    _set_mesh_context(mesh)
    p_sh = rules.params_shardings(model_lib.param_axes(cfg), mesh)
    b_sh = batch_shardings(cfg, shape, mesh)
    specs = {"layers": rules.compute_specs_tree(
        model_lib.param_axes(cfg)["layers"], drop_leading=1)}
    fn = functools.partial(prefill_step, cfg=cfg, unroll=unroll,
                           compute_specs=specs)
    logits_sh = NamedSharding(mesh, rules.batch_spec(mesh, shape.global_batch))
    jfn = jax.jit(fn, in_shardings=(p_sh, b_sh), out_shardings=logits_sh)
    return jfn, p_sh, b_sh
