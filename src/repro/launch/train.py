"""End-to-end training driver.

Production loop shape: resumable (checkpoint manager + pure-function-of-step
data), guarded (straggler watchdog + restart supervisor), compressed
checkpoints, optional compressed cross-pod gradient exchange.

On this CPU container it trains reduced configs (examples/train_tiny_lm.py
drives a ~100M-param config); on a real slice the same loop runs the full
archs — only the mesh constructor differs.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import CompressionConfig, ShapeConfig, TrainConfig
from repro.data.pipeline import DataConfig, Prefetcher
from repro.launch import mesh as mesh_lib, steps
from repro.runtime.fault import StepGuard


def build(args):
    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = configs.reduced_config(cfg)
    if args.d_model:
        cfg = dataclasses.replace(
            cfg,
            d_model=args.d_model,
            d_ff=args.d_ff or (4 * args.d_model),
            num_layers=args.layers or cfg.num_layers,
        )
    n_dev = len(jax.devices())
    model_par = 1 if args.reduced else min(n_dev, 2)
    mesh = mesh_lib.make_host_mesh(
        data=max(1, n_dev // model_par), model=model_par
    )
    traincfg = TrainConfig(
        total_steps=args.steps,
        warmup_steps=max(1, args.steps // 10),
        learning_rate=args.lr,
        microbatches=args.microbatches,
        compression=CompressionConfig(grad_cross_pod=False),
    )
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    return cfg, traincfg, mesh, shape


def train_loop(args):
    cfg, traincfg, mesh, shape = build(args)
    jfn, st_sh, b_sh = steps.make_train_step(cfg, traincfg, mesh, shape)
    mgr = CheckpointManager(
        args.ckpt_dir, compress=True,
        async_writes=bool(args.async_ckpt),
    ) if args.ckpt_dir else None
    guard = StepGuard(heartbeat_path=args.heartbeat)

    state = None
    start_step = 0
    if mgr is not None and mgr.steps():
        template = steps.abstract_train_state(cfg, traincfg)
        state, start_step = mgr.restore_latest(template, st_sh)
        if state is not None:
            print(f"[train] resumed from step {start_step}")
    if state is None:
        state = jax.device_put(steps.init_train_state(cfg, traincfg,
                                                      traincfg.seed), st_sh)
        start_step = 0

    dc = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
        global_batch=shape.global_batch, seed=traincfg.seed,
    )
    prefetch = Prefetcher(dc, start_step, shardings=b_sh)
    losses = []
    io_wait = 0.0  # ckpt I/O block time since the last observe
    for step in range(start_step, traincfg.total_steps):
        batch = prefetch.next()
        t0 = time.time()
        state, metrics = jfn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        # io_wait is the previous save's stall: the async writer's enqueue
        # backpressure (or the full write time in sync mode), accounted by
        # StepGuard as its own straggler axis, never the compute EWMA
        slow = guard.observe(step, dt, io_wait_s=io_wait)
        io_wait = 0.0
        losses.append(loss)
        if step % args.log_every == 0 or step == traincfg.total_steps - 1:
            tok_s = shape.global_batch * shape.seq_len / dt
            print(
                f"step {step:5d} loss {loss:8.4f} "
                f"gnorm {float(metrics['grad_norm']):7.3f} "
                f"lr {float(metrics['lr']):.2e} {dt*1e3:7.1f} ms "
                f"({tok_s:,.0f} tok/s){' [straggler]' if slow else ''}"
            )
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            ts = time.time()
            mgr.save(state, step + 1)
            io_wait = (
                mgr.last_save_io_wait_s if args.async_ckpt
                else time.time() - ts
            )
        if guard.should_restart:
            raise RuntimeError("straggler watchdog tripped")
    if mgr is not None:
        mgr.save(state, traincfg.total_steps)
        mgr.wait_until_finished()  # drain async writes before reporting
        print("[train] final checkpoint:", mgr.stats(traincfg.total_steps))
        if args.async_ckpt:
            ws = mgr.writer_stats()
            print(
                f"[train] async writer: {ws.get('writes', 0)} writes, "
                f"{ws.get('commits', 0)} commits, "
                f"{ws.get('blocked_s', 0.0)*1e3:.1f} ms backpressure; "
                f"io stalls {guard.stats.io_stalls}"
            )
    print(
        f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} over "
        f"{len(losses)} steps"
    )
    return np.array(losses)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--async-ckpt", action="store_true",
                    help="double-buffered background checkpoint writes "
                         "(runtime/async_io.py); save() stops stalling the "
                         "step on host I/O")
    ap.add_argument("--heartbeat", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    train_loop(args)


if __name__ == "__main__":
    main()
