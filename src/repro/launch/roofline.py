"""Roofline-term extraction from compiled dry-run artifacts.

TPU v5e-class hardware constants (per assignment):
    197 TFLOP/s bf16 per chip | 819 GB/s HBM | ~50 GB/s/link ICI

Three terms per (arch x shape x mesh), all in seconds-per-step:
    compute    = HLO_FLOPs / (chips x peak)
    memory     = HLO_bytes / (chips x hbm_bw)
    collective = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (per-device on
the SPMD-partitioned module; x chips = global).  collective_bytes is parsed
from ``compiled.as_text()``: the sum of operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes by collective kind (+ 'total', 'count')."""
    out = {k: 0 for k in _COLLECTIVES}
    count = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # match "<result> = <shape> <op>(<operand shapes...>)"
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+)$", s)
        if not m:
            continue
        rhs = m.group(1)
        op = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                op = k
                break
        if op is None:
            continue
        if re.search(rf"\b{op}-done\(", rhs):
            continue  # counted at -start
        # operand shapes are inside the call parens
        call = rhs.split("(", 1)
        operands = call[1] if len(call) > 1 else ""
        nbytes = sum(
            _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(operands)
        )
        if nbytes == 0:  # fall back to result shape
            nbytes = sum(
                _shape_bytes(d, dims)
                for d, dims in _SHAPE_RE.findall(call[0])[:1]
            )
        out[op] += nbytes
        count += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["count"] = count
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops: float           # 6ND / 2ND analytic, global
    coll_breakdown: dict

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achieved-model-FLOPs fraction of peak if the step ran at its
        dominant-term time (the dry-run analogue of MFU)."""
        t = self.bound_s
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N*D inference (N active)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    # decode: one token per sequence (+ cache attention, excluded from 2ND)
    return 2.0 * n_active * shape.global_batch


def from_compiled(arch, shape_name, mesh_name, chips, cost, hlo_text,
                  model_flops) -> Roofline:
    coll = collective_bytes(hlo_text)
    return Roofline(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        coll_bytes_per_device=float(coll["total"]),
        model_flops=model_flops,
        coll_breakdown=coll,
    )
