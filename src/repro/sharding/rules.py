"""Logical-axis -> mesh-axis sharding rules.

Model code annotates every param with logical axis names; this module maps
them onto the production mesh (("data","model") or ("pod","data","model")).
The pod axis only ever carries batch (pure cross-pod data parallelism — the
slow inter-pod links carry gradients, which is where GPULZ gradient
compression applies).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LOGICAL_RULES = {
    # embeddings
    "vocab": "model",  # output/tied table rows
    "vocab_in": "data",  # input table rows (d sharded on model)
    "embed_sharded": "model",
    "embed": "data",  # d_model inside weights: FSDP over data
    "embed_unsharded": None,
    "embed_out": "data",
    # attention
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "lora": None,  # MLA latent dims (replicated)
    # mlp / moe
    "ffn": "model",
    "experts": "model",  # expert parallelism
    "expert_ffn": None,
    # ssm
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_inner_conv": None,
    "state": None,
    "conv": None,
    # stacking
    "layers": None,
}


def _is_axes(x):
    return isinstance(x, tuple) and all(isinstance(s, str) for s in x)


# Weight-FSDP toggle (§Perf lever): when off, weight d_model/vocab_in dims
# replicate over the data axis — no per-layer weight all-gathers, at the cost
# of (params+grads)/model_axis bytes per device.  Profitable for models whose
# replicated working set fits HBM; required off... see steps.fsdp_decision.
_FSDP_AXES = ("embed", "vocab_in", "embed_out")
_FSDP = True


def set_fsdp(enabled: bool):
    global _FSDP
    _FSDP = bool(enabled)


def fsdp_enabled() -> bool:
    return _FSDP


def spec_for(axes: tuple) -> P:
    def one(a):
        if a in _FSDP_AXES and not _FSDP:
            return None
        return LOGICAL_RULES.get(a, None)

    return P(*(one(a) for a in axes))


def batch_axes(mesh: Mesh) -> tuple:
    """Mesh axes that carry the batch dimension."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def batch_spec(mesh: Mesh, batch_size: int) -> P:
    """Shard batch if divisible by the batch axes; else replicate (B=1)."""
    ax = batch_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in ax]))
    return P(ax) if batch_size % total == 0 else P(None)


def params_shardings(axes_tree, mesh: Mesh):
    return jax.tree.map(
        lambda a: NamedSharding(mesh, spec_for(a)), axes_tree, is_leaf=_is_axes
    )


def compute_spec(axes: tuple) -> P:
    """Weight layout *during compute*: storage spec minus the data (FSDP)
    axis.  Constraining a layer's weights to this inside the scan body makes
    the partitioner emit one small per-layer weight all-gather (classic FSDP)
    instead of replicating batch activations."""

    def one(a):
        r = LOGICAL_RULES.get(a, None)
        return None if r == "data" else r

    return P(*(one(a) for a in axes))


def compute_specs_tree(axes_tree, drop_leading: int = 0):
    """drop_leading: strip stacked dims (e.g. the (L, ...) 'layers' axis)
    when the specs will be applied to per-layer slices."""
    return jax.tree.map(
        lambda a: compute_spec(a[drop_leading:]), axes_tree, is_leaf=_is_axes
    )


def params_pspecs(axes_tree):
    return jax.tree.map(spec_for, axes_tree, is_leaf=_is_axes)


def zero_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Additionally shard optimizer state over the data axis (ZeRO-style).

    Picks the first unsharded dim divisible by the data axis; leaves the
    param's own (model) sharding intact.
    """
    data = mesh.shape["data"]
    parts = list(spec) + [None] * (len(shape) - len(spec))
    if any(p == "data" or (isinstance(p, tuple) and "data" in p) for p in parts):
        return P(*parts)  # already FSDP-sharded over data
    for i, (p, n) in enumerate(zip(parts, shape)):
        if p is None and n % data == 0 and n >= data:
            parts[i] = "data"
            return P(*parts)
    return P(*parts)


def zero_shardings(axes_tree, abstract_params, mesh: Mesh):
    def one(a, s):
        return NamedSharding(mesh, zero_spec(spec_for(a), s.shape, mesh))

    return jax.tree.map(one, axes_tree, abstract_params, is_leaf=_is_axes)


def activation_spec(mesh: Mesh, batch_size: int) -> P:
    """(B, T, d) activations: batch sharded, T/d replicated."""
    return batch_spec(mesh, batch_size)


# --------------------------------------------------------------------------
# Activation-sharding context: model code pins batch sharding with bare
# PartitionSpecs (resolved against the mesh installed by jax.sharding.set_mesh
# in the step builders).  Without these pins the SPMD partitioner may choose
# to replicate activations instead of weights once weights are FSDP-sharded.

_BATCH_AXES: tuple = ("data",)
_SEQ_PARALLEL = False  # shard T of the residual stream on "model"
_DATA_SHARDS = 1  # batch-axes size (for per-shard MoE dispatch)


def set_activation_batch_axes(axes: tuple, data_shards: int = None):
    global _BATCH_AXES, _DATA_SHARDS
    _BATCH_AXES = tuple(axes)
    if data_shards is not None:
        _DATA_SHARDS = int(data_shards)


def data_shard_count() -> int:
    return _DATA_SHARDS


def activation_batch_axes() -> tuple:
    return _BATCH_AXES


def set_seq_parallel(enabled: bool):
    """Megatron-style sequence parallelism: between layers the (B, T, d)
    residual stream is sharded (batch->data, T->model).  The partitioner then
    turns each TP partial-sum all-reduce into reduce-scatter(+all-gather at
    the next consumer), halving exchanged bytes and keeping norms/residuals
    T-sharded.  §Perf lever."""
    global _SEQ_PARALLEL
    _SEQ_PARALLEL = bool(enabled)


def seq_parallel_enabled() -> bool:
    return _SEQ_PARALLEL


def constrain_batch(x, *rest):
    """Pin dim0 of ``x`` to the batch axes (no-op without a mesh context).

    rest: specs for the remaining dims (defaults to None each).  With
    sequence parallelism on, 3D activations additionally shard dim1 (T) on
    the model axis.
    """
    explicit = len(rest)
    rest = list(rest) + [None] * (x.ndim - 1 - len(rest))
    if _SEQ_PARALLEL and explicit == 0 and x.ndim == 3:
        rest[0] = "model"
    spec = [_BATCH_AXES] + rest
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (RuntimeError, ValueError, TypeError):
        return x
