from repro.sharding.rules import (
    LOGICAL_RULES,
    activation_spec,
    batch_axes,
    batch_spec,
    params_pspecs,
    params_shardings,
    spec_for,
    zero_shardings,
    zero_spec,
)

__all__ = [
    "LOGICAL_RULES",
    "activation_spec",
    "batch_axes",
    "batch_spec",
    "params_pspecs",
    "params_shardings",
    "spec_for",
    "zero_shardings",
    "zero_spec",
]
