from repro.sharding.batch import (
    ShardedBatchRunner,
    normalize_batch_axes,
    shard_vmap,
    unsharded,
)
from repro.sharding.rules import (
    LOGICAL_RULES,
    activation_spec,
    batch_axes,
    batch_spec,
    params_pspecs,
    params_shardings,
    spec_for,
    zero_shardings,
    zero_spec,
)

__all__ = [
    "LOGICAL_RULES",
    "ShardedBatchRunner",
    "activation_spec",
    "batch_axes",
    "batch_spec",
    "normalize_batch_axes",
    "params_pspecs",
    "params_shardings",
    "shard_vmap",
    "spec_for",
    "unsharded",
    "zero_shardings",
    "zero_spec",
]
