"""Shard-mapped multi-device batch compression.

GPULZ's design scales by making every chunk independent (paper §IV: per-chunk
Kernel I, global prefix sums, deflate scatter).  The same independence holds
one level up: whole *buffers* in a batch are independent too, so the batched
entry points (``pipeline.compress_many_chunks`` / ``decompress_many_chunks``)
can partition the B dimension over a named mesh axis and run the registered
single-device pipeline per shard, instead of dispatching all B buffers on one
chip (cf. Sitaridi et al., "Massively-Parallel Lossless Data Decompression" —
the decompression-side version of the same argument).

``ShardedBatchRunner`` is that layer:

  * the batch dimension is padded up to a multiple of the shard count
    (zero rows, discarded after the gather) and split with ``shard_map``
    over the mesh axes named by ``batch_axis`` (default: the logical batch
    axes from ``sharding/rules.py`` — ``("pod", "data")`` when a pod axis
    exists, else ``("data",)``);
  * every shard runs the *existing* registered backend/decoder — the
    auto-resolved platform default (the single-kernel ``fused-mono`` pair
    in both directions on TPU, ``xla``/``xla-parallel`` elsewhere; the
    decode side dispatches through the ``decode_blob`` hook, so each shard's
    decompress is ONE Pallas launch reading its blobs straight from HBM) —
    so per-buffer blobs/symbols are byte-identical to the single-device
    dispatch by construction;
  * the ragged per-buffer blobs gather back as the same ``(B, cap)`` buffer +
    ``(B,)`` totals contract the unsharded batched cores return.

The runner is exposed through the backend registry rather than ``if``-ladders
in ``core/pipeline.py``: ``LZSSConfig(backend="sharded", decoder="sharded",
mesh=..., batch_axis=...)`` selects the registered ``"sharded"``
compressor/decoder pair (``pipeline.ShardedCompressor`` /
``pipeline.ShardedDecoder``), which lazily constructs a runner here.  With
``mesh=None`` (or a single-shard mesh) the runner degenerates to the plain
vmapped dispatch, so ``"sharded"`` is always a safe registry key.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import pipeline
from repro.sharding import rules


def unsharded(cfg: "pipeline.LZSSConfig") -> "pipeline.LZSSConfig":
    """The per-shard (single-device) view of a sharded config.

    Strips ``mesh``/``batch_axis`` and resolves the ``"sharded"`` registry
    keys to the platform defaults, so the function a shard runs is exactly
    the unsharded dispatch — this is what makes sharded output byte-identical
    by construction (and what prevents shard_map recursion).
    """
    backend = "auto" if cfg.backend == "sharded" else cfg.backend
    decoder = "auto" if cfg.decoder == "sharded" else cfg.decoder
    backend = pipeline.resolve_backend(backend)
    decoder = pipeline.resolve_decoder(decoder)
    if (backend, decoder, cfg.mesh) == (cfg.backend, cfg.decoder, None):
        return cfg
    return dataclasses.replace(
        cfg, backend=backend, decoder=decoder, mesh=None, batch_axis=None
    )


def normalize_batch_axes(mesh: Mesh, batch_axis=None) -> tuple:
    """Mesh axes carrying the batch dimension, as a tuple of axis names.

    ``batch_axis`` may be a single axis name, a tuple of names, or ``None``
    (use the logical batch axes from ``rules.batch_axes``, filtered to the
    axes this mesh actually has; falls back to the mesh's leading axis).
    """
    if batch_axis is None:
        axes = tuple(a for a in rules.batch_axes(mesh) if a in mesh.axis_names)
        return axes or (mesh.axis_names[0],)
    if isinstance(batch_axis, str):
        batch_axis = (batch_axis,)
    axes = tuple(batch_axis)
    missing = [a for a in axes if a not in mesh.axis_names]
    if missing:
        raise ValueError(
            f"batch_axis {missing} not in mesh axes {tuple(mesh.axis_names)}"
        )
    return axes


def _sharded_call(fn, mesh: Mesh, axes: tuple, in_arity: int):
    """shard_map ``fn`` with dim 0 of every arg and output split over ``axes``.

    Mesh axes outside ``axes`` replicate the computation, and their outputs
    are gathered by explicit *untiling*: the body prepends one length-1 dim
    per unmentioned axis so ``out_specs`` can name every mesh axis, and
    replica 0 is sliced off afterwards.  Simply omitting an axis from
    ``out_specs`` under ``check_rep=False`` is not portable: eager shard_map
    returns one replica, but inside jit the partitioner may *sum* the
    replicas instead (observed on forced-host CPU meshes), which corrupts
    byte-exact output.  ``check_rep=False`` itself is required because the
    body runs jitted pipeline code (Pallas kernels on TPU) whose replication
    XLA cannot infer.
    """
    other = tuple(a for a in mesh.axis_names if a not in axes)
    k = len(other)

    def body(*args):
        out = fn(*args)
        return jax.tree.map(lambda x: x.reshape((1,) * k + x.shape), out)

    run = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axes),) * in_arity,
        out_specs=P(*other, axes),
        check_rep=False,
    )

    def call(*args):
        out = run(*args)
        return jax.tree.map(lambda x: x[(0,) * k], out)

    return call


def shard_vmap(fn, mesh: Mesh, axis):
    """vmap ``fn`` over dim 0, with the rows split over ``axis`` shards.

    The shard-mapped analogue of ``jax.vmap(fn)``: each shard of the named
    mesh axis (or axes) maps ``fn`` over its local rows only.  Used by the
    gradient exchange to pin per-pod compression to the pod that owns the
    shard, and by ``ShardedBatchRunner`` below.
    """
    axes = (axis,) if isinstance(axis, str) else tuple(axis)

    def call(*args):
        return _sharded_call(jax.vmap(fn), mesh, axes, len(args))(*args)

    return call


def _pad_rows(x: jnp.ndarray, rows: int) -> jnp.ndarray:
    """Zero-pad dim 0 up to ``rows``; padded outputs are sliced off after the
    gather.

    Zero rows are valid pipeline inputs on both sides (all-zero symbols
    compress fine; a zero "container" row decodes as zero tokens — every
    section gather is bounds-checked).  Constant padding specifically:
    gather-based row padding (``mode="edge"`` / ``jnp.concatenate`` of a
    broadcast last row) feeding a shard_map whose mesh has unmentioned axes
    miscompiles under jit on CPU — the partitioner sums the replicas of the
    padded operand across the unmentioned axis, corrupting the bytes.
    """
    pad = rows - x.shape[0]
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))


class ShardedBatchRunner:
    """Partition the B dimension of the batched cores over a mesh axis.

    ``mesh=None`` (or a single-shard axis) degenerates to the plain vmapped
    single-device dispatch — same code path, same bytes.  Otherwise B is
    padded to a multiple of the shard count and ``shard_map`` runs the
    unsharded batched core per shard (see module docstring).
    """

    def __init__(self, mesh: Mesh | None, batch_axis=None):
        self.mesh = mesh
        self.axes = None if mesh is None else normalize_batch_axes(mesh, batch_axis)

    @property
    def n_shards(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.axes:
            n *= self.mesh.shape[a]
        return n

    def _padded_rows(self, b: int) -> int:
        k = self.n_shards
        return -(-b // k) * k

    def compress_many(self, symbols, cfg, orig_bytes):
        """(B, nc, C) symbols -> ((B, cap) u8 blobs, (B,) totals), sharded.

        Every shard compresses its local rows with the unsharded config
        (``unsharded(cfg)``), so each row's container is byte-identical to
        the single-device ``compress_many_chunks`` output.
        """
        inner = unsharded(cfg)
        if self.n_shards == 1:
            return pipeline.compress_many_chunks(symbols, inner, orig_bytes)
        b = symbols.shape[0]
        bp = self._padded_rows(b)
        run = _sharded_call(
            lambda s_, o_: pipeline.compress_many_chunks(s_, inner, o_),
            self.mesh,
            self.axes,
            2,
        )
        blobs, totals = run(_pad_rows(symbols, bp), _pad_rows(orig_bytes, bp))
        return blobs[:b], totals[:b]

    def decompress_many(
        self,
        blobs,
        n_tokens,
        payload_sizes,
        *,
        symbol_size,
        chunk_symbols,
        n_chunks,
        decoder="auto",
        chunks_per_block=None,
        method_params=(),
    ):
        """(B, L) blobs + (B, nc) tables -> (B, nc, C) symbols, sharded."""
        dec = pipeline.resolve_decoder("auto" if decoder == "sharded" else decoder)
        kw = dict(
            symbol_size=symbol_size,
            chunk_symbols=chunk_symbols,
            n_chunks=n_chunks,
            decoder=dec,
            chunks_per_block=chunks_per_block,
            method_params=method_params,
        )
        if self.n_shards == 1:
            return pipeline.decompress_many_chunks(
                blobs, n_tokens, payload_sizes, **kw
            )
        b = blobs.shape[0]
        bp = self._padded_rows(b)
        run = _sharded_call(
            lambda b_, t_, p_: pipeline.decompress_many_chunks(b_, t_, p_, **kw),
            self.mesh,
            self.axes,
            3,
        )
        out = run(
            _pad_rows(blobs, bp),
            _pad_rows(n_tokens, bp),
            _pad_rows(payload_sizes, bp),
        )
        return out[:b]
