"""Architecture-adaptive chunk-geometry autotuner.

GPULZ's third contribution is "maximizing shared memory utilization by
adapting data partitions to different GPU architectures" (PAPER.md §1) —
on TPU the analogous knobs are ``chunk_symbols`` (C, the per-chunk symbol
count: VMEM block width) and ``chunks_per_block`` (g, how many chunks ride
the sublane dimension of one grid step).  Until this module every kernel
hardcoded C=2048 / g=8; this is the tile chooser that adapts them per
architecture, in the spirit of the ``triton.Config`` candidate lists of
Triton autotuners (pow-2 candidate grids, timed sweep, best kept).

Design:

  * ``TuneKey`` — one tuning problem: (device kind, dtype, S, W, direction,
    C).  ``direction`` is ``"compress"`` (the single-kernel compressor,
    kernels/lz_fused.py) or ``"decompress"`` (the single-launch decoder,
    kernels/lz_decode_mono.py; its cost is W-independent, so decode keys
    carry ``window=0``).  ``chunk_symbols`` is the fixed container C when
    only g is tunable (every kernel call site — the shapes are already
    committed) or ``None`` for the joint (C, g) sweep behind
    ``tuned_chunk_geometry`` / ``pipeline.tuned_config``.
  * ``best_geometry(key)`` — cache lookup, then (if tuning is enabled) a
    timed sweep over ``candidates(key)`` on a deterministic synthetic
    workload, persisted to a JSON on-disk cache; otherwise the
    deterministic ``fallback`` table.
  * The cache is a JSON file at ``$REPRO_AUTOTUNE_CACHE`` (default
    ``~/.cache/gpulz-repro/autotune.json``), schema-checked on load
    (``validate_cache``); a corrupted file is treated as empty and
    rewritten, never crashed on.  Entries are memoized per process, so a
    jitted pipeline sees one stable geometry per key for the lifetime of
    the process (jit caches trace on config, not on geometry).

Gating: ``REPRO_AUTOTUNE=1`` forces tuning on, ``REPRO_AUTOTUNE=0`` forces
the deterministic fallback (bit-exact with the pre-autotuner static
geometry C=2048/g=8 — what tests and reproducibility-pinned runs want);
unset, tuning runs only on real TPU — interpret-mode timings on CPU are
meaningless, so CI and CPU containers stay on the fallback automatically.

The timed sweep additionally only ever runs *outside* a jax trace: inside
``jit``/``vmap`` tracing, ``block_until_ready`` no-ops on tracers and
``time.perf_counter`` would measure tracing overhead, not kernel runtime —
a winner picked there is noise, and persisting it would poison the cache
for every future run.  ``best_geometry`` therefore serves memo/cache hits
(re-validated against the VMEM budget) or the deterministic fallback when
called under a trace, and the non-jitted entry points
(``lzss.compress``/``decompress`` and their batched forms) resolve geometry
eagerly — ``pipeline.resolve_chunk_geometry`` — before crossing the jit
boundary, so real sweeps happen eagerly on real devices.

``validate_block_geometry`` is the shared geometry validator: it rejects a
``(chunk_symbols, chunks_per_block)`` pair whose VMEM block footprint
cannot fit, naming the offending pair — ``LZSSConfig.__post_init__`` calls
it so a bad geometry fails at config construction instead of as an opaque
Mosaic allocation error inside Pallas.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, Optional, Tuple

import jax

# The pre-autotuner static geometry: every kernel's historical default and
# the deterministic fallback when tuning is disabled.
DEFAULT_CHUNK_SYMBOLS = 2048
DEFAULT_CHUNKS_PER_BLOCK = 8

# Per-grid-step VMEM budget for one (g, C) block across the fused kernels'
# live buffers (inputs + scratch + intermediates).  TPU VMEM is ~16 MiB;
# the estimate below is deliberately conservative, so cap the budget there.
VMEM_LIMIT_BYTES = 16 * 2**20

CACHE_VERSION = 1
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
ENABLE_ENV = "REPRO_AUTOTUNE"

# Candidate grids, triton.Config style: pow-2 ladders around the defaults.
# Candidates that overflow the VMEM budget are filtered per key.
CHUNK_SYMBOL_CANDIDATES = (512, 1024, 2048, 4096)
CHUNKS_PER_BLOCK_CANDIDATES = (8, 16, 32)

# Deterministic per-architecture fallback rows: (device-kind prefix,
# direction) -> (chunk_symbols, chunks_per_block).  Populated as real-TPU
# sweeps land (ROADMAP); an absent row falls back to the historical static
# geometry, so disabling tuning is always bit-exact with the pre-autotuner
# pipeline.
FALLBACK_TABLE: Dict[Tuple[str, str], Tuple[int, int]] = {}

_MEMO: Dict[str, Tuple[int, int]] = {}  # per-process: cache_key -> (C, g)
_SWEEPS: Dict[str, int] = {}  # telemetry (tests assert on it): key -> sweeps


@dataclasses.dataclass(frozen=True)
class TuneKey:
    """One tuning problem; hashable, stable string form via ``cache_key``."""

    device_kind: str
    dtype: str
    symbol_size: int
    window: int  # 0 on the decode side: decode cost is W-independent
    direction: str  # "compress" | "decompress"
    chunk_symbols: Optional[int]  # fixed C, or None for the joint (C, g) sweep

    def cache_key(self) -> str:
        c = "auto" if self.chunk_symbols is None else str(self.chunk_symbols)
        return (
            f"{self.device_kind}|{self.dtype}|s{self.symbol_size}"
            f"|w{self.window}|{self.direction}|c{c}"
        )


def device_kind() -> str:
    """Normalized accelerator kind (e.g. ``TPU_v4``, ``cpu``)."""
    try:
        kind = jax.devices()[0].device_kind
    except Exception:  # platform init failure: key on the backend name
        kind = jax.default_backend()
    return str(kind).replace(" ", "_")


def default_dtype(symbol_size: int) -> str:
    return {1: "u8", 2: "u16", 4: "u32"}[symbol_size]


def enabled() -> bool:
    """Whether timed sweeps run (vs the deterministic fallback table)."""
    flag = os.environ.get(ENABLE_ENV)
    if flag is not None:
        return flag != "0"
    return jax.default_backend() == "tpu"  # interpret timings are meaningless


def trace_state_clean() -> bool:
    """True when no jax trace is being staged (sweeps are only valid then)."""
    try:
        return bool(jax.core.trace_state_clean())
    except AttributeError:  # jax moved/renamed it: fail safe, never sweep
        return False


def cache_path() -> str:
    return os.environ.get(
        CACHE_ENV,
        os.path.join(
            os.path.expanduser("~"), ".cache", "gpulz-repro", "autotune.json"
        ),
    )


# ------------------------------------------------------------- validation


def block_vmem_bytes(
    chunk_symbols: int, chunks_per_block: int, symbol_size: int
) -> int:
    """Conservative per-grid-step VMEM footprint of one (g, C) block.

    Envelope over both fused kernels: the compressor keeps ~3 (g, C) int32
    buffers plus the (g, C//8) flag, (g, C*S) payload and (1, g*C*S) slide
    windows live; the decoder holds the sections plus several (g, C)
    intermediates of the prefix-sum/binary-search chain.  8 C-width int32
    rows + 2 payload-width rows per chunk covers either.
    """
    g, c, s = chunks_per_block, chunk_symbols, symbol_size
    return 4 * g * c * (8 + 2 * s)


def validate_block_geometry(
    chunk_symbols: int, chunks_per_block: int, symbol_size: int
) -> None:
    """Reject a (C, g) pair Pallas could not run, naming the pair.

    Shared by ``LZSSConfig.__post_init__`` and the candidate filter, so an
    oversized geometry fails at config time with the offending numbers in
    the message instead of as an opaque Mosaic VMEM-allocation error.
    """
    c, g = chunk_symbols, chunks_per_block
    if not isinstance(g, int) or isinstance(g, bool) or g < 1:
        raise ValueError(
            f"chunks_per_block must be a positive int: got "
            f"(chunk_symbols={c}, chunks_per_block={g!r})"
        )
    need = block_vmem_bytes(c, g, symbol_size)
    if need > VMEM_LIMIT_BYTES:
        raise ValueError(
            f"block geometry (chunk_symbols={c}, chunks_per_block={g}) needs "
            f"~{need / 2**20:.1f} MiB of VMEM per grid step at "
            f"symbol_size={symbol_size}, over the {VMEM_LIMIT_BYTES / 2**20:.0f}"
            f" MiB budget — shrink chunk_symbols or chunks_per_block"
        )


def _fits(c: int, g: int, s: int) -> bool:
    return block_vmem_bytes(c, g, s) <= VMEM_LIMIT_BYTES


# --------------------------------------------------------------- choices


def fallback(key: TuneKey) -> Tuple[int, int]:
    """Deterministic geometry when tuning is disabled (or as sweep seed)."""
    c, g = None, None
    for (prefix, direction), row in FALLBACK_TABLE.items():
        if key.direction == direction and key.device_kind.startswith(prefix):
            c, g = row
            break
    if c is None:
        c = DEFAULT_CHUNK_SYMBOLS
        g = DEFAULT_CHUNKS_PER_BLOCK
    if key.chunk_symbols is not None:
        c = key.chunk_symbols  # C already committed by the caller's shapes
    while g > 1 and not _fits(c, g, key.symbol_size):
        g //= 2
    return c, g


def candidates(key: TuneKey):
    """VMEM-filtered (C, g) candidate list for one key."""
    cs = (
        CHUNK_SYMBOL_CANDIDATES
        if key.chunk_symbols is None
        else (key.chunk_symbols,)
    )
    out = [
        (c, g)
        for c in cs
        for g in CHUNKS_PER_BLOCK_CANDIDATES
        if _fits(c, g, key.symbol_size)
    ]
    return out or [fallback(key)]


# ------------------------------------------------------------- the cache


def validate_cache(obj) -> None:
    """Schema check for an on-disk cache object; raises ``ValueError``.

    Rides ``make check-bench`` via the artifact-schema tests, and gates
    ``_load_cache`` — a corrupted file is treated as empty, never trusted.
    """
    if not isinstance(obj, dict):
        raise ValueError("autotune cache: not a JSON object")
    if obj.get("version") != CACHE_VERSION:
        raise ValueError(
            f"autotune cache: version {obj.get('version')!r} != {CACHE_VERSION}"
        )
    entries = obj.get("entries")
    if not isinstance(entries, dict):
        raise ValueError("autotune cache: 'entries' must be an object")
    for k, e in entries.items():
        if not isinstance(e, dict):
            raise ValueError(f"autotune cache: entry {k!r} is not an object")
        for field in ("chunk_symbols", "chunks_per_block"):
            v = e.get(field)
            if not isinstance(v, int) or v < 1:
                raise ValueError(
                    f"autotune cache: entry {k!r} field {field!r} must be a "
                    f"positive int, got {v!r}"
                )
        spc = e.get("seconds_per_call")
        if not isinstance(spc, (int, float)) or spc <= 0:
            raise ValueError(
                f"autotune cache: entry {k!r} seconds_per_call must be a "
                f"positive number, got {spc!r}"
            )


def _entry_geometry(cache: dict, key: TuneKey) -> Optional[Tuple[int, int]]:
    """Validated geometry from a persisted cache entry, or ``None``.

    ``validate_cache`` only proves the schema ("positive ints"); an entry
    can still be unusable at *this* call site — the cache file is shareable
    (``REPRO_AUTOTUNE_CACHE``), hand-editable, and survives changes to
    ``VMEM_LIMIT_BYTES`` / ``block_vmem_bytes``.  Re-check on every hit
    that the pair still fits the VMEM budget and that a fixed-C key only
    adopts an entry tuned for that same C; a failing entry is ignored (and
    overwritten by the next eager sweep) instead of flowing into Pallas as
    the opaque Mosaic allocation error the validator exists to prevent.
    """
    entry = cache["entries"].get(key.cache_key())
    if entry is None:
        return None
    c, g = int(entry["chunk_symbols"]), int(entry["chunks_per_block"])
    if key.chunk_symbols is not None and c != key.chunk_symbols:
        return None
    if not _fits(c, g, key.symbol_size):
        return None
    return c, g


def _load_cache(path: str) -> dict:
    try:
        with open(path) as f:
            obj = json.load(f)
        validate_cache(obj)
        return obj
    except FileNotFoundError:
        return {"version": CACHE_VERSION, "entries": {}}
    except (json.JSONDecodeError, ValueError, OSError):
        # corrupted / stale-schema cache: recover by re-tuning, never crash
        return {"version": CACHE_VERSION, "entries": {}}


def _store_cache(path: str, cache: dict) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(cache, f, indent=2, sort_keys=True)
    os.replace(tmp, path)  # atomic publish, mirroring checkpoint/manager.py


def reset() -> None:
    """Drop per-process memoized geometry (tests / env changes)."""
    _MEMO.clear()
    _SWEEPS.clear()


# --------------------------------------------------------------- tuning


def _time(fn: Callable[[], object], warmup: int = 1, iters: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _default_measure(key: TuneKey) -> Callable[[int, int], float]:
    """Deterministic synthetic workload for one key: (C, g) -> seconds.

    Compress times the single-kernel compressor on a run-heavy corpus;
    decompress times the single-launch decoder on a worst-case all-literal
    container built in place (every flag/payload window at full width).
    Inputs are seeded, so re-sweeps on the same machine are reproducible.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core import encode
    from repro.core import format as fmt

    s = key.symbol_size
    interpret = jax.default_backend() != "tpu"

    if key.direction == "compress":
        from repro.kernels import lz_fused

        window = key.window or DEFAULT_CHUNK_SYMBOLS // 16
        min_match = encode.min_match_length(s)

        def measure(c: int, g: int) -> float:
            nc = max(2 * g, 16)
            rng = np.random.default_rng(0)
            syms = np.repeat(
                rng.integers(0, 1 << min(8 * s, 16), nc * c // 4), 4
            ).astype(np.int32)[: nc * c].reshape(nc, c)
            cap = fmt.max_compressed_bytes(nc * c * s, s, c)
            x = jnp.asarray(syms)

            def fn():
                return lz_fused.lz_fused_mono_pallas(
                    x,
                    window=window,
                    min_match=min_match,
                    symbol_size=s,
                    cap=cap,
                    sec_flags=fmt.HEADER_BYTES + 8 * nc,
                    chunks_per_block=g,
                    interpret=interpret,
                )

            return _time(fn)

    else:
        from repro.kernels import lz_decode_mono

        def measure(c: int, g: int) -> float:
            nc = max(2 * g, 16)
            cb = c // 8
            rng = np.random.default_rng(0)
            sec_flags = fmt.HEADER_BYTES + 8 * nc
            blob = np.zeros(sec_flags + nc * cb + nc * c * s, np.uint8)
            blob[sec_flags + nc * cb :] = rng.integers(
                0, 256, nc * c * s, dtype=np.int64
            ).astype(np.uint8)
            nt = jnp.full((nc,), c, jnp.int32)  # all-literal: worst case
            psz = jnp.full((nc,), c * s, jnp.int32)
            b = jnp.asarray(blob)

            def fn():
                return lz_decode_mono.lz_decode_mono_pallas(
                    b,
                    nt,
                    psz,
                    symbol_size=s,
                    chunk_symbols=c,
                    n_chunks=nc,
                    chunks_per_block=g,
                    interpret=interpret,
                )

            return _time(fn)

    return measure


def best_geometry(
    key: TuneKey, measure: Optional[Callable[[int, int], float]] = None
) -> Tuple[int, int]:
    """(chunk_symbols, chunks_per_block) for one key.

    Resolution order: deterministic fallback when tuning is disabled;
    per-process memo; the persisted JSON cache (entries re-validated
    against the VMEM budget on every hit — see ``_entry_geometry``);
    finally a timed sweep over ``candidates(key)`` whose winner is written
    back to the cache.  The result is memoized, so a jitted pipeline sees
    one stable geometry per key for the process lifetime.

    The sweep never runs while a jax trace is being staged: the kernel
    calls in ``measure`` would be staged into the surrounding trace
    (``block_until_ready`` no-ops on tracers) and the timings would be
    tracing overhead, not kernel runtime.  Under a trace an untuned key
    gets the deterministic fallback — unmemoized and unpersisted, so a
    later eager call can still tune it.  The non-jitted entry points
    resolve geometry eagerly (``pipeline.resolve_chunk_geometry``) exactly
    so the hot paths never hit this case.
    """
    if not enabled():
        return fallback(key)
    ck = key.cache_key()
    if ck in _MEMO:
        return _MEMO[ck]
    path = cache_path()
    cache = _load_cache(path)
    geom = _entry_geometry(cache, key)
    if geom is not None:
        _MEMO[ck] = geom
        return geom
    if not trace_state_clean():
        return fallback(key)  # in-trace timings are noise: never sweep here
    # sweep: time every candidate, keep the fastest, persist
    if measure is None:
        measure = _default_measure(key)
    cands = candidates(key)
    timed = [(measure(c, g), c, g) for c, g in cands]
    _SWEEPS[ck] = _SWEEPS.get(ck, 0) + 1
    best_t, c, g = min(timed)
    cache["entries"][ck] = {
        "chunk_symbols": c,
        "chunks_per_block": g,
        "seconds_per_call": best_t,
        "device_kind": key.device_kind,
        "direction": key.direction,
        "swept": len(timed),
    }
    _store_cache(path, cache)
    _MEMO[ck] = (c, g)
    return c, g


# ----------------------------------------------------- call-site helpers


def block_geometry(
    *,
    symbol_size: int,
    chunk_symbols: int,
    direction: str,
    window: int = 0,
    dtype: Optional[str] = None,
) -> int:
    """``chunks_per_block`` for a kernel call site whose C is committed.

    This is what ``kernels/ops.py`` resolves a ``chunks_per_block=None``
    default through — the fused compressor and the single-launch decoder
    both consume it.
    """
    key = TuneKey(
        device_kind=device_kind(),
        dtype=dtype or default_dtype(symbol_size),
        symbol_size=symbol_size,
        window=window if direction == "compress" else 0,
        direction=direction,
        chunk_symbols=chunk_symbols,
    )
    return best_geometry(key)[1]


def tuned_chunk_geometry(
    *, symbol_size: int, window: int, dtype: Optional[str] = None
) -> Tuple[int, int]:
    """Joint (chunk_symbols, chunks_per_block) sweep for new containers.

    Unlike ``block_geometry`` this also chooses C — a *format-visible*
    parameter (it changes container bytes), so it is only consulted when a
    config is being built (``pipeline.tuned_config``), never to reinterpret
    an existing container.
    """
    key = TuneKey(
        device_kind=device_kind(),
        dtype=dtype or default_dtype(symbol_size),
        symbol_size=symbol_size,
        window=window,
        direction="compress",
        chunk_symbols=None,
    )
    return best_geometry(key)
