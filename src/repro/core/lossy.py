"""Error-bounded lossy frontend: the ``lossy-fz`` container subsystem.

FZ-GPU's (PAPERS.md) recipe for scientific f32 data, as a method-2
container (core/format.py):

    dual-quant (core/quant.py math, ndim=1 over the flattened element
    stream) -> bitshuffle (core/bitshuffle.py bit-plane transpose of the
    uint16 code stream) -> lossless inner container (the platform LZSS
    backend, or ``deflate-full``)

plus an outlier section (saturated / non-finite elements stored as exact
(u32 index, f32 bits) pairs) and a fixed metadata block carrying the error
bound itself — ``decompress`` reconstructs within the bound from container
bytes alone, no side-channel state.

Guarantees (tested in tests/test_lossy.py / test_properties.py):

  * quant mode (``lossy_eb > 0``): max |x' - x| <= eb for every finite
    element; NaN/±inf elements round-trip bit-exactly through the outlier
    section.  The bound is *f32-deterministic*: the stored eb is the f32
    rounding of the configured bound, and both sides derive 2*eb in f32
    (exact — a power-of-two scale), so encoder and decoder integer chains
    agree bit-for-bit.
  * lossless mode (``lossy_eb == 0``): bit-exact reconstruction including
    NaN payloads — the f32 halves pass through bitshuffle untouched.

Both hooks are fixed-shape and fully in-graph (vmap/shard_map safe): the
inner container sits at a *static* offset so its header/tables parse with
static slices; only the outlier section lives at a dynamic offset (after
the inner container's live bytes) and is written/read with masked
OOB-dropped scatters/gathers, the same pattern as core/entropy.py.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import bitshuffle
from repro.core import format as fmt
from repro.core import quant

assert bitshuffle.BLOCK_UNITS == fmt.LOSSY_BLOCK_UNITS

INT30 = 2.0**30


def eb_to_f32(error_bound: float) -> float:
    """The f32-rounded bound both sides of the format actually honor."""
    return float(np.float32(error_bound))


def _rcp(eb2) -> jnp.ndarray:
    """The format's pre-quant scale: the f32 reciprocal of 2*eb.

    The encoder knows eb statically but the decoder reads it from container
    bytes; for their integer chains to agree bit-for-bit, BOTH sides must
    lower the eb arithmetic identically.  Defining pre-quant as
    ``round(x / eb2)`` breaks that: XLA strength-reduces division by a
    *constant* to a reciprocal multiply, which flips ``round`` at
    half-quantum boundaries relative to the decoder's true divide (observed
    on CPU: carry repair off by one quantum between outliers).  So the
    format defines pre-quant as ``round(x * fl32(1/(2*eb)))`` instead: the
    encoder's constant fold of this divide and the decoder's runtime divide
    are both IEEE correctly rounded — same bits — and a plain multiply has
    no strength reduction to diverge on.
    """
    return jnp.float32(1.0) / jnp.asarray(eb2, jnp.float32)


def _prequant(x: jnp.ndarray, rcp):
    """round/clip pre-quantization, NaN pinned to 0 (core/quant.py rules).

    ``rcp`` must be the ``_rcp`` scalar — identical lowering on the encode
    (static eb) and decode (eb from container bytes) sides is what makes
    the two integer chains agree bit-for-bit.
    """
    qf = jnp.round(x * rcp)
    nan = jnp.isnan(qf)
    q = jnp.clip(jnp.where(nan, 0.0, qf), -INT30, INT30).astype(jnp.int32)
    return qf, nan, q


def static_params(header: fmt.Header) -> tuple:
    """The ``lossy-fz`` decoder's static decode parameters.

    Mode and inner method change the in-graph shapes of the decode trace
    (unit counts, inner geometry, section capacities), so they travel as
    static jit arguments — parsed host-side from the header by
    ``lzss.decompress`` and threaded through ``method_params``.
    """
    return (header.lossy_mode, header.inner_method)


def compress_lossy(symbols, cfg, orig_bytes=None):
    """The ``lossy-fz`` backend's ``compress`` hook.

    ``symbols`` is the (nc, C) int32 S=4 symbol array — each symbol IS one
    little-endian f32 bit pattern.  Returns ``(buffer u8, total_bytes)``
    holding a complete method-2 container.
    """
    from repro.core import pipeline  # lazy: pipeline registers this hook

    nc, c = symbols.shape
    eb32 = eb_to_f32(cfg.lossy_eb)
    mode = (
        fmt.LOSSY_MODE_QUANT if eb32 > 0.0 else fmt.LOSSY_MODE_LOSSLESS
    )
    n_elems, units_pad, inner_nc = fmt.lossy_stream_geometry(nc, c, mode)
    flat = symbols.reshape(-1).astype(jnp.int32)

    if mode == fmt.LOSSY_MODE_QUANT:
        x = lax.bitcast_convert_type(flat, jnp.float32)
        eb2 = jnp.float32(2.0 * eb32)
        qf, nan, q = _prequant(x, _rcp(eb2))
        delta = jnp.diff(q, prepend=q[:1] * 0) + quant.CENTER
        sat = (
            (delta < quant.CODE_MIN)
            | (delta > quant.CODE_MAX)
            | (jnp.abs(qf) >= INT30)
            | nan
        )
        # The decoder reconstructs exactly ``q.astype(f32) * eb2`` (same op
        # sequence, bit-identical) — simulate it and promote any element the
        # f32 round trip pushes past the bound (half-quantum boundaries at
        # large |x|/eb) to an exact outlier.  ``~(err <= eb)`` also catches
        # non-finite x, making the <= eb guarantee strict, not ulp-fuzzy.
        # The two-ulp guard keeps the check conservative if XLA fuses this
        # mul+sub into an FMA (more accurate than the decoder's standalone
        # rounded mul, so an unguarded check could under-promote).
        recon = q.astype(jnp.float32) * eb2
        guard = jnp.abs(recon) * jnp.float32(2.0**-22)
        sat = sat | ~(jnp.abs(recon - x) + guard <= eb32)
        units_live = jnp.where(sat, quant.CENTER, delta)
    else:
        lo = flat & 0xFFFF
        hi = (flat >> 16) & 0xFFFF
        units_live = jnp.stack([lo, hi], axis=1).reshape(-1)
        sat = None

    units = (
        jnp.zeros((units_pad,), jnp.int32)
        .at[: units_live.shape[0]]
        .set(units_live)
    )
    shuffled = bitshuffle.shuffle(units.astype(jnp.uint16)).astype(jnp.int32)
    pairs = shuffled.reshape(-1, 2)
    inner_live = pairs[:, 0] | (pairs[:, 1] << 8)
    inner_c = fmt.LOSSY_INNER_CHUNK_SYMBOLS
    inner_syms = (
        jnp.zeros((inner_nc * inner_c,), jnp.int32)
        .at[:units_pad]
        .set(inner_live)
        .reshape(inner_nc, inner_c)
    )

    inner_name = pipeline.resolve_backend(cfg.lossy_inner)
    inner_method = pipeline.container_method(inner_name)
    inner_cfg = pipeline.LZSSConfig(
        symbol_size=2,
        window=cfg.window,
        chunk_symbols=inner_c,
        backend=inner_name,
    )
    inner_buf, inner_total = pipeline._compress_via(
        pipeline.get_backend(inner_name), inner_syms, inner_cfg, 2 * units_pad
    )
    inner_cap = fmt.lossy_inner_capacity(inner_nc, inner_method)
    assert inner_buf.shape[0] == inner_cap, (
        f"inner backend {inner_name!r} emitted a {inner_buf.shape[0]}-byte "
        f"capacity buffer, format expects {inner_cap}"
    )

    sec_meta = fmt.HEADER_BYTES + 8 * nc
    sec_inner = sec_meta + fmt.LOSSY_META_FIXED
    out_cap = sec_inner + inner_cap + (
        8 * n_elems if mode == fmt.LOSSY_MODE_QUANT else 0
    )
    zeros_nc = jnp.zeros((nc,), jnp.int32)
    out = jnp.zeros((out_cap,), jnp.int32)
    out = fmt.write_header_and_tables(
        out,
        symbol_size=4,
        window=cfg.window,
        chunk_symbols=c,
        n_chunks=nc,
        orig_bytes=nc * c * 4 if orig_bytes is None else orig_bytes,
        payload_total=0,
        flag_total=0,
        n_tokens=zeros_nc,
        payload_sizes=zeros_nc,
        method=fmt.METHOD_LOSSY,
        sub_log2=0,
    )
    out = out.at[sec_inner : sec_inner + inner_cap].set(
        inner_buf.astype(jnp.int32)
    )

    if mode == fmt.LOSSY_MODE_QUANT:
        n_out = jnp.sum(sat).astype(jnp.int32)
        rank = jnp.cumsum(sat) - 1
        obase = sec_inner + inner_total
        base_i = obase + 8 * rank
        idxs = jnp.arange(n_elems, dtype=jnp.int32)
        for j in range(4):  # OOB writes (index out_cap) drop
            pos = jnp.where(sat, base_i + j, out_cap)
            out = out.at[pos].add(jnp.where(sat, (idxs >> (8 * j)) & 0xFF, 0))
        for j in range(4):
            pos = jnp.where(sat, base_i + 4 + j, out_cap)
            out = out.at[pos].add(jnp.where(sat, (flat >> (8 * j)) & 0xFF, 0))
        total = obase + 8 * n_out
        eb_bits = int(np.float32(eb32).view(np.uint32))
    else:
        n_out = jnp.zeros((), jnp.int32)
        total = sec_inner + inner_total
        eb_bits = 0

    meta = (
        fmt._le_bytes(eb_bits, 4)
        + fmt._le_bytes(mode, 1)
        + fmt._le_bytes(1, 1)  # quantization ndim
        + fmt._le_bytes(inner_method, 1)
        + fmt._le_bytes(0, 1)
        + fmt._le_bytes(n_out, 4)
        + fmt._le_bytes(inner_total, 4)
        + fmt._le_bytes(n_elems, 8)
        + fmt._le_bytes(0, 8)
    )
    out = out.at[sec_meta : sec_meta + fmt.LOSSY_META_FIXED].set(
        jnp.stack(meta).astype(jnp.int32)
    )
    return out.astype(jnp.uint8), total


def decode_blob_lossy(
    blob,
    *,
    chunk_symbols: int,
    n_chunks: int,
    mode: int,
    inner_method: int,
):
    """The ``lossy-fz`` decoder's whole-container hook.

    Parses the method-2 metadata at static offsets, decodes the inner
    container through the platform LZSS chain (``deflate-full`` for a
    method-1 inner), inverts the bitshuffle, and (quant mode) integrates
    the delta chain with the outlier-anchored repair before overlaying the
    exact outlier values.  Returns (nc, C) int32 f32-bit-pattern symbols.
    """
    from repro.core import pipeline  # lazy: avoid import cycle

    nc, c = n_chunks, chunk_symbols
    n_elems, units_pad, inner_nc = fmt.lossy_stream_geometry(nc, c, mode)
    inner_cap = fmt.lossy_inner_capacity(inner_nc, inner_method)
    sec_meta = fmt.HEADER_BYTES + 8 * nc
    sec_inner = sec_meta + fmt.LOSSY_META_FIXED
    need = sec_inner + inner_cap + (
        8 * n_elems if mode == fmt.LOSSY_MODE_QUANT else 0
    )
    b32 = jnp.asarray(blob, jnp.int32).reshape(-1) & 0xFF
    if b32.shape[0] < need:  # static pad: every gather below stays in range
        b32 = jnp.pad(b32, (0, need - b32.shape[0]))

    def u32(off):
        return (
            b32[off] | (b32[off + 1] << 8) | (b32[off + 2] << 16)
            | (b32[off + 3] << 24)
        )

    inner_total = u32(sec_meta + 12)
    inner_blob = b32[sec_inner : sec_inner + inner_cap]
    inner_nt, inner_ps = fmt.parse_tables_jax(inner_blob, inner_nc)
    inner_syms = pipeline.decompress_chunks(
        inner_blob,
        inner_nt,
        inner_ps,
        symbol_size=2,
        chunk_symbols=fmt.LOSSY_INNER_CHUNK_SYMBOLS,
        n_chunks=inner_nc,
        decoder=(
            "deflate-full" if inner_method == fmt.METHOD_HUFFMAN else "auto"
        ),
    )
    pairs = inner_syms.reshape(-1)[:units_pad]
    shuffled = (
        jnp.stack([pairs & 0xFF, (pairs >> 8) & 0xFF], axis=1)
        .reshape(-1)
        .astype(jnp.uint8)
    )
    units = bitshuffle.unshuffle(shuffled).astype(jnp.int32)

    if mode == fmt.LOSSY_MODE_LOSSLESS:
        u = units[: 2 * n_elems].reshape(n_elems, 2)
        return (u[:, 0] | (u[:, 1] << 16)).reshape(nc, c)

    eb2 = 2.0 * lax.bitcast_convert_type(u32(sec_meta), jnp.float32)
    n_out = u32(sec_meta + 8)
    codes = units[:n_elems]
    q = jnp.cumsum(codes - quant.CENTER)

    # sparse outlier section -> dense mask/values (OOB-dropped scatter)
    k = jnp.arange(n_elems, dtype=jnp.int32)
    live = k < n_out
    pbase = sec_inner + inner_total + 8 * k

    def g(off):
        return jnp.take(b32, pbase + off)

    oidx = g(0) | (g(1) << 8) | (g(2) << 16) | (g(3) << 24)
    obits = g(4) | (g(5) << 8) | (g(6) << 16) | (g(7) << 24)
    tgt = jnp.where(live, jnp.clip(oidx, 0, n_elems - 1), n_elems)
    mask = jnp.zeros((n_elems + 1,), jnp.bool_).at[tgt].set(True)[:n_elems]
    vbits = (
        jnp.zeros((n_elems + 1,), jnp.int32)
        .at[tgt]
        .set(jnp.where(live, obits, 0))[:n_elems]
    )
    ovals = lax.bitcast_convert_type(vbits, jnp.float32)

    # chain repair, mirroring quant.dequantize's ndim=1 path with traced eb
    _, _, q_ref = _prequant(ovals, _rcp(eb2))
    last = lax.cummax(jnp.where(mask, k, -1))
    adj = jnp.where(mask, q_ref - q, 0)
    carry = jnp.take(adj, jnp.maximum(last, 0))
    q = q + jnp.where(last >= 0, carry, 0)
    x = q.astype(jnp.float32) * eb2
    x = jnp.where(mask, ovals, x)
    return lax.bitcast_convert_type(x, jnp.int32).reshape(nc, c)
