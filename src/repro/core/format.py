"""GPULZ container format.

Layout (little-endian):

  offset  size        field
  ------  ----        -----
  0       4           magic  b"GPLZ"
  4       1           version (1)
  5       1           symbol_size S (1, 2 or 4)
  6       2           window W (u16, <= 255)
  8       4           chunk_symbols C (u32)
  12      4           n_chunks (u32)
  16      8           orig_bytes (u64)
  24      8           payload_bytes total (u64)
  32      8           flag_bytes total (u64)
  40      8           reserved
  48      4*nc        section A: per-chunk token counts (u32)
  +       4*nc        section B: per-chunk payload sizes (u32)
  +       flag_bytes  section C: per-chunk flag arrays, concatenated
  +       payload     section D: per-chunk payloads, concatenated

The flag array + two per-chunk size tables mirror the paper's format (flag
array per §2.2; the two tables are what Kernel II prefix-sums).  Sections C/D
are compact (deflated); A/B let the decoder rebuild every chunk's offsets with
two exclusive prefix sums — decompression needs no sequential parse.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

MAGIC = (0x47, 0x50, 0x4C, 0x5A)  # "GPLZ"
VERSION = 1
HEADER_BYTES = 48


@dataclasses.dataclass(frozen=True)
class Header:
    symbol_size: int
    window: int
    chunk_symbols: int
    n_chunks: int
    orig_bytes: int
    payload_bytes: int
    flag_bytes: int

    @property
    def sec_a(self) -> int:
        return HEADER_BYTES

    @property
    def sec_b(self) -> int:
        return self.sec_a + 4 * self.n_chunks

    @property
    def sec_flags(self) -> int:
        return self.sec_b + 4 * self.n_chunks

    @property
    def sec_payload(self) -> int:
        return self.sec_flags + self.flag_bytes

    @property
    def total_bytes(self) -> int:
        return self.sec_payload + self.payload_bytes


def max_compressed_bytes(n_bytes: int, symbol_size: int, chunk_symbols: int) -> int:
    """Worst-case container size (all-literal chunks)."""
    nsym = -(-n_bytes // symbol_size)
    nc = max(1, -(-nsym // chunk_symbols))
    cb = (chunk_symbols + 7) // 8
    return HEADER_BYTES + 8 * nc + nc * cb + nc * chunk_symbols * symbol_size


def _le_bytes(value, n):
    """Decompose a (possibly traced) scalar into n little-endian bytes.

    Static python ints use exact arithmetic; traced values are int32
    in-graph (x64 disabled) — container sizes are bounded by per-call block
    sizes (<2 GiB; larger tensors are slab-split by callers), so 4 live
    bytes suffice; the u64 header fields exist for format stability.
    """
    if isinstance(value, int):
        return [
            jnp.asarray((value >> (8 * k)) & 0xFF, jnp.int32)
            for k in range(n)
        ]
    value = jnp.asarray(value, jnp.int32)
    out = [(value >> (8 * k)) & 0xFF for k in range(min(n, 4))]
    out += [jnp.zeros((), jnp.int32)] * (n - len(out))
    return out


def write_header_and_tables(out, *, symbol_size, window, chunk_symbols,
                            n_chunks, orig_bytes, payload_total, flag_total,
                            n_tokens, payload_sizes):
    """Fill header + sections A/B of the flat int32 byte buffer ``out``."""
    static = list(MAGIC) + [VERSION, symbol_size, window & 0xFF, window >> 8]
    static += [
        (chunk_symbols >> (8 * k)) & 0xFF for k in range(4)
    ] + [(n_chunks >> (8 * k)) & 0xFF for k in range(4)]
    out = out.at[0:16].set(jnp.array(static, jnp.int32))
    dyn = (
        _le_bytes(orig_bytes, 8)
        + _le_bytes(payload_total, 8)
        + _le_bytes(flag_total, 8)
        + [jnp.zeros((), jnp.int32)] * 8
    )
    out = out.at[16:48].set(jnp.stack(dyn).astype(jnp.int32))
    # sections A (token counts) and B (payload sizes), u32 little-endian
    sec_a = HEADER_BYTES
    sec_b = sec_a + 4 * n_chunks
    for k in range(4):
        out = out.at[sec_a + k : sec_a + 4 * n_chunks : 4].set(
            (n_tokens >> (8 * k)) & 0xFF
        )
        out = out.at[sec_b + k : sec_b + 4 * n_chunks : 4].set(
            (payload_sizes >> (8 * k)) & 0xFF
        )
    return out


def parse_header(blob: np.ndarray) -> Header:
    """Host-side header parse (numpy uint8 array)."""
    blob = np.asarray(blob, np.uint8)
    if blob.size < HEADER_BYTES:
        # before any field access: a chopped prefix can keep a valid magic
        # (blob[:4]) and then index out of bounds on the fixed fields
        raise ValueError(
            f"truncated container: the header alone is {HEADER_BYTES} bytes "
            f"but only {blob.size} bytes are present"
        )
    if tuple(int(b) for b in blob[:4]) != MAGIC:
        raise ValueError("bad magic: not a GPULZ container")
    if int(blob[4]) != VERSION:
        raise ValueError(f"unsupported version {int(blob[4])}")

    def u(lo, n):
        return int.from_bytes(bytes(blob[lo : lo + n]), "little")

    return Header(
        symbol_size=int(blob[5]),
        window=u(6, 2),
        chunk_symbols=u(8, 4),
        n_chunks=u(12, 4),
        orig_bytes=u(16, 8),
        payload_bytes=u(24, 8),
        flag_bytes=u(32, 8),
    )


def parse_tables(blob: np.ndarray, header: Header):
    """Host-side sections A/B parse -> (n_tokens, payload_sizes) uint32."""
    blob = np.asarray(blob, np.uint8)
    nc = header.n_chunks
    a = blob[header.sec_a : header.sec_a + 4 * nc].view(np.uint32).copy()
    b = blob[header.sec_b : header.sec_b + 4 * nc].view(np.uint32).copy()
    return a.astype(np.int32), b.astype(np.int32)


def validate_container(blob: np.ndarray, header: Header | None = None):
    """Host-side sanity check before a blob is handed to the decoder.

    The in-graph decode path is bounds-checked but *silent*: a truncated or
    table-corrupted container would decode to garbage symbols instead of
    failing.  This raises a ``ValueError`` naming the expected vs actual
    byte counts (or the offending table entry) first.  Returns the parsed
    ``(header, n_tokens, payload_sizes)`` so callers don't parse twice.

    Header-geometry corruption detection is best-effort: the checks catch
    every truncation, out-of-range field and table inconsistency, but a
    flipped field whose corrupted value describes a *different valid
    container over the same tables* (e.g. symbol_size 2 -> 4 when every
    chunk is all-pointers) is indistinguishable without decoding — that is
    what the containers' checksummed transport (checkpoint files, KV
    store) is for.
    """
    blob = np.asarray(blob, np.uint8)
    h = parse_header(blob) if header is None else header
    # geometry fields first: a flipped header byte (e.g. symbol_size 1->2)
    # passes every byte-count cross-check below and would decode to silent
    # garbage; re-apply the write-side invariants
    if h.symbol_size not in (1, 2, 4):
        raise ValueError(
            f"corrupted container: symbol_size {h.symbol_size} not in (1, 2, 4)"
        )
    if not 1 <= h.window <= 255:
        raise ValueError(
            f"corrupted container: window {h.window} not in [1, 255]"
        )
    if h.chunk_symbols <= 0 or h.chunk_symbols % 8:
        raise ValueError(
            f"corrupted container: chunk_symbols {h.chunk_symbols} is not a "
            f"positive multiple of 8"
        )
    if h.n_chunks < 1:
        raise ValueError(f"corrupted container: n_chunks {h.n_chunks} < 1")
    if blob.size < h.total_bytes:
        raise ValueError(
            f"truncated container: header declares {h.total_bytes} bytes "
            f"({HEADER_BYTES} header + {8 * h.n_chunks} tables + "
            f"{h.flag_bytes} flags + {h.payload_bytes} payload) but only "
            f"{blob.size} bytes are present"
        )
    n_tokens, payload_sizes = parse_tables(blob, h)
    c, s = h.chunk_symbols, h.symbol_size
    for name, table, cap in (
        ("n_tokens", n_tokens, c),
        ("payload_sizes", payload_sizes, c * s),
    ):
        bad = np.nonzero((table < 0) | (table > cap))[0]
        if bad.size:
            i = int(bad[0])
            raise ValueError(
                f"corrupted container: table {name}[{i}] = {int(table[i])} "
                f"exceeds the per-chunk bound {cap} "
                f"(C={c}, S={s})"
            )
    # per-chunk token/byte consistency: a chunk's payload is 2 bytes per
    # pointer + S per literal, so min(2, S)*n_tokens <= payload_sizes <=
    # max(2, S)*n_tokens must hold chunk-wise.  This is what actually trips
    # on a flipped symbol_size byte (e.g. 1 -> 2 forces equality at
    # 2*n_tokens, which real mixed chunks don't satisfy) — the membership
    # checks above can't, because {1, 2, 4} are all legal values.
    lo_b = min(2, s) * n_tokens
    hi_b = max(2, s) * n_tokens
    bad = np.nonzero((payload_sizes < lo_b) | (payload_sizes > hi_b))[0]
    if bad.size:
        i = int(bad[0])
        raise ValueError(
            f"corrupted container: chunk {i} has payload_sizes={int(payload_sizes[i])} "
            f"outside [{int(lo_b[i])}, {int(hi_b[i])}] implied by "
            f"n_tokens={int(n_tokens[i])} and symbol_size={s}"
        )
    flag_total = int(((n_tokens + 7) // 8).sum())
    pay_total = int(payload_sizes.sum())
    if flag_total != h.flag_bytes or pay_total != h.payload_bytes:
        raise ValueError(
            f"corrupted container: header declares {h.flag_bytes} flag + "
            f"{h.payload_bytes} payload bytes but the per-chunk tables sum "
            f"to {flag_total} + {pay_total}"
        )
    if h.orig_bytes > h.n_chunks * c * s:
        raise ValueError(
            f"corrupted container: orig_bytes {h.orig_bytes} exceeds the "
            f"chunk capacity {h.n_chunks * c * s} "
            f"(n_chunks={h.n_chunks}, C={c}, S={s})"
        )
    return h, n_tokens, payload_sizes


def parse_tables_jax(blob_i32, n_chunks: int):
    """In-graph sections A/B parse (u32 little-endian).

    ``blob_i32`` is a container as a flat int32 byte buffer (traced);
    ``n_chunks`` must be static.  Used by consumers that decode containers
    inside jit (gradient exchange, batched decompression).
    """

    def sec(base):
        rows = blob_i32[base : base + 4 * n_chunks].reshape(n_chunks, 4)
        return (
            rows[:, 0] | (rows[:, 1] << 8) | (rows[:, 2] << 16)
            | (rows[:, 3] << 24)
        )

    return sec(HEADER_BYTES), sec(HEADER_BYTES + 4 * n_chunks)
