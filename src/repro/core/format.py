"""GPULZ container format.

Layout (little-endian):

  offset  size        field
  ------  ----        -----
  0       4           magic  b"GPLZ"
  4       1           version (2; version-1 blobs remain readable)
  5       1           symbol_size S (1, 2 or 4)
  6       2           window W (u16, <= 255)
  8       4           chunk_symbols C (u32)
  12      4           n_chunks (u32)
  16      8           orig_bytes (u64)
  24      8           payload_bytes total (u64, RAW/decoded size)
  32      8           flag_bytes total (u64, RAW/decoded size)
  40      1           method: 0 raw LZSS sections, 1 canonical Huffman,
                      2 error-bounded lossy (quantize+bitshuffle+LZSS)
  41      1           sub_log2: gap sub-block size log2 (method 1; else 0)
  42      6           reserved
  48      4*nc        section A: per-chunk token counts (u32)
  +       4*nc        section B: per-chunk payload sizes (u32)

method 0 (raw, the version-1 layout after the tables):

  +       flag_bytes  section C: per-chunk flag arrays, concatenated
  +       payload     section D: per-chunk payloads, concatenated

method 1 (``deflate-full``: sections C/D replaced by canonical-Huffman
bitstreams with gap-array parallel entry points, core/entropy.py):

  +       128         flag codebook: nibble-packed code lengths (sym 2i in
                      the low nibble of byte i, sym 2i+1 in the high)
  +       128         payload codebook, same packing
  +       8           flag_bits (u64): flag bitstream length in bits
  +       8           payload_bits (u64)
  +       4*nsub_f    flag gap array: u32 bit offset of every SUB-th
                      decoded byte's codeword, SUB = 1 << sub_log2,
                      nsub_f = ceil(flag_bytes / SUB)
  +       4*nsub_p    payload gap array, nsub_p = ceil(payload_bytes / SUB)
  +       ...         flag bitstream, ceil(flag_bits / 8) bytes
  +       ...         payload bitstream, ceil(payload_bits / 8) bytes

The flag array + two per-chunk size tables mirror the paper's format (flag
array per §2.2; the two tables are what Kernel II prefix-sums).  Sections C/D
are compact (deflated); A/B let the decoder rebuild every chunk's offsets with
two exclusive prefix sums — decompression needs no sequential parse.  Method-1
containers keep A/B verbatim and store the RAW section sizes in the header, so
the same prefix sums still hold after the bitstreams are gap-decoded; bit
offsets are int32 in-graph, bounding one container's sections at 2**28 bytes
(the same slab-split regime as ``_le_bytes``).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

MAGIC = (0x47, 0x50, 0x4C, 0x5A)  # "GPLZ"
VERSION = 2
SUPPORTED_VERSIONS = (1, 2)
HEADER_BYTES = 48

METHOD_RAW = 0  # sections C/D are raw LZSS bytes (the version-1 layout)
METHOD_HUFFMAN = 1  # sections C/D are canonical-Huffman bitstreams
METHOD_LOSSY = 2  # error-bounded lossy payload (core/lossy.py)
DEFAULT_SUB_LOG2 = 9  # gap-array sub-block: one entry per 512 decoded bytes
ENTROPY_META_FIXED = 272  # 2 x 128 B codebooks + 2 x 8 B bit counts
SUPPORTED_METHODS = (METHOD_RAW, METHOD_HUFFMAN, METHOD_LOSSY)

# method-2 (lossy-fz) fixed metadata, at ``sec_meta`` where raw section C
# would start (the A/B tables are stored as zeros — the lossy payload has
# no per-chunk sections; the outer geometry describes the *reconstructed*
# f32 element stream):
#
#   +0   u32  error bound, f32 bit pattern (0 => lossless mode)
#   +4   u8   mode: 0 lossless passthrough, 1 quantized
#   +5   u8   quantization ndim (always 1: the flattened element stream)
#   +6   u8   inner container method (0 raw LZSS, 1 deflate-full)
#   +7   u8   reserved
#   +8   u32  n_outliers (quantizer saturation escapes)
#   +12  u32  inner container live bytes
#   +16  u64  n_elems: padded f32 element capacity (n_chunks*chunk_symbols)
#   +24  8B   reserved
#
# then the complete inner container (bitshuffled code stream through the
# lossless backend) at ``sec_lossy_inner``, then ``n_outliers`` 8-byte
# (u32 element index, u32 f32 bit pattern) pairs at ``sec_outliers``.
LOSSY_META_FIXED = 32
LOSSY_MODE_LOSSLESS = 0
LOSSY_MODE_QUANT = 1


@dataclasses.dataclass(frozen=True)
class Header:
    symbol_size: int
    window: int
    chunk_symbols: int
    n_chunks: int
    orig_bytes: int
    payload_bytes: int
    flag_bytes: int
    version: int = VERSION
    method: int = METHOD_RAW
    sub_log2: int = 0
    flag_bits: int = 0
    payload_bits: int = 0
    # method-2 (lossy) metadata, parsed from the fixed block at sec_meta
    lossy_eb_bits: int = 0
    lossy_mode: int = 0
    lossy_ndim: int = 0
    inner_method: int = 0
    n_outliers: int = 0
    inner_total: int = 0
    n_elems: int = 0

    @property
    def sec_a(self) -> int:
        return HEADER_BYTES

    @property
    def sec_b(self) -> int:
        return self.sec_a + 4 * self.n_chunks

    @property
    def sec_flags(self) -> int:
        return self.sec_b + 4 * self.n_chunks

    @property
    def sec_payload(self) -> int:
        return self.sec_flags + self.flag_bytes

    # ------------------------------------ method-1 (entropy) layout
    @property
    def sec_meta(self) -> int:
        """Codebooks + bit counts start where raw section C would."""
        return self.sec_b + 4 * self.n_chunks

    @property
    def n_sub_flags(self) -> int:
        return -(-self.flag_bytes // (1 << self.sub_log2))

    @property
    def n_sub_payload(self) -> int:
        return -(-self.payload_bytes // (1 << self.sub_log2))

    @property
    def sec_gap_flags(self) -> int:
        return self.sec_meta + ENTROPY_META_FIXED

    @property
    def sec_gap_payload(self) -> int:
        return self.sec_gap_flags + 4 * self.n_sub_flags

    @property
    def sec_stream_flags(self) -> int:
        return self.sec_gap_payload + 4 * self.n_sub_payload

    @property
    def sec_stream_payload(self) -> int:
        return self.sec_stream_flags + (self.flag_bits + 7) // 8

    # ------------------------------------- method-2 (lossy) layout
    @property
    def sec_lossy_inner(self) -> int:
        """The complete inner (lossless) container, at a static offset."""
        return self.sec_meta + LOSSY_META_FIXED

    @property
    def sec_outliers(self) -> int:
        """The (u32 idx, u32 f32-bits) outlier pairs, after the inner."""
        return self.sec_lossy_inner + self.inner_total

    @property
    def total_bytes(self) -> int:
        if self.method == METHOD_HUFFMAN:
            return self.sec_stream_payload + (self.payload_bits + 7) // 8
        if self.method == METHOD_LOSSY:
            return self.sec_outliers + 8 * self.n_outliers
        return self.sec_payload + self.payload_bytes


def max_compressed_bytes(n_bytes: int, symbol_size: int, chunk_symbols: int) -> int:
    """Worst-case container size (all-literal chunks)."""
    nsym = -(-n_bytes // symbol_size)
    nc = max(1, -(-nsym // chunk_symbols))
    cb = (chunk_symbols + 7) // 8
    return HEADER_BYTES + 8 * nc + nc * cb + nc * chunk_symbols * symbol_size


def entropy_meta_bytes(
    flag_cap: int, payload_cap: int, sub_log2: int = DEFAULT_SUB_LOG2
) -> int:
    """Method-1 metadata overhead over the raw layout at section capacity."""
    sub = 1 << sub_log2
    return ENTROPY_META_FIXED + 4 * -(-flag_cap // sub) + 4 * -(-payload_cap // sub)


def entropy_max_compressed_bytes(
    n_bytes: int, symbol_size: int, chunk_symbols: int,
    sub_log2: int = DEFAULT_SUB_LOG2,
) -> int:
    """Worst-case method-1 container size.

    The stored-escape in ``entropy.container_code_lengths`` caps each
    bitstream at its raw section size (8 bits/byte), so the worst case is
    the raw worst case plus the fixed metadata + gap arrays — incompressible
    input cannot expand past this bound (tested in tests/test_entropy.py).
    """
    nsym = -(-n_bytes // symbol_size)
    nc = max(1, -(-nsym // chunk_symbols))
    cb = (chunk_symbols + 7) // 8
    return max_compressed_bytes(n_bytes, symbol_size, chunk_symbols) + (
        entropy_meta_bytes(nc * cb, nc * chunk_symbols * symbol_size, sub_log2)
    )


# Inner-container geometry for method-2 payloads: fixed by the wire format
# (core/lossy.py asserts its stage constants agree).  The inner container is
# an S=2 LZSS/deflate-full container over the bitshuffled uint16 unit
# stream; units are padded to whole bitshuffle blocks, then to whole inner
# chunks.
LOSSY_INNER_CHUNK_SYMBOLS = 2048
LOSSY_BLOCK_UNITS = 512  # == core/bitshuffle.py BLOCK_UNITS


def lossy_stream_geometry(n_chunks: int, chunk_symbols: int, mode: int):
    """Static method-2 stream geometry implied by the outer header.

    Returns ``(n_elems, units_pad, inner_n_chunks)``: the padded f32
    element capacity, the bitshuffled uint16 unit count (quant mode codes
    one unit per element; lossless mode stores both halves), and the inner
    container's chunk count.
    """
    n_elems = n_chunks * chunk_symbols
    units = n_elems if mode == LOSSY_MODE_QUANT else 2 * n_elems
    units_pad = -(-units // LOSSY_BLOCK_UNITS) * LOSSY_BLOCK_UNITS
    inner_nc = max(1, -(-units_pad // LOSSY_INNER_CHUNK_SYMBOLS))
    return n_elems, units_pad, inner_nc


def lossy_inner_capacity(inner_nc: int, inner_method: int) -> int:
    """Worst-case byte capacity of a method-2 payload's inner container."""
    nbytes = inner_nc * LOSSY_INNER_CHUNK_SYMBOLS * 2
    if inner_method == METHOD_HUFFMAN:
        return entropy_max_compressed_bytes(
            nbytes, 2, LOSSY_INNER_CHUNK_SYMBOLS
        )
    return max_compressed_bytes(nbytes, 2, LOSSY_INNER_CHUNK_SYMBOLS)


def lossy_max_compressed_bytes(n_bytes: int, chunk_symbols: int) -> int:
    """Worst-case method-2 container size for ``n_bytes`` of f32 input.

    Upper-bounds both modes: the lossless-mode inner stream (two units per
    element, entropy metadata included — a superset of the quant-mode inner
    capacity) plus the quant-mode worst case of every element escaping as
    an 8-byte outlier pair.
    """
    n_elems = -(-n_bytes // 4)
    nc = max(1, -(-n_elems // chunk_symbols))
    cap_elems, _, inner_nc = lossy_stream_geometry(
        nc, chunk_symbols, LOSSY_MODE_LOSSLESS
    )
    return (
        HEADER_BYTES
        + 8 * nc
        + LOSSY_META_FIXED
        + lossy_inner_capacity(inner_nc, METHOD_HUFFMAN)
        + 8 * cap_elems
    )


def _le_bytes(value, n):
    """Decompose a (possibly traced) scalar into n little-endian bytes.

    Static python ints use exact arithmetic; traced values are int32
    in-graph (x64 disabled) — container sizes are bounded by per-call block
    sizes (<2 GiB; larger tensors are slab-split by callers), so 4 live
    bytes suffice; the u64 header fields exist for format stability.
    """
    if isinstance(value, int):
        return [
            jnp.asarray((value >> (8 * k)) & 0xFF, jnp.int32)
            for k in range(n)
        ]
    value = jnp.asarray(value, jnp.int32)
    out = [(value >> (8 * k)) & 0xFF for k in range(min(n, 4))]
    out += [jnp.zeros((), jnp.int32)] * (n - len(out))
    return out


def write_header_and_tables(out, *, symbol_size, window, chunk_symbols,
                            n_chunks, orig_bytes, payload_total, flag_total,
                            n_tokens, payload_sizes,
                            method=METHOD_RAW, sub_log2=0):
    """Fill header + sections A/B of the flat int32 byte buffer ``out``."""
    static = list(MAGIC) + [VERSION, symbol_size, window & 0xFF, window >> 8]
    static += [
        (chunk_symbols >> (8 * k)) & 0xFF for k in range(4)
    ] + [(n_chunks >> (8 * k)) & 0xFF for k in range(4)]
    out = out.at[0:16].set(jnp.array(static, jnp.int32))
    dyn = (
        _le_bytes(orig_bytes, 8)
        + _le_bytes(payload_total, 8)
        + _le_bytes(flag_total, 8)
        + _le_bytes(int(method), 1)
        + _le_bytes(int(sub_log2), 1)
        + [jnp.zeros((), jnp.int32)] * 6
    )
    out = out.at[16:48].set(jnp.stack(dyn).astype(jnp.int32))
    # sections A (token counts) and B (payload sizes), u32 little-endian
    sec_a = HEADER_BYTES
    sec_b = sec_a + 4 * n_chunks
    for k in range(4):
        out = out.at[sec_a + k : sec_a + 4 * n_chunks : 4].set(
            (n_tokens >> (8 * k)) & 0xFF
        )
        out = out.at[sec_b + k : sec_b + 4 * n_chunks : 4].set(
            (payload_sizes >> (8 * k)) & 0xFF
        )
    return out


def parse_header(blob: np.ndarray) -> Header:
    """Host-side header parse (numpy uint8 array)."""
    blob = np.asarray(blob, np.uint8)
    if blob.size < HEADER_BYTES:
        # before any field access: a chopped prefix can keep a valid magic
        # (blob[:4]) and then index out of bounds on the fixed fields
        raise ValueError(
            f"truncated container: the header alone is {HEADER_BYTES} bytes "
            f"but only {blob.size} bytes are present"
        )
    if tuple(int(b) for b in blob[:4]) != MAGIC:
        raise ValueError("bad magic: not a GPULZ container")
    version = int(blob[4])
    if version not in SUPPORTED_VERSIONS:
        raise ValueError(
            f"unsupported version: container declares version {version} but "
            f"this reader expects one of {SUPPORTED_VERSIONS}"
        )

    def u(lo, n):
        return int.from_bytes(bytes(blob[lo : lo + n]), "little")

    # version 1 predates the method byte: bytes 40-47 were reserved zeros
    method = int(blob[40]) if version >= 2 else METHOD_RAW
    sub_log2 = int(blob[41]) if version >= 2 else 0
    if method not in SUPPORTED_METHODS:
        raise ValueError(
            f"corrupted container: method byte {method} not in "
            f"{SUPPORTED_METHODS}"
        )
    h = Header(
        symbol_size=int(blob[5]),
        window=u(6, 2),
        chunk_symbols=u(8, 4),
        n_chunks=u(12, 4),
        orig_bytes=u(16, 8),
        payload_bytes=u(24, 8),
        flag_bytes=u(32, 8),
        version=version,
        method=method,
        sub_log2=sub_log2,
    )
    if method == METHOD_HUFFMAN:
        need = h.sec_meta + ENTROPY_META_FIXED
        if blob.size < need:
            raise ValueError(
                f"truncated container: method-1 metadata ends at byte {need} "
                f"but only {blob.size} bytes are present"
            )
        h = dataclasses.replace(
            h,
            flag_bits=u(h.sec_meta + 256, 8),
            payload_bits=u(h.sec_meta + 264, 8),
        )
    if method == METHOD_LOSSY:
        need = h.sec_meta + LOSSY_META_FIXED
        if blob.size < need:
            raise ValueError(
                f"truncated container: method-2 metadata ends at byte {need} "
                f"but only {blob.size} bytes are present"
            )
        m = h.sec_meta
        h = dataclasses.replace(
            h,
            lossy_eb_bits=u(m, 4),
            lossy_mode=int(blob[m + 4]),
            lossy_ndim=int(blob[m + 5]),
            inner_method=int(blob[m + 6]),
            n_outliers=u(m + 8, 4),
            inner_total=u(m + 12, 4),
            n_elems=u(m + 16, 8),
        )
    return h


def parse_tables(blob: np.ndarray, header: Header):
    """Host-side sections A/B parse -> (n_tokens, payload_sizes) uint32."""
    blob = np.asarray(blob, np.uint8)
    nc = header.n_chunks
    a = blob[header.sec_a : header.sec_a + 4 * nc].view(np.uint32).copy()
    b = blob[header.sec_b : header.sec_b + 4 * nc].view(np.uint32).copy()
    return a.astype(np.int32), b.astype(np.int32)


def validate_container(blob: np.ndarray, header: Header | None = None):
    """Host-side sanity check before a blob is handed to the decoder.

    The in-graph decode path is bounds-checked but *silent*: a truncated or
    table-corrupted container would decode to garbage symbols instead of
    failing.  This raises a ``ValueError`` naming the expected vs actual
    byte counts (or the offending table entry) first.  Returns the parsed
    ``(header, n_tokens, payload_sizes)`` so callers don't parse twice.

    Header-geometry corruption detection is best-effort: the checks catch
    every truncation, out-of-range field and table inconsistency, but a
    flipped field whose corrupted value describes a *different valid
    container over the same tables* (e.g. symbol_size 2 -> 4 when every
    chunk is all-pointers) is indistinguishable without decoding — that is
    what the containers' checksummed transport (checkpoint files, KV
    store) is for.
    """
    blob = np.asarray(blob, np.uint8)
    h = parse_header(blob) if header is None else header
    # geometry fields first: a flipped header byte (e.g. symbol_size 1->2)
    # passes every byte-count cross-check below and would decode to silent
    # garbage; re-apply the write-side invariants
    if h.symbol_size not in (1, 2, 4):
        raise ValueError(
            f"corrupted container: symbol_size {h.symbol_size} not in (1, 2, 4)"
        )
    if not 1 <= h.window <= 255:
        raise ValueError(
            f"corrupted container: window {h.window} not in [1, 255]"
        )
    if h.chunk_symbols <= 0 or h.chunk_symbols % 8:
        raise ValueError(
            f"corrupted container: chunk_symbols {h.chunk_symbols} is not a "
            f"positive multiple of 8"
        )
    if h.n_chunks < 1:
        raise ValueError(f"corrupted container: n_chunks {h.n_chunks} < 1")
    if blob.size < h.total_bytes:
        raise ValueError(
            f"truncated container: header declares {h.total_bytes} bytes "
            f"({HEADER_BYTES} header + {8 * h.n_chunks} tables + "
            f"{h.flag_bytes} flags + {h.payload_bytes} payload) but only "
            f"{blob.size} bytes are present"
        )
    n_tokens, payload_sizes = parse_tables(blob, h)
    c, s = h.chunk_symbols, h.symbol_size
    for name, table, cap in (
        ("n_tokens", n_tokens, c),
        ("payload_sizes", payload_sizes, c * s),
    ):
        bad = np.nonzero((table < 0) | (table > cap))[0]
        if bad.size:
            i = int(bad[0])
            raise ValueError(
                f"corrupted container: table {name}[{i}] = {int(table[i])} "
                f"exceeds the per-chunk bound {cap} "
                f"(C={c}, S={s})"
            )
    # per-chunk token/byte consistency: a chunk's payload is 2 bytes per
    # pointer + S per literal, so min(2, S)*n_tokens <= payload_sizes <=
    # max(2, S)*n_tokens must hold chunk-wise.  This is what actually trips
    # on a flipped symbol_size byte (e.g. 1 -> 2 forces equality at
    # 2*n_tokens, which real mixed chunks don't satisfy) — the membership
    # checks above can't, because {1, 2, 4} are all legal values.
    lo_b = min(2, s) * n_tokens
    hi_b = max(2, s) * n_tokens
    bad = np.nonzero((payload_sizes < lo_b) | (payload_sizes > hi_b))[0]
    if bad.size:
        i = int(bad[0])
        raise ValueError(
            f"corrupted container: chunk {i} has payload_sizes={int(payload_sizes[i])} "
            f"outside [{int(lo_b[i])}, {int(hi_b[i])}] implied by "
            f"n_tokens={int(n_tokens[i])} and symbol_size={s}"
        )
    flag_total = int(((n_tokens + 7) // 8).sum())
    pay_total = int(payload_sizes.sum())
    if flag_total != h.flag_bytes or pay_total != h.payload_bytes:
        raise ValueError(
            f"corrupted container: header declares {h.flag_bytes} flag + "
            f"{h.payload_bytes} payload bytes but the per-chunk tables sum "
            f"to {flag_total} + {pay_total}"
        )
    if h.orig_bytes > h.n_chunks * c * s:
        raise ValueError(
            f"corrupted container: orig_bytes {h.orig_bytes} exceeds the "
            f"chunk capacity {h.n_chunks * c * s} "
            f"(n_chunks={h.n_chunks}, C={c}, S={s})"
        )
    if h.method == METHOD_HUFFMAN:
        _validate_entropy_sections(blob, h)
    if h.method == METHOD_LOSSY:
        _validate_lossy_sections(blob, h)
    return h, n_tokens, payload_sizes


def _validate_entropy_sections(blob: np.ndarray, h: Header) -> None:
    """Method-1 cross-checks: codebooks, bit counts, gap arrays.

    The in-graph gap decoder clips every bitstream access, so a corrupted
    gap entry or oversubscribed codebook decodes to silent garbage; this
    raises first.  ``parse_header`` already guaranteed the fixed metadata
    is present and the caller checked ``total_bytes`` truncation.
    """
    if h.sub_log2 != DEFAULT_SUB_LOG2:
        raise ValueError(
            f"unsupported container: gap sub-block log2 {h.sub_log2}; this "
            f"reader supports only {DEFAULT_SUB_LOG2} "
            f"(sub-block {1 << DEFAULT_SUB_LOG2} bytes)"
        )
    for name, bits, raw in (
        ("flag", h.flag_bits, h.flag_bytes),
        ("payload", h.payload_bits, h.payload_bytes),
    ):
        if bits > 8 * raw:
            raise ValueError(
                f"corrupted container: {name} bitstream declares {bits} bits "
                f"for {raw} decoded bytes — the stored escape caps it at "
                f"{8 * raw}"
            )
    for name, base, raw in (
        ("flag", h.sec_meta, h.flag_bytes),
        ("payload", h.sec_meta + 128, h.payload_bytes),
    ):
        packed = blob[base : base + 128].astype(np.int64)
        lens = np.stack([packed & 0xF, packed >> 4], axis=1).reshape(-1)
        kraft = int(np.where(lens > 0, 1 << (15 - lens), 0).sum())
        if kraft > 1 << 15:
            raise ValueError(
                f"corrupted container: {name} codebook oversubscribes the "
                f"code space (Kraft sum {kraft} > {1 << 15})"
            )
        if raw > 0 and kraft == 0:
            raise ValueError(
                f"corrupted container: {name} codebook is empty but the "
                f"section decodes {raw} bytes"
            )
    for name, base, nsub, bits in (
        ("flag", h.sec_gap_flags, h.n_sub_flags, h.flag_bits),
        ("payload", h.sec_gap_payload, h.n_sub_payload, h.payload_bits),
    ):
        gaps = blob[base : base + 4 * nsub].view(np.uint32).astype(np.int64)
        if nsub and gaps[0] != 0:
            raise ValueError(
                f"corrupted container: {name} gap array starts at bit "
                f"{int(gaps[0])}, expected 0"
            )
        if (np.diff(gaps) < 0).any() or (gaps >= max(bits, 1)).any():
            raise ValueError(
                f"corrupted container: {name} gap array is not a monotone "
                f"sequence of entry points below the {bits}-bit stream"
            )


def _validate_lossy_sections(blob: np.ndarray, h: Header) -> None:
    """Method-2 cross-checks: metadata fields, inner container, outliers.

    The in-graph lossy decoder clips every access, so corrupted metadata
    decodes to silent garbage; this raises first.  The inner container is
    validated recursively — it is a complete container with its own header,
    tables and (for a deflate-full inner) entropy metadata.
    """
    if h.symbol_size != 4:
        raise ValueError(
            f"corrupted container: method-2 payloads reconstruct f32 "
            f"elements (symbol_size 4), header declares {h.symbol_size}"
        )
    if h.lossy_mode not in (LOSSY_MODE_LOSSLESS, LOSSY_MODE_QUANT):
        raise ValueError(
            f"corrupted container: lossy mode byte {h.lossy_mode} not in "
            f"({LOSSY_MODE_LOSSLESS}, {LOSSY_MODE_QUANT})"
        )
    if h.lossy_ndim != 1:
        raise ValueError(
            f"unsupported container: lossy quantization ndim "
            f"{h.lossy_ndim}; this reader supports only 1"
        )
    if h.inner_method not in (METHOD_RAW, METHOD_HUFFMAN):
        raise ValueError(
            f"corrupted container: lossy inner method byte "
            f"{h.inner_method} not in ({METHOD_RAW}, {METHOD_HUFFMAN})"
        )
    n_elems, _, inner_nc = lossy_stream_geometry(
        h.n_chunks, h.chunk_symbols, h.lossy_mode
    )
    if h.n_elems != n_elems:
        raise ValueError(
            f"corrupted container: lossy n_elems {h.n_elems} does not "
            f"match the geometry-implied capacity {n_elems} "
            f"(n_chunks={h.n_chunks}, C={h.chunk_symbols})"
        )
    if h.lossy_mode == LOSSY_MODE_QUANT:
        eb = np.uint32(h.lossy_eb_bits).view(np.float32)
        if not np.isfinite(eb) or eb <= 0:
            raise ValueError(
                f"corrupted container: quant-mode error bound {eb} "
                f"(bits 0x{h.lossy_eb_bits:08x}) is not a positive finite "
                f"f32"
            )
        if h.n_outliers > n_elems:
            raise ValueError(
                f"corrupted container: {h.n_outliers} outlier pairs exceed "
                f"the element capacity {n_elems}"
            )
    elif h.n_outliers:
        raise ValueError(
            f"corrupted container: lossless-mode payload declares "
            f"{h.n_outliers} outlier pairs, expected 0"
        )
    if h.inner_total > lossy_inner_capacity(inner_nc, h.inner_method):
        raise ValueError(
            f"corrupted container: inner container declares "
            f"{h.inner_total} bytes, above the worst-case capacity "
            f"{lossy_inner_capacity(inner_nc, h.inner_method)}"
        )
    inner = blob[h.sec_lossy_inner : h.sec_lossy_inner + h.inner_total]
    ih, _, _ = validate_container(inner)
    if (
        ih.method != h.inner_method
        or ih.symbol_size != 2
        or ih.chunk_symbols != LOSSY_INNER_CHUNK_SYMBOLS
        or ih.n_chunks != inner_nc
    ):
        raise ValueError(
            f"corrupted container: inner container geometry (method="
            f"{ih.method}, S={ih.symbol_size}, C={ih.chunk_symbols}, "
            f"nc={ih.n_chunks}) does not match the outer header "
            f"(method={h.inner_method}, S=2, "
            f"C={LOSSY_INNER_CHUNK_SYMBOLS}, nc={inner_nc})"
        )
    pairs = blob[h.sec_outliers : h.sec_outliers + 8 * h.n_outliers]
    idx = pairs.reshape(-1, 8)[:, :4].copy().view(np.uint32).reshape(-1)
    if idx.size and int(idx.max()) >= n_elems:
        raise ValueError(
            f"corrupted container: outlier index {int(idx.max())} exceeds "
            f"the element capacity {n_elems}"
        )


def parse_tables_jax(blob_i32, n_chunks: int):
    """In-graph sections A/B parse (u32 little-endian).

    ``blob_i32`` is a container as a flat int32 byte buffer (traced);
    ``n_chunks`` must be static.  Used by consumers that decode containers
    inside jit (gradient exchange, batched decompression).
    """

    def sec(base):
        rows = blob_i32[base : base + 4 * n_chunks].reshape(n_chunks, 4)
        return (
            rows[:, 0] | (rows[:, 1] << 8) | (rows[:, 2] << 16)
            | (rows[:, 3] << 24)
        )

    return sec(HEADER_BYTES), sec(HEADER_BYTES + 4 * n_chunks)
