"""Canonical-Huffman entropy stage: the ``deflate-full`` container subsystem.

GPULZ deliberately stops at LZSS; Deflate-class *ratio* needs an entropy
stage over the emitted sections.  This module adds one: a byte-level
canonical Huffman code over each of the two compact container sections
(flags, payload), producing a method-1 container (core/format.py VERSION 2)
whose sections are replaced by

    codebooks (nibble-packed code lengths) + bit counts + gap arrays +
    MSB-first bitstreams

The *gap arrays* are the parallel-decode contribution of "Accelerating
Lossless Data Compression with GPUs" (PAPERS.md): one stored bit offset per
``SUB = 1 << format.DEFAULT_SUB_LOG2`` decoded bytes, so decoding is
embarrassingly parallel across sub-blocks (each lane scans exactly SUB
codewords from its stored entry point) while staying sequential — the
fundamental Huffman constraint — only *within* one.

Layering:

  * host tree building (``huffman_code_lengths`` — promoted here from
    benchmarks/huffman.py, which now consumes it) and an in-graph mirror
    (``huffman_code_lengths_jax``) that reproduces the heapq merge order
    *exactly* (ties broken by (count, id), internal ids above leaf ids), so
    host and traced code lengths are equal bit-for-bit.  ``code_lengths``
    is the single API over both: concrete inputs take the host path,
    tracers the in-graph one.
  * length limiting to ``MAX_CODE_LEN`` (deterministic Kraft repair:
    deepest non-max length first, smallest symbol on ties) plus the
    *stored escape* — if the limited code would expand the section past
    8 bits/byte, every symbol is forced to the 8-bit identity code, which
    bounds the bitstream at the raw section size and makes
    ``format.entropy_max_compressed_bytes`` a hard worst case.
  * ``byte_histogram`` — Pallas reduction on TPU (kernels/lz_entropy.py),
    XLA scatter-add fallback elsewhere, ``REPRO_ENTROPY_PALLAS`` forces.
  * ``encode_section`` / ``decode_section`` — fixed-shape, fully in-graph
    (vmap/shard_map safe; no host callbacks anywhere in the compress or
    decode path).  Decode dispatches to the Pallas gap-array kernel on TPU
    and a ``lax.scan`` sub-block decoder elsewhere.
  * ``compress_entropy`` / ``decode_blob_entropy`` — the ``deflate-full``
    backend/decoder hooks registered in core/pipeline.py: LZSS via the
    platform backend, entropy-code the sections, and on decode rebuild the
    per-chunk aligned sections and hand off to the existing in-VMEM LZSS
    decode chain.

Size limit: bit offsets are int32 in-graph (x64 disabled), so one
dispatch's sections must stay under 2**28 bytes (~256 MiB) — the same
slab-split regime as ``format._le_bytes``.
"""

from __future__ import annotations

import heapq
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import format as fmt

MAX_CODE_LEN = 15  # nibble-packed codebook: one hex digit per symbol
STORED_LEN = 8  # escape code length: identity byte code, no expansion
N_SYMBOLS = 256
_TREE = 2 * N_SYMBOLS - 1  # leaves + at most N-1 merge nodes
# np scalar, NOT jnp: a module-level jnp value created while some caller's
# jit trace triggers the first import of this module would leak a tracer
_INF = np.int32(2**31 - 1)


def _use_pallas(impl) -> bool:
    """Impl selection for the histogram / gap-decode stages.

    ``impl`` is ``"pallas"`` / ``"xla"`` (explicit) or ``None`` (platform
    default: Pallas on TPU, XLA elsewhere — the same convention as the LZSS
    kernels; ``REPRO_ENTROPY_PALLAS=1/0`` overrides the default, e.g. to
    exercise the kernels in interpret mode off-TPU).
    """
    if impl in ("pallas", "xla"):
        return impl == "pallas"
    if impl is not None:
        raise ValueError(f"impl must be 'pallas', 'xla' or None: {impl!r}")
    env = os.environ.get("REPRO_ENTROPY_PALLAS")
    if env is not None:
        return env != "0"
    return jax.default_backend() == "tpu"


# ----------------------------------------------------- host tree building


def huffman_code_lengths(counts: np.ndarray, max_len: int | None = None):
    """Code length per symbol (0 for absent symbols), host heapq build.

    Promoted from benchmarks/huffman.py (which now imports it): the
    Table-3 size estimator and the container entropy stage must agree on
    one definition.  ``max_len`` applies ``limit_code_lengths`` on top.
    """
    counts = np.asarray(counts)
    heap = [(int(c), i) for i, c in enumerate(counts) if c > 0]
    if len(heap) == 1:
        lengths = np.zeros(counts.size, np.int64)
        lengths[heap[0][1]] = 1
        return lengths
    heapq.heapify(heap)
    # internal nodes: (count, id); track merges to recover depths
    parent = {}
    next_id = counts.size
    while len(heap) > 1:
        c1, n1 = heapq.heappop(heap)
        c2, n2 = heapq.heappop(heap)
        parent[n1] = next_id
        parent[n2] = next_id
        heapq.heappush(heap, (c1 + c2, next_id))
        next_id += 1
    lengths = np.zeros(counts.size, np.int64)
    for sym in range(counts.size):
        if counts[sym] == 0:
            continue
        d, node = 0, sym
        while node in parent:
            node = parent[node]
            d += 1
        lengths[sym] = d
    if max_len is not None:
        lengths = limit_code_lengths(lengths, max_len)
    return lengths


def limit_code_lengths(lengths: np.ndarray, max_len: int) -> np.ndarray:
    """Clamp code lengths to ``max_len`` and repair the Kraft sum.

    Clamping over-deep leaves oversubscribes the code space; the repair
    deterministically deepens the symbol with the largest length below
    ``max_len`` (smallest symbol id on ties) until Kraft holds again.  The
    result is a valid (not necessarily optimal) prefix code; exactness is
    what the roundtrip needs, optimality is a few permille at L=15.
    """
    l = np.where(lengths > 0, np.minimum(lengths, max_len), 0).astype(np.int64)
    excess = int(np.where(l > 0, 1 << (max_len - l), 0).sum()) - (1 << max_len)
    while excess > 0:
        cand = np.nonzero((l > 0) & (l < max_len))[0]
        deepest = cand[l[cand] == l[cand].max()][0]
        excess -= 1 << (max_len - int(l[deepest]) - 1)
        l[deepest] += 1
    return l


def container_code_lengths(counts: np.ndarray) -> np.ndarray:
    """The code the container writer uses: limited Huffman + stored escape.

    If the limited code would expand the section (more than 8 bits/byte on
    average), every symbol is forced to the 8-bit identity code — the
    canonical code over all-equal lengths is the identity byte mapping, so
    the bitstream is bounded by the raw section size.  This is what makes
    the worst-case container bound in ``format.entropy_max_compressed_bytes``
    unconditional.
    """
    counts = np.asarray(counts, np.int64)
    l = huffman_code_lengths(counts, max_len=MAX_CODE_LEN)
    # bits > 8*n  <=>  sum(counts * (l - 8)) > 0: the delta form also keeps
    # the in-graph int32 mirror overflow-free (|delta| <= 7 per byte)
    if int((counts * (l - STORED_LEN)).sum()) > 0:
        l = np.full(counts.size, STORED_LEN, np.int64)
    return l


# -------------------------------------------------- in-graph tree building


@jax.jit
def huffman_code_lengths_jax(counts):
    """In-graph mirror of ``huffman_code_lengths`` (no ``max_len``).

    255 masked merge steps over a 511-node arena; each step extracts the
    two lexicographically smallest ``(count, id)`` active nodes — argmin
    over a dense key returns the *first* minimum, which is exactly heapq's
    tie order since internal ids (256+) sort after every leaf id.  Depths
    are recovered by parent-pointer doubling.  Equal to the host build
    bit-for-bit (tests/test_entropy.py pins it on adversarial histograms).
    """
    counts = jnp.asarray(counts, jnp.int32)
    n = counts.shape[0]
    t = 2 * n - 1
    cnt = jnp.zeros(t, jnp.int32).at[:n].set(counts)
    act = jnp.concatenate([counts > 0, jnp.zeros(n - 1, bool)])
    parent = jnp.full(t, -1, jnp.int32)
    n_live = jnp.sum((counts > 0).astype(jnp.int32))

    def merge(k, st):
        cnt, act, parent, na = st
        key = jnp.where(act, cnt, _INF)
        i1 = jnp.argmin(key)
        i2 = jnp.argmin(key.at[i1].set(_INF))
        do = na >= 2
        new = n + k
        cnt = cnt.at[new].set(jnp.where(do, cnt[i1] + cnt[i2], cnt[new]))
        act = act.at[i1].set(act[i1] & ~do)
        act = act.at[i2].set(act[i2] & ~do)
        act = act.at[new].set(act[new] | do)
        parent = parent.at[i1].set(jnp.where(do, new, parent[i1]))
        parent = parent.at[i2].set(jnp.where(do, new, parent[i2]))
        return cnt, act, parent, jnp.where(do, na - 1, na)

    _, _, parent, _ = lax.fori_loop(0, n - 1, merge, (cnt, act, parent, n_live))

    # depth = hops to the root: pointer doubling, 2^9 >= max chain length
    jump, dist = parent, (parent >= 0).astype(jnp.int32)
    for _ in range(9):
        src = jnp.clip(jump, 0, t - 1)
        live = jump >= 0
        dist = dist + jnp.where(live, jnp.take(dist, src), 0)
        jump = jnp.where(live, jnp.take(jump, src), -1)
    lengths = jnp.where(counts > 0, dist[:n], 0)
    # a lone symbol has depth 0 but needs a 1-bit code (host convention)
    return jnp.where((n_live == 1) & (counts > 0), 1, lengths)


def limit_code_lengths_jax(lengths, max_len: int = MAX_CODE_LEN):
    """In-graph mirror of ``limit_code_lengths`` (same repair order)."""
    l = jnp.where(lengths > 0, jnp.minimum(lengths, max_len), 0).astype(jnp.int32)
    excess = jnp.sum(jnp.where(l > 0, 1 << (max_len - l), 0)) - (1 << max_len)

    def repair(st):
        ex, l = st
        key = jnp.where((l > 0) & (l < max_len), l, -1)
        i = jnp.argmax(key)  # deepest non-max length, smallest symbol on ties
        ex = ex - (1 << (max_len - l[i] - 1))
        return ex, l.at[i].set(l[i] + 1)

    _, l = lax.while_loop(lambda st: st[0] > 0, repair, (excess, l))
    return l


def container_code_lengths_jax(counts):
    """In-graph mirror of ``container_code_lengths`` (limit + escape)."""
    counts = jnp.asarray(counts, jnp.int32)
    l = limit_code_lengths_jax(huffman_code_lengths_jax(counts))
    over = jnp.sum(counts * (l - STORED_LEN)) > 0
    return jnp.where(over, jnp.full_like(l, STORED_LEN), l)


def code_lengths(counts, max_len: int = MAX_CODE_LEN):
    """Container code lengths behind one API, host or traced.

    Concrete histograms (numpy arrays, python lists, materialized jnp
    arrays) run the host heapq builder; tracers run the in-graph mirror —
    the two are equal bit-for-bit, so callers never branch.  This is the
    "host tree-building fallback behind the same API" seam: the in-graph
    path is what the fused compress hook uses, the host path is free of
    the 255-step fori_loop for eager callers (benchmarks, tools).
    """
    if isinstance(counts, jax.core.Tracer):
        lengths = limit_code_lengths_jax(huffman_code_lengths_jax(counts), max_len)
        over = jnp.sum(jnp.asarray(counts, jnp.int32) * (lengths - STORED_LEN)) > 0
        return jnp.where(over, jnp.full_like(lengths, STORED_LEN), lengths)
    return container_code_lengths(np.asarray(counts))


# ----------------------------------------------------- canonical code maps


def canonical_tables_jax(lengths):
    """Canonical (MSB-first) code tables from a length assignment.

    Returns a dict:
      ``lengths`` (n,)  the input, int32
      ``codes``   (n,)  codeword per symbol (0 for absent symbols)
      ``first``   (L+1,) first codeword of each length
      ``count``   (L+1,) symbols per length
      ``base``    (L+1,) symbols with a shorter (positive) length
      ``order``   (n,)  symbols sorted by (length, symbol) — the decode map

    Decode-side validity of a window ``cand = win >> (L - l)`` is
    ``first[l] <= cand < first[l] + count[l]``; the canonical construction
    guarantees at most one length matches (shorter-length prefixes of
    longer codes always land at or past ``first[l] + count[l]``).

    Deliberately sort-free: ``rank``/``order`` come from a counting
    construction over the (length, symbol) grid, not ``jnp.argsort`` —
    XLA's sort miscompiles inside a jitted ``shard_map(check_rep=False)``
    region on CPU host meshes (wrong decode on every shard but the first),
    and for a 256-symbol alphabet the O(L*n) counting form is cheap anyway.
    """
    l = jnp.asarray(lengths, jnp.int32)
    n = l.shape[0]
    sym = jnp.arange(n, dtype=jnp.int32)
    ls = jnp.arange(MAX_CODE_LEN + 1, dtype=jnp.int32)
    live = l > 0
    onehot = (l[None, :] == ls[:, None]) & live[None, :]  # (L+1, n)
    count = jnp.sum(onehot, axis=1).astype(jnp.int32)
    base = jnp.cumsum(count) - count
    lc = jnp.clip(l, 0, MAX_CODE_LEN)
    # stable (length, symbol) rank: bucket base + position within the bucket
    within = jnp.cumsum(onehot.astype(jnp.int32), axis=1) - onehot
    rank_live = jnp.take(base, lc) + jnp.take_along_axis(
        within, lc[None, :], axis=0
    )[0]
    n_live = jnp.sum(live.astype(jnp.int32))
    rank_dead = n_live + jnp.cumsum((~live).astype(jnp.int32)) - 1
    rank = jnp.where(live, rank_live, rank_dead).astype(jnp.int32)
    order = jnp.zeros(n, jnp.int32).at[rank].set(sym)
    firsts = [jnp.zeros((), jnp.int32)]  # index 0: unused placeholder
    f = jnp.zeros((), jnp.int32)
    for ll in range(1, MAX_CODE_LEN + 1):
        if ll > 1:
            f = (f + count[ll - 1]) << 1
        firsts.append(f)
    first = jnp.stack(firsts)
    codes = jnp.where(
        l > 0, jnp.take(first, lc) + rank - jnp.take(base, lc), 0
    )
    return dict(
        lengths=l,
        codes=codes,
        first=first,
        count=count,
        base=base,
        order=order.astype(jnp.int32),
    )


def canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Host mirror of the encode map (tests / eager tools)."""
    l = np.asarray(lengths, np.int64)
    order = sorted(range(l.size), key=lambda s: (l[s] if l[s] > 0 else 99, s))
    codes = np.zeros(l.size, np.int64)
    code, prev = 0, 0
    for s in order:
        if l[s] == 0:
            break
        code <<= int(l[s]) - prev
        codes[s] = code
        code += 1
        prev = int(l[s])
    return codes


# --------------------------------------------------------------- histogram


def byte_histogram(buf, start, length, *, impl=None):
    """(256,) int32 counts of ``buf[start : start + length]`` byte values.

    ``buf`` is a flat int32 byte buffer (values 0..255); ``start`` /
    ``length`` may be traced.  Pallas reduction on TPU (or when forced),
    XLA scatter-add fallback elsewhere — identical counts by test.
    """
    b32 = jnp.asarray(buf, jnp.int32)
    if _use_pallas(impl):
        from repro.kernels import ops  # lazy: kernels are optional at import

        return ops.byte_histogram(b32, start, length)
    idx = jnp.arange(b32.shape[0], dtype=jnp.int32)
    in_range = (idx >= start) & (idx < start + length)
    slot = jnp.where(in_range, b32 & 0xFF, N_SYMBOLS)
    return jnp.zeros(N_SYMBOLS + 1, jnp.int32).at[slot].add(1)[:N_SYMBOLS]


# ------------------------------------------------------- section transcode


def encode_section(buf, start, length, lengths, *, cap: int, sub: int | None = None):
    """Bit-pack one section with a canonical code; fixed shapes, in-graph.

    ``buf`` is a flat int32 byte buffer holding the section at dynamic
    ``[start, start + length)``; ``cap`` is the static section capacity.
    Returns ``(stream, nbits, gaps)``: a ``(cap + 8,)`` int32 byte buffer
    whose first ``ceil(nbits / 8)`` entries are live (the stored escape in
    ``container_code_lengths`` guarantees ``nbits <= 8 * length``), the
    total bit count, and the ``(ceil(cap / sub),)`` gap array — the bit
    offset of every ``sub``-th byte's codeword, the decoder's parallel
    entry points.

    The pack is three masked scatter-adds: each codeword (<= 15 bits at a
    bit phase <= 7) lands inside a 24-bit window, i.e. three consecutive
    stream bytes; contributions of adjacent codewords touch disjoint bits,
    so byte-wise addition never carries.
    """
    sub = (1 << fmt.DEFAULT_SUB_LOG2) if sub is None else sub
    tabs = canonical_tables_jax(lengths)
    b32 = jnp.asarray(buf, jnp.int32)
    idx = jnp.arange(cap, dtype=jnp.int32)
    valid = idx < length
    byte = jnp.take(b32, jnp.clip(start + idx, 0, b32.shape[0] - 1)) & 0xFF
    l = jnp.where(valid, jnp.take(tabs["lengths"], byte), 0)
    code = jnp.where(valid, jnp.take(tabs["codes"], byte), 0)
    csum = jnp.cumsum(l)
    off = csum - l
    nbits = csum[-1]
    w = code << (24 - l - (off & 7))
    base = off >> 3
    stream = jnp.zeros(cap + 8, jnp.int32)
    for k in range(3):
        stream = stream.at[base + k].add((w >> (8 * (2 - k))) & 0xFF)
    gaps = jnp.take(off, jnp.arange(-(-cap // sub), dtype=jnp.int32) * sub)
    return stream, nbits, gaps


def decode_section(
    blob, base_byte, gaps, lengths, *, count, cap: int, sub: int | None = None,
    impl=None,
):
    """Inverse of ``encode_section``: gap-array parallel bitstream decode.

    ``blob`` is the whole container as a flat int32 byte buffer,
    ``base_byte`` the (dynamic) byte offset of this section's bitstream,
    ``gaps`` the ``(ceil(cap / sub),)`` bit-offset entry points and
    ``count`` the live decoded byte count (static capacity ``cap``).
    Every sub-block decodes independently from its gap entry — the Pallas
    kernel (TPU) DMAs one fixed-width bitstream window per sub-block into
    VMEM; the XLA fallback is a ``lax.scan`` of ``sub`` codeword steps
    vectorized over all sub-blocks.  Returns ``(cap,)`` int32 bytes, zero
    beyond ``count``.
    """
    sub = (1 << fmt.DEFAULT_SUB_LOG2) if sub is None else sub
    tabs = canonical_tables_jax(lengths)
    nsub = gaps.shape[0]
    if _use_pallas(impl):
        from repro.kernels import ops  # lazy: kernels are optional at import

        wstarts = base_byte + (jnp.asarray(gaps, jnp.int32) >> 3)
        rems = jnp.asarray(gaps, jnp.int32) & 7
        syms = ops.huffman_gap_decode(
            blob, wstarts, rems,
            tabs["first"], tabs["count"], tabs["base"], tabs["order"],
            sub=sub,
        )
    else:
        syms = _decode_scan(blob, base_byte, gaps, tabs, sub=sub)
    flat = syms.reshape(nsub * sub)[:cap]
    return jnp.where(jnp.arange(cap, dtype=jnp.int32) < count, flat, 0)


def _decode_scan(blob, base_byte, gaps, tabs, *, sub: int):
    """XLA gap decoder: scan ``sub`` codeword steps over all sub-blocks."""
    b32 = jnp.asarray(blob, jnp.int32) & 0xFF
    top = b32.shape[0] - 1
    ls = jnp.arange(1, MAX_CODE_LEN + 1, dtype=jnp.int32)
    fc = jnp.take(tabs["first"], ls)
    cn = jnp.take(tabs["count"], ls)

    def step(off, _):
        pos = base_byte + (off >> 3)
        w24 = (
            (jnp.take(b32, jnp.clip(pos, 0, top)) << 16)
            | (jnp.take(b32, jnp.clip(pos + 1, 0, top)) << 8)
            | jnp.take(b32, jnp.clip(pos + 2, 0, top))
        )
        win = (w24 >> (9 - (off & 7))) & ((1 << MAX_CODE_LEN) - 1)
        cand = win[:, None] >> (MAX_CODE_LEN - ls)[None, :]
        ok = (cand >= fc[None, :]) & (cand - fc[None, :] < cn[None, :])
        sel = jnp.argmax(ok, axis=1)  # first (shortest) valid length - 1
        lsel = sel + 1
        csel = jnp.take_along_axis(cand, sel[:, None], axis=1)[:, 0]
        sidx = jnp.take(tabs["base"], lsel) + csel - jnp.take(tabs["first"], lsel)
        sym = jnp.take(tabs["order"], jnp.clip(sidx, 0, N_SYMBOLS - 1))
        return off + lsel, sym

    _, syms = lax.scan(step, jnp.asarray(gaps, jnp.int32), None, length=sub)
    return syms.T  # (nsub, sub)


# ------------------------------------------- container-level hooks (v2)


def compress_entropy(symbols, cfg, orig_bytes=None):
    """The ``deflate-full`` backend's ``compress`` hook.

    Runs the platform LZSS backend (``"auto"``: the single-kernel
    ``fused-mono`` on TPU) for the sections, histograms + entropy-codes
    both, and assembles a method-1 VERSION-2 container.  Fully in-graph —
    vmap (``compress_many``) and shard_map (the sharded runner) see plain
    jnp ops, never a callback.
    """
    from repro.core import pipeline  # lazy: pipeline registers this hook

    nc, c = symbols.shape
    s = cfg.symbol_size
    cb = (c + 7) // 8
    sub = 1 << fmt.DEFAULT_SUB_LOG2
    raw, _ = pipeline._compress_via(
        pipeline.get_backend("auto"), symbols, cfg, orig_bytes
    )
    b32 = raw.astype(jnp.int32)
    n_tokens, payload_sizes = fmt.parse_tables_jax(b32, nc)
    fsz = (n_tokens + 7) // 8
    f_tot = jnp.sum(fsz)
    p_tot = jnp.sum(payload_sizes)
    sec = fmt.HEADER_BYTES + 8 * nc
    flag_cap, pay_cap = nc * cb, nc * c * s

    lf = container_code_lengths_jax(byte_histogram(b32, sec, f_tot))
    lp = container_code_lengths_jax(byte_histogram(b32, sec + f_tot, p_tot))
    stream_f, fbits, gaps_f = encode_section(b32, sec, f_tot, lf, cap=flag_cap)
    stream_p, pbits, gaps_p = encode_section(
        b32, sec + f_tot, p_tot, lp, cap=pay_cap
    )

    cap2 = fmt.entropy_max_compressed_bytes(nc * c * s, s, c)
    out = jnp.zeros((cap2,), jnp.int32)
    out = fmt.write_header_and_tables(
        out,
        symbol_size=s,
        window=cfg.window,
        chunk_symbols=c,
        n_chunks=nc,
        orig_bytes=nc * c * s if orig_bytes is None else orig_bytes,
        payload_total=p_tot,
        flag_total=f_tot,
        n_tokens=n_tokens,
        payload_sizes=payload_sizes,
        method=fmt.METHOD_HUFFMAN,
        sub_log2=fmt.DEFAULT_SUB_LOG2,
    )
    # nibble-packed codebooks + bit counts at static offsets
    out = out.at[sec : sec + 128].set(lf[0::2] | (lf[1::2] << 4))
    out = out.at[sec + 128 : sec + 256].set(lp[0::2] | (lp[1::2] << 4))
    out = out.at[sec + 256 : sec + 264].set(jnp.stack(fmt._le_bytes(fbits, 8)))
    out = out.at[sec + 264 : sec + 272].set(jnp.stack(fmt._le_bytes(pbits, 8)))

    nsub_f = (f_tot + sub - 1) // sub
    nsub_p = (p_tot + sub - 1) // sub
    gbase_f = sec + fmt.ENTROPY_META_FIXED
    gbase_p = gbase_f + 4 * nsub_f

    def put_gaps(out, base, gaps, nsub):
        k = jnp.arange(gaps.shape[0], dtype=jnp.int32)
        live = k < nsub
        for j in range(4):
            pos = jnp.where(live, base + 4 * k + j, cap2)  # OOB writes drop
            out = out.at[pos].add(jnp.where(live, (gaps >> (8 * j)) & 0xFF, 0))
        return out

    out = put_gaps(out, gbase_f, gaps_f, nsub_f)
    out = put_gaps(out, gbase_p, gaps_p, nsub_p)

    fbytes = (fbits + 7) // 8
    pbytes = (pbits + 7) // 8
    sbase_f = gbase_p + 4 * nsub_p
    sbase_p = sbase_f + fbytes

    def put_stream(out, base, stream, nbytes):
        i = jnp.arange(stream.shape[0], dtype=jnp.int32)
        live = i < nbytes
        pos = jnp.where(live, base + i, cap2)  # OOB writes drop
        return out.at[pos].add(jnp.where(live, stream, 0))

    out = put_stream(out, sbase_f, stream_f, fbytes)
    out = put_stream(out, sbase_p, stream_p, pbytes)
    total = sbase_p + pbytes
    return out.astype(jnp.uint8), total


def decode_blob_entropy(
    blob,
    n_tokens,
    payload_sizes,
    *,
    symbol_size: int,
    chunk_symbols: int,
    n_chunks: int,
    chunks_per_block=None,
    impl=None,
):
    """The ``deflate-full`` decoder's ``decode_blob`` hook.

    Parses the method-1 metadata at static offsets, gap-decodes both
    bitstreams back to the compact sections, rebuilds the per-chunk
    aligned flag/payload arrays (``deflate.gather_section``) and hands off
    to the platform LZSS decode chain (``"auto"``: the in-VMEM fused
    decoder on TPU).  Fixed shapes throughout; vmap/shard_map safe.

    The gap sub-block size is pinned to ``format.DEFAULT_SUB_LOG2`` (the
    shapes here are static); ``validate_container`` rejects containers
    recorded with any other value before they reach this trace.
    """
    from repro.core import deflate, pipeline  # lazy: avoid import cycle

    c, s, nc = chunk_symbols, symbol_size, n_chunks
    cb = (c + 7) // 8
    sub = 1 << fmt.DEFAULT_SUB_LOG2
    b32 = jnp.asarray(blob, jnp.int32).reshape(-1) & 0xFF
    sec = fmt.HEADER_BYTES + 8 * nc
    flag_cap, pay_cap = nc * cb, nc * c * s

    fsz = ((jnp.asarray(n_tokens, jnp.int32) + 7) // 8).astype(jnp.int32)
    psz = jnp.asarray(payload_sizes, jnp.int32)
    f_tot = jnp.sum(fsz)
    p_tot = jnp.sum(psz)

    cbf = b32[sec : sec + 128]
    cbp = b32[sec + 128 : sec + 256]
    lf = jnp.stack([cbf & 0xF, (cbf >> 4) & 0xF], axis=1).reshape(-1)
    lp = jnp.stack([cbp & 0xF, (cbp >> 4) & 0xF], axis=1).reshape(-1)

    def u32(off):
        return (
            b32[off] | (b32[off + 1] << 8) | (b32[off + 2] << 16)
            | (b32[off + 3] << 24)
        )

    fbits = u32(sec + 256)  # 4 live bytes of the u64 field (<2 GiB sections)
    pbits = u32(sec + 264)

    def gather_gaps(base, nsub_cap):
        pos = base + 4 * jnp.arange(nsub_cap, dtype=jnp.int32)
        top = b32.shape[0] - 1

        def g(o):
            return jnp.take(b32, jnp.clip(pos + o, 0, top))

        return g(0) | (g(1) << 8) | (g(2) << 16) | (g(3) << 24)

    nsub_f = (f_tot + sub - 1) // sub
    nsub_p = (p_tot + sub - 1) // sub
    gbase_f = sec + fmt.ENTROPY_META_FIXED
    gbase_p = gbase_f + 4 * nsub_f
    gaps_f = gather_gaps(gbase_f, -(-flag_cap // sub))
    gaps_p = gather_gaps(gbase_p, -(-pay_cap // sub))
    sbase_f = gbase_p + 4 * nsub_p
    sbase_p = sbase_f + (fbits + 7) // 8

    flag_flat = decode_section(
        b32, sbase_f, gaps_f, lf, count=f_tot, cap=flag_cap, sub=sub, impl=impl
    )
    pay_flat = decode_section(
        b32, sbase_p, gaps_p, lp, count=p_tot, cap=pay_cap, sub=sub, impl=impl
    )

    flag_off = jnp.cumsum(fsz) - fsz
    pay_off = jnp.cumsum(psz) - psz
    flags = deflate.gather_section(flag_flat, 0, fsz, flag_off, cb)
    payload = deflate.gather_section(pay_flat, 0, psz, pay_off, c * s)

    dec = pipeline.get_decoder("auto")
    return dec.decode(
        flags,
        payload,
        jnp.asarray(n_tokens, jnp.int32),
        symbol_size=s,
        **pipeline._geometry_kw(dec.decode, chunks_per_block),
    )
