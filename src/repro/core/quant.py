"""Error-bounded Lorenzo quantization (cuSZ-style) — the paper's §4.5 use case.

GPULZ's flagship integration in the paper is compressing cuSZ's uint16
quantization codes.  We implement the cuSZ "dual-quant" scheme, which is fully
parallel (no sequential prediction chain):

    q[i]    = round(x[i] / (2 * eb))                (pre-quantization, int32)
    code[i] = q[i] - Lorenzo_pred(q, i) + CENTER    (integer Lorenzo delta)

Reconstruction integrates the deltas (cumsum along each predicted axis) and
multiplies back:  |x' - x| <= eb  for every element within int range.

Codes center at 32768 and saturate to uint16; saturated positions are stored
as fp32 outliers (paper: cuSZ outlier handling).  The uint16 code stream is
exactly the hurr/hacc/nyx-quant dataset family evaluated in the paper.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

CENTER = 1 << 15
CODE_MIN, CODE_MAX = 0, (1 << 16) - 1


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("codes", "outlier_mask", "outlier_vals"),
    meta_fields=("error_bound",),
)
@dataclasses.dataclass(frozen=True)
class QuantResult:
    codes: jnp.ndarray      # uint16, same shape as input
    outlier_mask: jnp.ndarray  # bool
    outlier_vals: jnp.ndarray  # fp32, 0 where not outlier
    error_bound: float


def _lorenzo_delta(q: jnp.ndarray, ndim: int) -> jnp.ndarray:
    """q - pred(q) where pred is the order-1 Lorenzo predictor over `ndim` axes."""
    delta = q
    # Lorenzo delta == composition of first differences along each axis.
    for ax in range(-ndim, 0):
        zero = jnp.take(delta, jnp.array([0]), axis=ax) * 0
        delta = jnp.diff(delta, axis=ax, prepend=zero)
    return delta


def _lorenzo_undelta(d: jnp.ndarray, ndim: int) -> jnp.ndarray:
    q = d
    for ax in range(-ndim, 0):
        q = jnp.cumsum(q, axis=ax)
    return q


@functools.partial(jax.jit, static_argnames=("error_bound", "ndim"))
def quantize(x: jnp.ndarray, *, error_bound: float, ndim: int = 1) -> QuantResult:
    if ndim < 1 or ndim > min(3, x.ndim):
        raise ValueError(f"ndim must be in [1, {min(3, x.ndim)}]")
    # saturate the pre-quantization to int30: degenerate bounds (e.g. a
    # constant field => range-relative eb ~ 0) then route through the exact
    # fp32 outlier path instead of overflowing int32
    qf = jnp.round(x.astype(jnp.float32) / (2.0 * error_bound))
    # NaN would cast to an unspecified int32 and poison the delta chain:
    # pin its pre-quant to 0 and force it through the exact outlier path
    # (the same q_ref=0 convention dequantize's chain repair uses).
    nan = jnp.isnan(qf)
    q = jnp.clip(jnp.where(nan, 0.0, qf), -(2.0 ** 30), 2.0 ** 30).astype(
        jnp.int32
    )
    delta = _lorenzo_delta(q, ndim) + CENTER
    saturated_pre = (jnp.abs(qf) >= 2.0 ** 30) | nan
    saturated = (delta < CODE_MIN) | (delta > CODE_MAX) | saturated_pre
    codes = jnp.where(saturated, CENTER, delta).astype(jnp.uint16)
    return QuantResult(
        codes=codes,
        outlier_mask=saturated,
        outlier_vals=jnp.where(saturated, x, 0.0).astype(jnp.float32),
        error_bound=error_bound,
    )


def _encoder_prequant(x: jnp.ndarray, error_bound: float) -> jnp.ndarray:
    """The exact pre-quant integer quantize() computed for value x."""
    qf = jnp.round(x.astype(jnp.float32) / (2.0 * error_bound))
    qf = jnp.where(jnp.isnan(qf), 0.0, qf)
    return jnp.clip(qf, -(2.0 ** 30), 2.0 ** 30).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("error_bound", "ndim"))
def dequantize(codes, outlier_mask, outlier_vals, *, error_bound, ndim=1):
    delta = codes.astype(jnp.int32) - CENTER
    q = _lorenzo_undelta(delta, ndim)
    if ndim == 1:
        # Chain repair: an outlier stores code CENTER (delta 0) while the
        # encoder's delta chain downstream was computed against the true
        # (clipped) pre-quant, so the raw cumsum is shifted by a constant
        # for every element after an outlier.  The outlier value itself
        # pins the encoder's pre-quant exactly (q_ref below reproduces it
        # bit-for-bit, including the NaN->0 and inf->2^30 conventions), so
        # adding q_ref - q_raw from the *last* outlier at or before each
        # position restores the exact chain.  int32 wraparound in the
        # intermediate difference is harmless: it cancels on the add, and
        # the true pre-quant magnitude is <= 2^30.
        q_ref = _encoder_prequant(outlier_vals, error_bound)
        n = codes.shape[-1]
        idx = jnp.broadcast_to(
            jnp.arange(n, dtype=jnp.int32), codes.shape
        )
        last = jax.lax.cummax(
            jnp.where(outlier_mask, idx, -1), axis=codes.ndim - 1
        )
        adj = jnp.where(outlier_mask, q_ref - q, 0)
        carry = jnp.take_along_axis(adj, jnp.maximum(last, 0), axis=-1)
        q = q + jnp.where(last >= 0, carry, 0)
    # ndim > 1: the multi-axis Lorenzo chain has no 1D segment structure to
    # repair; outliers there still reconstruct exactly (overlay below) but
    # non-outliers downstream of one keep the historical shifted-cumsum
    # behavior.  The registered lossy backend always quantizes ndim=1.
    x = q.astype(jnp.float32) * (2.0 * error_bound)
    return jnp.where(outlier_mask, outlier_vals, x)


def relative_error_bound(x, rel_eb: float) -> float:
    """Paper uses value-range-relative bounds (e.g. 1e-2, 1e-3)."""
    x = np.asarray(x)
    rng = float(x.max() - x.min()) if x.size else 1.0
    return max(rel_eb * rng, np.finfo(np.float32).tiny)
