"""Lightweight parameter selection (paper §3.2.3).

The paper's rule, verbatim:
  * default: S = sizeof(dtype), W = 128, C = 2048;
  * monitor the average compression ratio over the fields seen so far; if it
    is low (< 1.5) switch back to single-byte matching (multi-byte matching
    hides byte-level repeats on low-redundancy data, cf. tpch-int32);
  * when multi-byte matching is kept, the window may be enlarged (the S-fold
    throughput win pays for the larger W);
  * user-facing window levels 1-4 = 32/64/128/255 trade ratio for throughput.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.lzss import LZSSConfig, WINDOW_LEVELS, compress

RATIO_THRESHOLD = 1.5


def dtype_symbol_size(dtype) -> int:
    size = np.dtype(dtype).itemsize
    return size if size in (1, 2, 4) else 4


@dataclasses.dataclass
class ParamSelector:
    """Streaming selector: feed fields, get the adapted config."""

    dtype: np.dtype
    level: int = 3                  # window level 1-4
    chunk_symbols: int = 2048
    enlarge_window: bool = True
    _ratios: list = dataclasses.field(default_factory=list)

    def current_config(self) -> LZSSConfig:
        s = dtype_symbol_size(self.dtype)
        if self._ratios and float(np.mean(self._ratios)) < RATIO_THRESHOLD:
            s = 1  # paper: fall back to byte matching on low-redundancy data
        w = WINDOW_LEVELS[self.level]
        if s > 1 and self.enlarge_window:
            w = min(255, w * 2) if self.level < 4 else 255
        return LZSSConfig(symbol_size=s, window=w, chunk_symbols=self.chunk_symbols)

    def observe(self, field: np.ndarray) -> LZSSConfig:
        """Compress one field with the current config; update the running stats."""
        cfg = self.current_config()
        res = compress(field, cfg)
        self._ratios.append(res.ratio)
        return cfg

    @property
    def mean_ratio(self) -> float:
        return float(np.mean(self._ratios)) if self._ratios else 0.0


def select_params(sample: np.ndarray, level: int = 3) -> LZSSConfig:
    """One-shot variant: probe multi-byte vs single-byte on a sample."""
    sel = ParamSelector(dtype=np.asarray(sample).dtype, level=level)
    sel.observe(np.asarray(sample))
    return sel.current_config()
