# The paper's primary contribution: GPULZ — multi-byte LZSS compression
# restructured for accelerator execution (matching / local prefix sum /
# encoding fused; global prefix sum; deflate), plus the cuSZ-style
# error-bounded quantizer it pairs with in the paper's use case.
from repro.core.lzss import (
    DEFAULT_CONFIG,
    LZSSConfig,
    WINDOW_LEVELS,
    BatchedCompressResult,
    CompressorBackend,
    CompressResult,
    available_backends,
    compress,
    compress_chunks,
    compress_many,
    compress_many_chunks,
    compression_ratio,
    decompress,
    decompress_chunks,
    decompress_many,
    decompress_many_chunks,
    default_backend,
    get_backend,
    pack_symbols,
    register_backend,
    unpack_symbols,
)
from repro.core.match import find_matches
from repro.core.params import ParamSelector, select_params
from repro.core.quant import dequantize, quantize, relative_error_bound

__all__ = [
    "DEFAULT_CONFIG",
    "LZSSConfig",
    "WINDOW_LEVELS",
    "BatchedCompressResult",
    "CompressorBackend",
    "CompressResult",
    "available_backends",
    "compress",
    "compress_chunks",
    "compress_many",
    "compress_many_chunks",
    "compression_ratio",
    "decompress",
    "decompress_chunks",
    "decompress_many",
    "decompress_many_chunks",
    "default_backend",
    "get_backend",
    "register_backend",
    "pack_symbols",
    "unpack_symbols",
    "find_matches",
    "ParamSelector",
    "select_params",
    "quantize",
    "dequantize",
    "relative_error_bound",
]
