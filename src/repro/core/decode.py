"""LZSS decompression (pure-XLA decoders).

These are the XLA entries of the decoder registry in core/pipeline.py
(``xla-parallel`` / ``xla-scan``); the fused Pallas decoder lives in
kernels/lz_decode.py.  Two decoders over per-chunk aligned sections:

  * ``decode_scan``     — sequential token walk per chunk (lax.scan, vmapped
    over chunks).  This is the paper's decompression parallelization (chunk
    level only); kept as the oracle.
  * ``decode_parallel`` — beyond-paper fully parallel decoder.  Because match
    length <= offset (match.py), a copied symbol's source lies strictly before
    the copy's own token, so back-references form a forest rooted at literals.
    Token read/write offsets come from two prefix sums (over [2|S] byte sizes
    and over output lengths), and chained copies resolve with ceil(log2 C)
    rounds of pointer doubling.  No sequential dependency remains.

Inputs are the (nc, C//8) flag bytes, (nc, C*S) payload bytes and (nc,) token
counts produced by deflate.gather_section; output is (nc, C) int32 symbols.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.match import MAX_LEN_CAP


def _bit(flag_bytes, t):
    """t-th flag bit per chunk; t: (nc, K) or scalar-per-chunk indices."""
    byte = jnp.take_along_axis(flag_bytes, t // 8, axis=1)
    return (byte >> (t % 8)) & 1


@functools.partial(jax.jit, static_argnames=("symbol_size",))
def decode_parallel(flag_bytes, payload, n_tokens, *, symbol_size):
    nc, cb = flag_bytes.shape
    c = cb * 8
    s = symbol_size
    rows = jnp.arange(nc)[:, None]
    t = jnp.arange(c, dtype=jnp.int32)[None, :]
    active = t < n_tokens[:, None]

    flags = _bit(flag_bytes, jnp.broadcast_to(t, (nc, c))) * active
    read_size = jnp.where(active, jnp.where(flags == 1, 2, s), 0)
    rcsum = jnp.cumsum(read_size, axis=1)
    read_off = rcsum - read_size

    def pay_at(k):
        return jnp.take_along_axis(
            payload, jnp.clip(read_off + k, 0, payload.shape[1] - 1), axis=1
        )

    ln = jnp.where(flags == 1, pay_at(0), 1) * active
    off = jnp.where(flags == 1, pay_at(1), 0) * active
    lit = jnp.zeros((nc, c), jnp.int32)
    for b in range(s):
        lit = lit + (pay_at(b) << (8 * b))
    lit = jnp.where(flags == 0, lit, 0)

    wcsum = jnp.cumsum(ln, axis=1)
    out_pos = wcsum - ln  # token write start (symbols)

    # Per-output-symbol token id: scatter token starts, then prefix-sum fill.
    starts = (
        jnp.zeros((nc, c), jnp.int32)
        .at[rows, jnp.where(active & (ln > 0), out_pos, c)]
        .add(1, mode="drop")
    )
    token_id = jnp.clip(jnp.cumsum(starts, axis=1) - 1, 0, c - 1)

    w = jnp.arange(c, dtype=jnp.int32)[None, :]
    flag_w = jnp.take_along_axis(flags, token_id, axis=1)
    off_w = jnp.take_along_axis(off, token_id, axis=1)
    lit_w = jnp.take_along_axis(lit, token_id, axis=1)
    src = jnp.where(flag_w == 1, jnp.clip(w - off_w, 0, c - 1), w)

    for _ in range(max(1, math.ceil(math.log2(c)))):
        src = jnp.take_along_axis(src, src, axis=1)

    return jnp.take_along_axis(lit_w, src, axis=1)


@functools.partial(jax.jit, static_argnames=("symbol_size", "max_len"))
def decode_scan(flag_bytes, payload, n_tokens, *, symbol_size,
                max_len=MAX_LEN_CAP):
    """Oracle decoder: sequential token walk (scan over token slots)."""
    nc, cb = flag_bytes.shape
    c = cb * 8
    s = symbol_size
    rows = jnp.arange(nc)[:, None]
    k = jnp.arange(max_len, dtype=jnp.int32)[None, :]

    def pay_at(idx):
        return jnp.take_along_axis(payload, jnp.clip(idx, 0, payload.shape[1] - 1), axis=1)

    def body(carry, t):
        rp, wp, out = carry
        active = t < n_tokens
        byte = lax.dynamic_slice_in_dim(flag_bytes, t // 8, 1, axis=1)[:, 0]
        flag = (byte >> (t % 8)) & 1
        is_m = (flag == 1) & active
        is_l = (flag == 0) & active
        ln = pay_at(rp[:, None])[:, 0]
        off = pay_at(rp[:, None] + 1)[:, 0]
        sym = jnp.zeros((nc,), jnp.int32)
        for b in range(s):
            sym = sym + (pay_at(rp[:, None] + b)[:, 0] << (8 * b))
        # match copy (len <= off => source fully decoded, no overlap)
        src_idx = jnp.clip(wp[:, None] - off[:, None] + k, 0, c - 1)
        vals = jnp.take_along_axis(out, src_idx, axis=1)
        mask = (k < ln[:, None]) & is_m[:, None]
        dst = jnp.where(mask, wp[:, None] + k, c)
        out = out.at[rows, dst].add(jnp.where(mask, vals, 0), mode="drop")
        # literal write
        dst_l = jnp.where(is_l, wp, c)
        out = out.at[jnp.arange(nc), dst_l].add(
            jnp.where(is_l, sym, 0), mode="drop"
        )
        rp = rp + jnp.where(active, jnp.where(is_m, 2, s), 0)
        wp = wp + jnp.where(active, jnp.where(is_m, ln, 1), 0)
        return (rp, wp, out), None

    init = (
        jnp.zeros((nc,), jnp.int32),
        jnp.zeros((nc,), jnp.int32),
        jnp.zeros((nc, c), jnp.int32),
    )
    (_, _, out), _ = lax.scan(body, init, jnp.arange(c, dtype=jnp.int32))
    return out
