"""GPULZ top-level API: the paper's five-step pipeline on TPU/XLA.

    matching -> local prefix sum -> encoding -> global prefix sum -> deflating
    `------------- Kernel I -------------'    `-- Kernel II --'   `Kernel III'

``compress_chunks`` is the fully jittable core (fixed shapes, usable in-graph
for gradient/KV compression); ``compress``/``decompress`` are host-facing
wrappers handling padding, headers and dynamic sizes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decode as decode_mod
from repro.core import deflate, encode, format as fmt, match


@dataclasses.dataclass(frozen=True)
class LZSSConfig:
    """Paper parameters: S (symbol bytes), W (window), C (chunk symbols)."""

    symbol_size: int = 2          # S in {1, 2, 4}
    window: int = 128             # W in [1, 255]; levels 1-4 = 32/64/128/255
    chunk_symbols: int = 2048     # C; VMEM-resident chunk
    selector: Literal["scan", "doubling"] = "doubling"
    matcher: Literal["xla", "pallas"] = "xla"
    decoder: Literal["parallel", "scan"] = "parallel"

    def __post_init__(self):
        if self.symbol_size not in (1, 2, 4):
            raise ValueError(f"symbol_size must be 1, 2 or 4: {self.symbol_size}")
        if not 1 <= self.window <= 255:
            raise ValueError(f"window must be in [1, 255]: {self.window}")
        if self.chunk_symbols % 8:
            raise ValueError("chunk_symbols must be a multiple of 8")

    @property
    def min_match(self) -> int:
        return encode.min_match_length(self.symbol_size)


DEFAULT_CONFIG = LZSSConfig()  # paper default: C=2048, S=2, W=128

# window "levels" exposed to users (paper §3.2.3: level 1-4 trade ratio/speed)
WINDOW_LEVELS = {1: 32, 2: 64, 3: 128, 4: 255}


def pack_symbols(data: jnp.ndarray, symbol_size: int) -> jnp.ndarray:
    """(n_bytes,) uint8 -> (n_sym,) int32 little-endian symbols (n_bytes % S == 0)."""
    d = data.reshape(-1, symbol_size).astype(jnp.int32)
    sym = d[:, 0]
    for b in range(1, symbol_size):
        sym = sym | (d[:, b] << (8 * b))
    return sym


def unpack_symbols(symbols: jnp.ndarray, symbol_size: int) -> jnp.ndarray:
    """(n_sym,) int32 -> (n_sym * S,) uint8 little-endian."""
    cols = [((symbols >> (8 * b)) & 0xFF) for b in range(symbol_size)]
    return jnp.stack(cols, axis=-1).reshape(-1).astype(jnp.uint8)


def _find_matches(symbols, cfg: LZSSConfig):
    if cfg.matcher == "pallas":
        from repro.kernels import ops  # lazy: kernels are optional at import

        return ops.lz_match(symbols, window=cfg.window)
    return match.find_matches(symbols, window=cfg.window)


def _select(lengths, cfg: LZSSConfig):
    fn = (
        encode.select_tokens_doubling
        if cfg.selector == "doubling"
        else encode.select_tokens_scan
    )
    return fn(lengths, min_match=cfg.min_match)


@functools.partial(jax.jit, static_argnames=("cfg",))
def compress_chunks(symbols: jnp.ndarray, cfg: LZSSConfig):
    """Jittable core: (nc, C) int32 symbols -> (buffer u8[cap], total_bytes).

    The buffer holds a complete container (header + tables + flags + payload);
    bytes past ``total_bytes`` are zero.
    """
    nc, c = symbols.shape
    s = cfg.symbol_size
    lengths, offsets = _find_matches(symbols, cfg)
    emitted = _select(lengths, cfg)
    fields = encode.token_fields(
        lengths, emitted, min_match=cfg.min_match, symbol_size=s
    )
    flag_bytes, flag_sizes = deflate.pack_flags(emitted, fields["use_match"])
    payload = deflate.build_chunk_payloads(
        symbols, lengths, offsets, fields, symbol_size=s
    )
    pay_off, pay_total, flag_off, flag_total = deflate.global_offsets(
        fields["payload_sizes"], flag_sizes
    )
    cap = fmt.max_compressed_bytes(nc * c * s, s, c)
    out = jnp.zeros((cap,), jnp.int32)
    out = fmt.write_header_and_tables(
        out,
        symbol_size=s,
        window=cfg.window,
        chunk_symbols=c,
        n_chunks=nc,
        orig_bytes=nc * c * s,
        payload_total=pay_total,
        flag_total=flag_total,
        n_tokens=fields["n_tokens"],
        payload_sizes=fields["payload_sizes"],
    )
    sec_flags = fmt.HEADER_BYTES + 8 * nc
    out = deflate.scatter_section(out, sec_flags, flag_bytes, flag_sizes, flag_off)
    out = deflate.scatter_section(
        out, sec_flags + flag_total, payload, fields["payload_sizes"], pay_off
    )
    total = sec_flags + flag_total + pay_total
    return out.astype(jnp.uint8), total


@functools.partial(
    jax.jit, static_argnames=("symbol_size", "chunk_symbols", "n_chunks", "decoder")
)
def decompress_chunks(
    blob, n_tokens, payload_sizes, *, symbol_size, chunk_symbols, n_chunks, decoder
):
    """Jittable core: container bytes -> (nc, C) int32 symbols."""
    c, s, nc = chunk_symbols, symbol_size, n_chunks
    blob = blob.astype(jnp.int32)
    flag_sizes = (n_tokens + 7) // 8
    fcsum = jnp.cumsum(flag_sizes)
    pcsum = jnp.cumsum(payload_sizes)
    flag_off = fcsum - flag_sizes
    pay_off = pcsum - payload_sizes
    sec_flags = fmt.HEADER_BYTES + 8 * nc
    flag_bytes = deflate.gather_section(
        blob, sec_flags, flag_sizes, flag_off, (c + 7) // 8
    )
    payload = deflate.gather_section(
        blob, sec_flags + fcsum[-1], payload_sizes, pay_off, c * s
    )
    fn = (
        decode_mod.decode_parallel
        if decoder == "parallel"
        else decode_mod.decode_scan
    )
    return fn(flag_bytes, payload, n_tokens, symbol_size=s)


# ---------------------------------------------------------------- host API


@dataclasses.dataclass(frozen=True)
class CompressResult:
    data: np.ndarray        # uint8, exactly total_bytes long
    orig_bytes: int
    total_bytes: int

    @property
    def ratio(self) -> float:
        return self.orig_bytes / max(1, self.total_bytes)


def compress(data, config: LZSSConfig = DEFAULT_CONFIG) -> CompressResult:
    """Compress any array/bytes. Pads to whole chunks; header records truth."""
    raw = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
    n = raw.size
    s, c = config.symbol_size, config.chunk_symbols
    nsym = -(-max(n, 1) // s)
    nc = -(-nsym // c)
    padded = np.zeros(nc * c * s, np.uint8)
    padded[:n] = raw
    symbols = pack_symbols(jnp.asarray(padded), s).reshape(nc, c)
    buf, total = compress_chunks(symbols, config)
    buf = np.array(buf)  # writable host copy
    total = int(total)
    # patch true orig_bytes into the header (host-side, cheap)
    buf[16:24] = np.frombuffer(int(n).to_bytes(8, "little"), np.uint8)
    return CompressResult(data=buf[:total], orig_bytes=n, total_bytes=total)


def decompress(blob, decoder: str = "parallel") -> np.ndarray:
    """Decompress a container -> uint8 array of the original bytes."""
    blob = np.asarray(blob, np.uint8)
    h = fmt.parse_header(blob)
    n_tokens, payload_sizes = fmt.parse_tables(blob, h)
    cap = fmt.max_compressed_bytes(
        h.n_chunks * h.chunk_symbols * h.symbol_size, h.symbol_size, h.chunk_symbols
    )
    full = np.zeros(cap, np.uint8)
    full[: blob.size] = blob
    symbols = decompress_chunks(
        jnp.asarray(full),
        jnp.asarray(n_tokens),
        jnp.asarray(payload_sizes),
        symbol_size=h.symbol_size,
        chunk_symbols=h.chunk_symbols,
        n_chunks=h.n_chunks,
        decoder=decoder,
    )
    out = np.asarray(unpack_symbols(symbols.reshape(-1), h.symbol_size))
    return out[: h.orig_bytes]


def compression_ratio(data, config: LZSSConfig = DEFAULT_CONFIG) -> float:
    return compress(data, config).ratio
