"""GPULZ top-level API: the paper's five-step pipeline on TPU/XLA.

    matching -> local prefix sum -> encoding -> global prefix sum -> deflating
    `------------- Kernel I -------------'    `-- Kernel II --'   `Kernel III'

The pipeline is pluggable (core/pipeline.py): ``LZSSConfig(backend=...)``
selects the Kernel-I strategy AND the emit tail — ``fused-mono`` (the TPU
``"auto"`` default) runs the whole chain, from matching through the
Kernel-III deflate-scatter, in ONE Pallas kernel (``fused-deflate`` keeps
the three-launch split as the fallback).
``compress_chunks`` / ``compress_many_chunks`` are the fully jittable cores
(fixed shapes, usable in-graph for gradient/KV compression); ``compress`` /
``decompress`` and ``compress_many`` / ``decompress_many`` are host-facing
wrappers handling padding, headers and dynamic sizes.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import format as fmt

# The jittable pipeline layer; re-exported so existing imports keep working.
from repro.core.pipeline import (  # noqa: F401
    DEFAULT_CONFIG,
    WINDOW_LEVELS,
    CompressorBackend,
    DecoderBackend,
    LZSSConfig,
    available_backends,
    available_decoders,
    compress_chunks,
    compress_many_chunks,
    decompress_chunks,
    decompress_many_chunks,
    default_backend,
    default_decoder,
    get_backend,
    get_decoder,
    pack_symbols,
    register_backend,
    register_decoder,
    resolve_backend,
    resolve_chunk_geometry,
    resolve_decode_geometry,
    resolve_decoder,
    tuned_config,
    unpack_symbols,
)

# ---------------------------------------------------------------- host API


@dataclasses.dataclass(frozen=True)
class CompressResult:
    data: np.ndarray        # uint8, exactly total_bytes long
    orig_bytes: int
    total_bytes: int

    @property
    def ratio(self) -> float:
        return self.orig_bytes / max(1, self.total_bytes)


_DISPATCH_QUANTUM = 4096  # decompress shape-bucketing granularity (bytes)


def _dispatch_capacity(n_bytes: int) -> int:
    """Round a container size up to the next dispatch bucket.

    The decompression gathers are bounds-checked (clipped + masked), so the
    dispatch buffer only needs to cover the blob itself; rounding to a coarse
    quantum bounds jit-cache growth across blob sizes.  Crucially this is
    linear in the blob size — small blobs are NOT padded to the worst-case
    ``max_compressed_bytes`` capacity of their (possibly huge) chunk geometry.
    """
    return -(-max(n_bytes, 1) // _DISPATCH_QUANTUM) * _DISPATCH_QUANTUM


def _pack_padded(raw: np.ndarray, nc: int, cfg: LZSSConfig) -> jnp.ndarray:
    """(n,) uint8 host bytes -> (nc, C) int32 symbols, zero-padded."""
    s, c = cfg.symbol_size, cfg.chunk_symbols
    padded = np.zeros(nc * c * s, np.uint8)
    padded[: raw.size] = raw
    return pack_symbols(jnp.asarray(padded), s).reshape(nc, c)


def _as_bytes(data) -> np.ndarray:
    return np.ascontiguousarray(data).view(np.uint8).reshape(-1)


def compress(data, config: LZSSConfig = DEFAULT_CONFIG) -> CompressResult:
    """Compress any array/bytes. Pads to whole chunks; header records truth."""
    raw = _as_bytes(data)
    n = raw.size
    s, c = config.symbol_size, config.chunk_symbols
    nsym = -(-max(n, 1) // s)
    nc = -(-nsym // c)
    symbols = _pack_padded(raw, nc, config)
    # tuned geometry must resolve HERE, outside the jit trace — timed
    # sweeps inside a trace would measure tracing, not kernels
    config = resolve_chunk_geometry(config)
    buf, total = compress_chunks(symbols, config, jnp.int32(n))
    buf, total = np.asarray(buf), int(total)
    return CompressResult(data=buf[:total], orig_bytes=n, total_bytes=total)


def decompress(blob, decoder: str = "auto", chunks_per_block=None) -> np.ndarray:
    """Decompress a container -> uint8 array of the original bytes.

    ``decoder`` selects the decode strategy by registry key
    (``available_decoders()``; ``"auto"`` = the single-launch ``fused-mono``
    decoder on TPU, which reads the blob straight from HBM — ONE Pallas
    launch per decompress, no section gathers).  ``chunks_per_block`` pins
    the decode kernels' block geometry (format-invisible; ``None`` = the
    autotuner, resolved eagerly here — outside the jit trace).
    """
    blob = np.asarray(blob, np.uint8)
    # raises ValueError (expected vs actual byte counts) on truncated or
    # table-corrupted blobs instead of decoding garbage symbols
    h, n_tokens, payload_sizes = fmt.validate_container(blob)
    full = np.zeros(_dispatch_capacity(blob.size), np.uint8)
    full[: blob.size] = blob
    # the container's method byte routes the decode: entropy containers
    # decode only through the entropy decoder, lossy ones only through the
    # lossy decoder, raw ones through any raw decoder — a mismatch is a
    # clean ValueError, never garbage symbols
    method_params = ()
    if h.method == fmt.METHOD_HUFFMAN:
        if decoder not in ("auto", "deflate-full"):
            raise ValueError(
                f"method-1 (entropy) container: decodes only via "
                f"decoder='deflate-full' (or 'auto'), got {decoder!r}"
            )
        dec = "deflate-full"
    elif h.method == fmt.METHOD_LOSSY:
        if decoder not in ("auto", "lossy-fz"):
            raise ValueError(
                f"method byte {h.method} (lossy) container: decodes only "
                f"via decoder='lossy-fz' (or 'auto'), got {decoder!r}"
            )
        dec = "lossy-fz"
        # mode / inner method are trace-shape relevant: recover them from
        # the header host-side and pin them as static decode parameters
        method_params = get_decoder(dec).static_params(h)
    else:
        # canonicalize before the jit boundary: "auto"/aliases must share
        # the resolved key's trace cache entry, not mint their own
        dec = resolve_decoder(decoder)
        if dec == "deflate-full":
            raise ValueError(
                "decoder='deflate-full' decodes method-1 (entropy) "
                "containers only; this container is method 0 (raw LZSS)"
            )
        if dec == "lossy-fz":
            raise ValueError(
                "decoder='lossy-fz' decodes method-2 (lossy) containers "
                f"only; this container's method byte is {h.method}"
            )
    symbols = decompress_chunks(
        jnp.asarray(full),
        jnp.asarray(n_tokens),
        jnp.asarray(payload_sizes),
        symbol_size=h.symbol_size,
        chunk_symbols=h.chunk_symbols,
        n_chunks=h.n_chunks,
        decoder=dec,
        # tuned decode geometry resolves eagerly — never inside the trace
        chunks_per_block=resolve_decode_geometry(
            chunks_per_block,
            symbol_size=h.symbol_size,
            chunk_symbols=h.chunk_symbols,
            decoder=dec,
        ),
        method_params=method_params,
    )
    out = np.asarray(unpack_symbols(symbols.reshape(-1), h.symbol_size))
    return out[: h.orig_bytes]


def compression_ratio(data, config: LZSSConfig = DEFAULT_CONFIG) -> float:
    return compress(data, config).ratio


# ------------------------------------------------------------ batched API


@dataclasses.dataclass(frozen=True)
class BatchedCompressResult:
    """B containers compressed in one dispatch.

    ``data`` is the stacked (B, cap) uint8 buffer; row ``b`` holds a complete
    container in its first ``total_bytes[b]`` bytes (zeros beyond).
    """

    data: np.ndarray          # (B, cap) uint8
    orig_bytes: np.ndarray    # (B,) int64
    total_bytes: np.ndarray   # (B,) int64
    config: LZSSConfig

    def __len__(self) -> int:
        return self.data.shape[0]

    def __getitem__(self, b: int) -> CompressResult:
        return CompressResult(
            data=self.data[b, : self.total_bytes[b]],
            orig_bytes=int(self.orig_bytes[b]),
            total_bytes=int(self.total_bytes[b]),
        )

    @property
    def ratio(self) -> float:
        return int(self.orig_bytes.sum()) / max(1, int(self.total_bytes.sum()))


def compress_many(
    arrays, config: LZSSConfig = DEFAULT_CONFIG
) -> BatchedCompressResult:
    """Compress a batch of buffers in ONE jitted dispatch.

    ``arrays`` is either a list of array-likes (ragged sizes allowed — every
    buffer is padded to the batch's common chunk count, headers record true
    sizes) or a (B, n) array treated as B equal-size buffers.  This is the
    entry point the serving / checkpoint / gradient consumers use instead of
    per-array ``compress()`` loops.
    """
    if isinstance(arrays, np.ndarray) and arrays.ndim == 2:
        raws = [_as_bytes(arrays[i]) for i in range(arrays.shape[0])]
    else:
        raws = [_as_bytes(a) for a in arrays]
    if not raws:
        raise ValueError("compress_many needs at least one buffer")
    s, c = config.symbol_size, config.chunk_symbols
    sizes = np.array([r.size for r in raws], np.int64)
    nsym_max = -(-max(1, int(sizes.max())) // s)
    nc = -(-nsym_max // c)
    symbols = jnp.stack([_pack_padded(r, nc, config) for r in raws])
    # tuned geometry must resolve HERE, outside the jit trace (see compress)
    config = resolve_chunk_geometry(config)
    data, totals = compress_many_chunks(
        symbols, config, jnp.asarray(sizes, jnp.int32)
    )
    return BatchedCompressResult(
        data=np.asarray(data),
        orig_bytes=sizes,
        total_bytes=np.asarray(totals, np.int64),
        config=config,
    )


def decompress_many(
    batch, decoder: str = "auto", mesh=None, batch_axis=None,
    chunks_per_block=None,
) -> list:
    """Decompress a batch of containers in ONE jitted dispatch.

    ``batch`` is a ``BatchedCompressResult`` or a list of container blobs.
    All containers must share the same geometry (S, C, n_chunks) — true for
    anything produced by ``compress_many``.  ``decoder`` selects the decode
    strategy by registry key.  ``mesh``/``batch_axis`` shard the B dimension
    of the dispatch over a device mesh via the ``"sharded"`` decoder
    (``sharding/batch.py``); symbols are identical to the single-device
    dispatch.  ``chunks_per_block`` pins the decode kernels' block geometry
    (format-invisible; ``None`` = the autotuner, resolved eagerly here).
    Entropy (method-1) batches route to the ``"deflate-full"`` decoder
    automatically — with a mesh, it becomes the per-shard inner decoder of
    the sharded dispatch.  Returns a list of uint8 arrays.
    """
    if mesh is None and batch_axis is not None:
        # mirror LZSSConfig.__post_init__: a batch_axis without a mesh
        # would otherwise be silently dropped by the vmap default path
        raise ValueError("batch_axis requires mesh=...")
    if isinstance(batch, BatchedCompressResult):
        # slice rows to their live bytes: the stacked buffer is worst-case
        # wide, and the dispatch width below must track actual sizes
        blobs = [
            batch.data[b, : int(batch.total_bytes[b])]
            for b in range(len(batch))
        ]
    else:
        blobs = [np.asarray(b, np.uint8) for b in batch]
    headers, tables = [], []
    for i, b in enumerate(blobs):
        try:
            h, n_tok, pay = fmt.validate_container(b)
        except ValueError as e:
            raise ValueError(f"buffer {i}: {e}") from None
        headers.append(h)
        tables.append((n_tok, pay))
    h0 = headers[0]
    for i, h in enumerate(headers[1:], start=1):
        if (h.symbol_size, h.chunk_symbols, h.n_chunks, h.method) != (
            h0.symbol_size, h0.chunk_symbols, h0.n_chunks, h0.method
        ):
            raise ValueError(
                f"decompress_many requires a homogeneous batch geometry; "
                f"buffer 0 has (symbol_size={h0.symbol_size}, "
                f"chunk_symbols={h0.chunk_symbols}, n_chunks={h0.n_chunks}, "
                f"method={h0.method}) "
                f"but buffer {i} has (symbol_size={h.symbol_size}, "
                f"chunk_symbols={h.chunk_symbols}, n_chunks={h.n_chunks}, "
                f"method={h.method}); "
                f"decompress mismatched containers individually"
            )
    # method-byte routing, mirroring ``decompress``: entropy batches take
    # the entropy decoder, lossy batches the lossy decoder (per-shard,
    # when a mesh shards the dispatch)
    entropy_batch = h0.method == fmt.METHOD_HUFFMAN
    lossy_batch = h0.method == fmt.METHOD_LOSSY
    method_params = ()
    if lossy_batch:
        # mode / inner method are static decode parameters (trace-shape
        # relevant), so a batched dispatch needs them homogeneous too
        sp = get_decoder("lossy-fz").static_params
        method_params = sp(h0)
        for i, h in enumerate(headers[1:], start=1):
            if sp(h) != method_params:
                raise ValueError(
                    f"decompress_many requires a homogeneous lossy batch; "
                    f"buffer 0 has (mode, inner_method)={method_params} "
                    f"but buffer {i} has {sp(h)}; "
                    f"decompress mismatched containers individually"
                )
    inner_decoder = None
    if mesh is not None:
        if decoder not in ("auto", "sharded"):
            raise ValueError(
                f"mesh= shards the dispatch through the 'sharded' decoder; "
                f"it cannot be combined with decoder={decoder!r}"
            )
        decoder = "sharded"
        if entropy_batch:
            inner_decoder = "deflate-full"
        elif lossy_batch:
            inner_decoder = "lossy-fz"
    elif entropy_batch:
        if decoder not in ("auto", "deflate-full"):
            raise ValueError(
                f"method-1 (entropy) containers: decode only via "
                f"decoder='deflate-full' (or 'auto'), got {decoder!r}"
            )
        decoder = "deflate-full"
    elif lossy_batch:
        if decoder not in ("auto", "lossy-fz"):
            raise ValueError(
                f"method byte {h0.method} (lossy) containers: decode only "
                f"via decoder='lossy-fz' (or 'auto'), got {decoder!r}"
            )
        decoder = "lossy-fz"
    elif decoder != "sharded" and resolve_decoder(decoder) == "deflate-full":
        raise ValueError(
            "decoder='deflate-full' decodes method-1 (entropy) containers "
            "only; this batch is method 0 (raw LZSS)"
        )
    elif decoder != "sharded" and resolve_decoder(decoder) == "lossy-fz":
        raise ValueError(
            "decoder='lossy-fz' decodes method-2 (lossy) containers only; "
            f"this batch's method byte is {h0.method}"
        )
    width = _dispatch_capacity(max(b.size for b in blobs))
    stacked = np.zeros((len(blobs), width), np.uint8)
    for i, b in enumerate(blobs):
        stacked[i, : b.size] = b
    dec = resolve_decoder(decoder)  # one trace cache entry per key
    symbols = decompress_many_chunks(
        jnp.asarray(stacked),
        jnp.asarray(np.stack([t[0] for t in tables])),
        jnp.asarray(np.stack([t[1] for t in tables])),
        symbol_size=h0.symbol_size,
        chunk_symbols=h0.chunk_symbols,
        n_chunks=h0.n_chunks,
        decoder=dec,
        chunks_per_block=resolve_decode_geometry(
            chunks_per_block,  # eager: sweeps never run inside the trace
            symbol_size=h0.symbol_size,
            chunk_symbols=h0.chunk_symbols,
            decoder=dec,
        ),
        mesh=mesh,
        batch_axis=(
            tuple(batch_axis)
            if isinstance(batch_axis, list)
            else batch_axis  # static jit arg: must be hashable
        ),
        inner_decoder=inner_decoder,
        method_params=method_params,
    )
    s = h0.symbol_size
    flat = np.asarray(symbols).reshape(len(blobs), -1)
    out_bytes = np.stack(
        [(flat >> (8 * k)) & 0xFF for k in range(s)], axis=-1
    ).astype(np.uint8).reshape(len(blobs), -1)
    return [out_bytes[i, : h.orig_bytes] for i, h in enumerate(headers)]
