"""Flag-array packing, per-chunk payload construction, and deflating.

Maps to the paper's pipeline as follows:
  * ``pack_flags`` / ``build_chunk_payloads`` — the encode tail of Kernel I
    (write compressed symbols at their local-prefix-sum offsets, emit the
    per-chunk flag array);
  * ``global_offsets`` — Kernel II (two exclusive prefix sums: one over the
    compressed payload sizes, one over the flag-array sizes — the paper calls
    CUB ``DeviceScan::ExclusiveSum`` twice);
  * ``scatter_sections`` — Kernel III (deflate: drop the empty bytes by
    scattering each chunk's compact bytes to its global offset).

All shapes are static; variable-size results live in fixed worst-case buffers
with masked ('drop'-mode) scatters, the JAX analogue of bounds-checked writes.
"""

from __future__ import annotations

import jax.numpy as jnp


def pack_flags(emitted, use_match, n_tokens=None):
    """Pack one flag bit per emitted token (1 = pointer, 0 = literal).

    ``n_tokens`` (nc,) may be supplied when the backend already computed it
    (the fused Kernel I does) to skip the reduction here.

    Returns:
      flag_bytes: (nc, C//8) int32 in [0,255] — bit t of the chunk's flag
        stream is the t-th token's kind; trailing bits are zero.
      flag_sizes: (nc,) int32 — ceil(n_tokens/8) bytes actually used.
    """
    nc, c = emitted.shape
    cb = (c + 7) // 8
    rank = jnp.cumsum(emitted.astype(jnp.int32), axis=1) - 1
    byte_idx = jnp.where(emitted, rank // 8, cb)  # cb => dropped
    bitval = (use_match.astype(jnp.int32) << (rank % 8)) * emitted
    rows = jnp.arange(nc)[:, None]
    flag_bytes = (
        jnp.zeros((nc, cb), jnp.int32)
        .at[rows, byte_idx]
        .add(bitval, mode="drop")
    )
    if n_tokens is None:
        n_tokens = jnp.sum(emitted.astype(jnp.int32), axis=1)
    flag_sizes = (n_tokens + 7) // 8
    return flag_bytes, flag_sizes


def build_chunk_payloads(symbols, lengths, offsets, fields, *, symbol_size):
    """Write each chunk's compressed bytes at their local offsets.

    Returns (nc, C*S) int32 byte values; bytes beyond fields['payload_sizes']
    are zero.  Pointers are [length, offset]; literals are the S symbol bytes
    little-endian.
    """
    nc, c = symbols.shape
    s = symbol_size
    bufsz = c * s
    use_match = fields["use_match"]
    emitted = use_match | (fields["sizes"] > 0)
    local = fields["local_off"]
    rows = jnp.arange(nc)[:, None]
    buf = jnp.zeros((nc, bufsz), jnp.int32)
    for b in range(max(2, s)):
        match_byte = jnp.where(b == 0, lengths, offsets)
        lit_byte = (symbols >> (8 * b)) & 0xFF
        val = jnp.where(use_match, match_byte, lit_byte)
        width = jnp.where(use_match, 2, s)
        valid = emitted & (b < width)
        idx = jnp.where(valid, local + b, bufsz)  # bufsz => dropped
        buf = buf.at[rows, idx].add(jnp.where(valid, val, 0), mode="drop")
    return buf


def global_offsets(payload_sizes, flag_sizes):
    """Kernel II: exclusive prefix sums over chunk payload and flag sizes."""
    pay_csum = jnp.cumsum(payload_sizes)
    flag_csum = jnp.cumsum(flag_sizes)
    pay_off = pay_csum - payload_sizes
    flag_off = flag_csum - flag_sizes
    return pay_off, pay_csum[-1], flag_off, flag_csum[-1]


def scatter_section(out, base, chunk_bytes, chunk_sizes, chunk_offsets):
    """Kernel III: scatter per-chunk compact bytes to base + global offsets.

    out:         (cap,) int32 flat output buffer
    base:        scalar int32 — section start within ``out``
    chunk_bytes: (nc, B) int32 — per-chunk buffers (valid prefix only)
    """
    nc, b = chunk_bytes.shape
    j = jnp.arange(b, dtype=jnp.int32)[None, :]
    valid = j < chunk_sizes[:, None]
    dest = jnp.where(valid, base + chunk_offsets[:, None] + j, out.shape[0])
    return out.at[dest.reshape(-1)].add(
        jnp.where(valid, chunk_bytes, 0).reshape(-1), mode="drop"
    )


def gather_section(flat, base, chunk_sizes, chunk_offsets, width):
    """Inverse of scatter_section: re-chunk a compact section into (nc, width).

    Bytes beyond chunk_sizes[c] are zeroed.  Used by the decoder to rebuild
    per-chunk aligned flag / payload arrays from the blob.
    """
    nc = chunk_sizes.shape[0]
    j = jnp.arange(width, dtype=jnp.int32)[None, :]
    valid = j < chunk_sizes[:, None]
    src = jnp.clip(base + chunk_offsets[:, None] + j, 0, flat.shape[0] - 1)
    vals = flat[src.reshape(-1)].reshape(nc, width)
    return jnp.where(valid, vals, 0)
