"""Multi-byte LZSS matching (paper §3.2.3, §3.3.2) — TPU-native formulation.

The paper assigns one CUDA thread per coding position; each thread walks the
sliding window with a bounded, divergence-free loop.  On TPU there are no
independent threads, so we transpose the parallelism: positions live on vector
lanes and we loop over window offsets ``d``.  For a fixed ``d`` the candidate
match length at position ``i`` is the run length of ``eq_d[i] = x[i] == x[i-d]``
starting at ``i``.  We compute that run length with a *capped log-doubling*
recurrence instead of the paper's sequential pointer walk:

    r_0[i]   = eq[i]                      (= min(run, 1))
    r_{k+1}[i] = r_k[i] + (r_k[i] == 2^k) * r_k[i + 2^k]   (= min(run, 2^{k+1}))

which preserves the paper's *stable complexity* property (their reason for
redesigning the matching loop: warp divergence on GPU == serialization on TPU).

Semantics (paper-faithful):
  * matches never cross chunk boundaries (the chunk is the parallel unit);
  * match source starts in the window  [i - min(i, W), i - 1];
  * match length is capped at  min(offset, max_len, chunk remainder)  — the
    "length never exceeds offset" rule from §3.3.2, which also guarantees
    copies never self-overlap (enables the parallel decoder in decode.py);
  * ties between equal-length candidates resolve to the *largest* offset,
    matching the paper's window walk (far-to-near, strict improvement only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

MAX_LEN_CAP = 255  # lengths are encoded in one byte


def _num_doubling_levels(window: int, max_len: int = MAX_LEN_CAP) -> int:
    """Levels K such that 2^K >= achievable length cap min(window, max_len)."""
    cap = min(window, max_len)
    k = 0
    while (1 << k) < cap:
        k += 1
    return k


def _shift_left_static(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """out[..., i] = x[..., i + k], zero fill (no wrap across chunk ends)."""
    if k == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, k)]
    return jnp.pad(x, pad)[..., k:]


def capped_run_lengths(eq: jnp.ndarray, levels: int) -> jnp.ndarray:
    """min(run-length starting at i, 2^levels) for a 0/1 int array ``eq``."""
    r = eq.astype(jnp.int32)
    for k in range(levels):
        stride = 1 << k
        r = r + jnp.where(r == stride, _shift_left_static(r, stride), 0)
    return r


@functools.partial(jax.jit, static_argnames=("window", "max_len"))
def find_matches(
    symbols: jnp.ndarray, *, window: int, max_len: int = MAX_LEN_CAP
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Find the longest match for every position of every chunk.

    Args:
      symbols: (num_chunks, C) int32 symbol values (S bytes packed per symbol).
      window:  sliding-window size W in symbols (1..255).
      max_len: maximum match length in symbols (<= 255; one-byte length field).

    Returns:
      lengths: (num_chunks, C) int32 — best match length (0 = no match).
      offsets: (num_chunks, C) int32 — its offset d in [1, W] (0 = no match).
    """
    if symbols.ndim != 2:
        raise ValueError(f"symbols must be (num_chunks, C), got {symbols.shape}")
    if not (1 <= window <= 255):
        raise ValueError(f"window must be in [1, 255], got {window}")
    nc, c = symbols.shape
    idx = lax.broadcasted_iota(jnp.int32, (nc, c), 1)
    # Left-pad with a sentinel so x[i-d] is gathered with a static-size
    # dynamic_slice; the sentinel never equals a real symbol *and* positions
    # i < d are additionally masked via the iota test below.
    padded = jnp.concatenate(
        [jnp.zeros((nc, window), jnp.int32), symbols.astype(jnp.int32)], axis=1
    )
    pack = window + 1  # key = len * pack + d  (ties -> larger offset wins)

    def body_for(levels):
        def body(d, best):
            shifted = lax.dynamic_slice_in_dim(padded, window - d, c, axis=1)
            eq = (symbols == shifted) & (idx >= d)
            r = capped_run_lengths(eq.astype(jnp.int32), levels)
            cand = jnp.minimum(r, jnp.minimum(d, max_len))
            key = cand * pack + d
            return jnp.maximum(best, key)

        return body

    # Bucketed offsets: candidates are capped at min(d, max_len), so offsets
    # in (2^{k-1}, 2^k] only need k doubling levels — ~15% fewer vector ops
    # at W=128 than running every offset at ceil(log2 W) levels (§Perf).
    best = jnp.zeros((nc, c), jnp.int32)
    lo = 1
    k = 0
    max_levels = _num_doubling_levels(window, max_len)
    while lo <= window:
        k = min(k, max_levels)
        hi = min(window, (1 << k) if k else 1)
        best = lax.fori_loop(lo, hi + 1, body_for(k), best)
        lo = hi + 1
        k += 1
    lengths = best // pack
    offsets = jnp.where(lengths > 0, best % pack, 0)
    return lengths, offsets


def find_matches_reference(symbols, *, window: int, max_len: int = MAX_LEN_CAP):
    """Brute-force O(C^2 W) oracle (numpy, host) for tests."""
    import numpy as np

    symbols = np.asarray(symbols)
    nc, c = symbols.shape
    lengths = np.zeros((nc, c), np.int32)
    offsets = np.zeros((nc, c), np.int32)
    for n in range(nc):
        for i in range(c):
            best_len, best_off = 0, 0
            for d in range(1, min(i, window) + 1):
                cap = min(d, max_len, c - i)
                ln = 0
                while ln < cap and symbols[n, i + ln] == symbols[n, i - d + ln]:
                    ln += 1
                # strict improvement, scanning far-to-near => largest-offset tie-break
                if ln > best_len or (ln == best_len and ln > 0 and d > best_off):
                    best_len, best_off = ln, d
            lengths[n, i] = best_len
            offsets[n, i] = best_off
    return lengths, offsets
