"""Bit-plane transpose (bitshuffle) over uint16 unit streams.

FZ-GPU's (PAPERS.md) key pre-stage for error-bounded scientific data: after
dual-quant, most uint16 code bits are zero or slowly varying, but they are
*interleaved* across bit positions inside each unit.  Transposing each block
of units into bit planes groups the near-constant high bits into long byte
runs, which is exactly the shape the LZSS/deflate-full backends compress
well.

Layout (fixed, part of the method-2 wire format):

  * the stream is processed in blocks of ``BLOCK_UNITS = 512`` uint16 units
    (1024 bytes); callers pad to a multiple (padding value 0).
  * within a block, output plane ``b`` (b = 0..15, LSB first) is 64 bytes;
    its byte ``j`` packs bit ``b`` of units ``8j .. 8j+7``, unit ``8j`` in
    the byte's LSB.
  * blocks are emitted back to back, planes in order within each block, so
    the output byte count equals the input byte count.

Both directions are fixed-shape and fully in-graph (vmap/shard_map safe).
The Pallas kernels (kernels/lz_bitshuffle.py) are selected on TPU;
``REPRO_BITSHUFFLE_PALLAS=1/0`` overrides (same convention as
``REPRO_ENTROPY_PALLAS``), and the XLA path below is the reference both are
tested byte-identical against.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax import lax

BLOCK_UNITS = 512          # uint16 units per bitshuffle block
BLOCK_BYTES = BLOCK_UNITS * 2
PLANES = 16
PLANE_BYTES = BLOCK_UNITS // 8


def _use_pallas(impl) -> bool:
    """Impl selection, mirroring ``core.entropy._use_pallas``.

    ``impl`` is ``"pallas"`` / ``"xla"`` (explicit) or ``None`` (platform
    default: Pallas on TPU, XLA elsewhere; ``REPRO_BITSHUFFLE_PALLAS=1/0``
    overrides, e.g. to exercise the kernels in interpret mode off-TPU).
    """
    if impl in ("pallas", "xla"):
        return impl == "pallas"
    if impl is not None:
        raise ValueError(f"impl must be 'pallas', 'xla' or None: {impl!r}")
    env = os.environ.get("REPRO_BITSHUFFLE_PALLAS")
    if env is not None:
        return env != "0"
    return jax.default_backend() == "tpu"


def padded_units(n_units: int) -> int:
    """Smallest multiple of BLOCK_UNITS holding ``n_units``."""
    return -(-max(n_units, 1) // BLOCK_UNITS) * BLOCK_UNITS


def shuffle_xla(units: jnp.ndarray) -> jnp.ndarray:
    """(N,) uint16 -> (2N,) uint8 bit-plane transpose; N % 512 == 0."""
    n = units.shape[0]
    nb = n // BLOCK_UNITS
    u = units.reshape(nb, BLOCK_UNITS).astype(jnp.int32)
    planes = lax.broadcasted_iota(jnp.int32, (nb, BLOCK_UNITS, PLANES), 2)
    bits = (u[:, :, None] >> planes) & 1                   # (nb, 512, 16)
    bits = bits.reshape(nb, PLANE_BYTES, 8, PLANES)
    weight = lax.broadcasted_iota(jnp.int32, bits.shape, 2)
    packed = jnp.sum(bits << weight, axis=2)               # (nb, 64, 16)
    out = packed.transpose(0, 2, 1).reshape(nb * BLOCK_BYTES)
    return out.astype(jnp.uint8)


def unshuffle_xla(shuffled: jnp.ndarray) -> jnp.ndarray:
    """(2N,) uint8 -> (N,) uint16 inverse transpose; 2N % 1024 == 0."""
    nb = shuffled.shape[0] // BLOCK_BYTES
    p = shuffled.reshape(nb, PLANES, PLANE_BYTES).astype(jnp.int32)
    pos = lax.broadcasted_iota(
        jnp.int32, (nb, PLANES, PLANE_BYTES, 8), 3
    )
    bits = (p[:, :, :, None] >> pos) & 1                   # (nb, 16, 64, 8)
    bits = bits.transpose(0, 2, 3, 1)                      # (nb, 64, 8, 16)
    weight = lax.broadcasted_iota(jnp.int32, bits.shape, 3)
    vals = jnp.sum(bits << weight, axis=3)                 # (nb, 64, 8)
    return vals.reshape(nb * BLOCK_UNITS).astype(jnp.uint16)


def shuffle(units: jnp.ndarray, impl=None) -> jnp.ndarray:
    """Bit-plane transpose of a padded uint16 unit stream."""
    if units.shape[0] % BLOCK_UNITS:
        raise ValueError(
            f"bitshuffle input must be a multiple of {BLOCK_UNITS} units: "
            f"{units.shape[0]}"
        )
    if _use_pallas(impl):
        from repro.kernels import ops

        return ops.bitshuffle(units)
    return shuffle_xla(units)


def unshuffle(shuffled: jnp.ndarray, impl=None) -> jnp.ndarray:
    """Inverse of ``shuffle``; input length a multiple of 1024 bytes."""
    if shuffled.shape[0] % BLOCK_BYTES:
        raise ValueError(
            f"bitshuffle inverse input must be a multiple of {BLOCK_BYTES} "
            f"bytes: {shuffled.shape[0]}"
        )
    if _use_pallas(impl):
        from repro.kernels import ops

        return ops.bitunshuffle(shuffled)
    return unshuffle_xla(shuffled)
