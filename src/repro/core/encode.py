"""Greedy token selection + per-chunk (local) compressed layout.

This is the encode half of the paper's Kernel I (§3.3.2): one CUDA thread per
block walks the chunk, emits a token at the current coding position (a 2-byte
pointer if a long-enough match exists, else an S-byte literal), and skips the
symbols a match covers.

Two implementations:
  * ``select_tokens_scan``     — paper-faithful sequential walk (lax.scan over
    positions, vmapped across chunks — exactly the paper's one-thread-per-chunk
    parallelization, chunk-parallel only).
  * ``select_tokens_doubling`` — beyond-paper parallel selector.  The walk is an
    orbit of 0 under the single-successor map next(i) = i + step(i); the visited
    set is computed in ceil(log2 C) rounds of gather+scatter pointer doubling.

Both return identical results (property-tested); the doubling variant removes
the last O(C) sequential dependency from the compression pipeline.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax


def min_match_length(symbol_size: int) -> int:
    """Minimum match length worth encoding as a 2-byte pointer.

    A pointer costs 2 bytes (+1 flag bit); a literal costs S bytes (+1 flag
    bit).  A match of length L replaces L literals (L*S bytes, L flag bits),
    so it pays off when L*S > 2, i.e. L >= floor(2/S) + 1.
    """
    return max(1, 2 // symbol_size + 1)


def _steps(lengths: jnp.ndarray, min_match: int) -> jnp.ndarray:
    return jnp.where(lengths >= min_match, lengths, 1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("min_match",))
def select_tokens_scan(lengths: jnp.ndarray, *, min_match: int) -> jnp.ndarray:
    """(nc, C) match lengths -> (nc, C) bool 'a token is emitted here'."""
    nc, c = lengths.shape
    step = _steps(lengths, min_match)

    def body(next_pos, xs):
        i, step_i = xs
        emit = next_pos == i
        next_pos = jnp.where(emit, i + step_i, next_pos)
        return next_pos, emit

    _, emitted = lax.scan(
        body,
        jnp.zeros((nc,), jnp.int32),
        (jnp.arange(c, dtype=jnp.int32), step.T),
    )
    return emitted.T


@functools.partial(jax.jit, static_argnames=("min_match",))
def select_tokens_doubling(lengths: jnp.ndarray, *, min_match: int) -> jnp.ndarray:
    """Parallel selector: pointer-doubling orbit marking (beyond-paper)."""
    nc, c = lengths.shape
    step = _steps(lengths, min_match)
    idx = jnp.arange(c, dtype=jnp.int32)[None, :]
    # Successor map over [0, C]; C is an absorbing end state.
    jump = jnp.minimum(idx + step, c)
    jump = jnp.concatenate([jump, jnp.full((nc, 1), c, jnp.int32)], axis=1)
    visited = jnp.zeros((nc, c + 1), jnp.bool_).at[:, 0].set(True)
    rows = jnp.arange(nc)[:, None]
    for _ in range(max(1, math.ceil(math.log2(c + 1)))):
        landed = (
            jnp.zeros((nc, c + 1), jnp.int32)
            .at[rows, jump]
            .add(visited.astype(jnp.int32))
        )
        visited = visited | (landed > 0)
        jump = jnp.take_along_axis(jump, jump, axis=1)
    return visited[:, :c]


def token_fields(
    lengths: jnp.ndarray,
    emitted: jnp.ndarray,
    *,
    min_match: int,
    symbol_size: int,
):
    """Derive per-position token metadata from the selection.

    Returns dict with (nc, C) arrays:
      use_match: bool — emitted token is a pointer
      sizes:     int32 — encoded bytes contributed at this position (0 if none)
      local_off: int32 — exclusive prefix sum of sizes within the chunk
                 (the paper's *local prefix sum*, up-sweep/down-sweep § 3.2.2)
    and (nc,) arrays:
      payload_sizes: int32 — compressed payload bytes per chunk
      n_tokens:      int32 — tokens per chunk (= flag bits)
    """
    use_match = emitted & (lengths >= min_match)
    sizes = jnp.where(
        emitted, jnp.where(use_match, 2, symbol_size), 0
    ).astype(jnp.int32)
    csum = jnp.cumsum(sizes, axis=1)
    local_off = csum - sizes  # exclusive
    payload_sizes = csum[:, -1]
    n_tokens = jnp.sum(emitted.astype(jnp.int32), axis=1)
    return dict(
        use_match=use_match,
        sizes=sizes,
        local_off=local_off,
        payload_sizes=payload_sizes,
        n_tokens=n_tokens,
    )
