"""Pluggable compression pipeline backends.

The paper's pipeline is

    matching -> local prefix sum -> encoding -> global prefix sum -> deflating
    `------------- Kernel I -------------'    `-- Kernel II --'   `Kernel III'

Kernel I is the part with real implementation freedom (their Fig. 4(c) vs
(d)): it can be staged through HBM as separate XLA ops, or fused so the
equality rows, run lengths, selection walk and local prefix sum never leave
VMEM.  This module makes that choice a *backend*:

  * ``CompressorBackend`` — the Kernel-I contract: ``kernel1(symbols, cfg)``
    returns every per-position / per-chunk array the shared Kernel-II/III
    tail needs (see ``Kernel1Result``).
  * a registry (``register_backend`` / ``get_backend``) so new execution
    strategies plug in without touching the pipeline tail — this is the
    extension point for future PRs (see ROADMAP.md).
  * ``compress_chunks`` / ``decompress_chunks`` — the jittable single-buffer
    cores, now dispatching Kernel I through the configured backend.
  * ``compress_many_chunks`` / ``decompress_many_chunks`` — the batched
    in-graph API: one dispatch compresses B independent buffers (vmap over
    the backend + tail), which is what the gradient/KV/checkpoint consumers
    need instead of per-array host loops.

A backend may also own the *emit* tail (Kernel II global prefix sums +
Kernel III deflate-scatter) by providing an optional ``emit`` method; the
default is the shared XLA tail ``emit_xla``.  This keeps new execution
strategies registry entries rather than ``if``-ladders in
``compress_chunks``.

Registered backends:

  ``xla``           unfused reference path (workflow (c)): XLA matching, the
                    beyond-paper pointer-doubling selector, XLA prefix sums.
  ``xla-scan``      same but with the paper-faithful sequential selection
                    walk (lax.scan) — the equivalence oracle.
  ``pallas-match``  Pallas matching kernel, XLA select + prefix sums (the
                    old ``matcher="pallas"`` switch).
  ``fused``         the fused Pallas Kernel I (kernels/lz_match.py) produces
                    lengths/offsets/emitted/local_off/payload_sizes/n_tokens
                    in one VMEM-resident kernel; the redundant XLA selection
                    and local prefix sum are skipped entirely.  The emit
                    tail stays XLA.
  ``fused-deflate`` fused Kernel I plus a fused Kernel II+III
                    (kernels/lz_scatter.py) — one kernel computes both
                    global exclusive prefix sums, a second rebuilds the
                    flag/payload sections in VMEM and scatters them into the
                    blob via scalar-prefetched per-chunk offsets.  The
                    aligned (nc, C//8)/(nc, C*S) section arrays never
                    materialize in HBM, but the (nc, C) Kernel-I outputs
                    still round-trip through it between the launches.
  ``fused-mono``    the paper's workflow (d) end to end in ONE kernel
                    (kernels/lz_fused.py): matching, selection, both local
                    AND global prefix sums (SMEM carry over the sequential
                    grid), section rebuild and the blob scatter — no
                    intermediate of any shape touches HBM, and the blob is
                    written through per-chunk DMA windows instead of a
                    VMEM-resident (1, cap) block, so containers are not
                    bounded by VMEM.  Owns the whole single-buffer path via
                    the optional ``compress`` hook (see below).
  ``sharded``       multi-device batch layer (sharding/batch.py): the B
                    dimension of the batched entry points is shard-mapped
                    over ``LZSSConfig(mesh=..., batch_axis=...)`` and every
                    shard runs the auto-resolved platform backend.  Plugs in
                    through two more optional backend hooks, ``compress_many``
                    / ``decompress_many`` (mirroring ``emit``): a backend may
                    own the whole batched dispatch, with the vmapped
                    single-buffer core as the default.
  ``deflate-full``  the entropy-coded container (core/entropy.py): the
                    platform LZSS pipeline runs first (via the ``compress``
                    hook, so the fused-mono kernel is still the Kernel-I/II/
                    III engine on TPU), then both container sections are
                    canonical-Huffman coded into a method-1 VERSION-2
                    container with gap-array parallel entry points.  The
                    only backend whose containers differ from the others —
                    byte-identity is traded for ratio; decode requires the
                    ``deflate-full`` decoder (``LZSSConfig`` normalizes
                    ``decoder="auto"`` to it, and ``lzss.decompress``
                    dispatches on the container's method byte).

Decompression mirrors the same design: ``DecoderBackend`` is the decode-side
contract (per-chunk aligned flag/payload sections -> symbols), with its own
registry (``register_decoder`` / ``get_decoder``) and entries

  ``xla-parallel``  beyond-paper fully parallel XLA decoder
                    (core/decode.py:decode_parallel).
  ``xla-scan``      paper-faithful sequential token walk — the oracle.
  ``fused``         fused Pallas decoder (kernels/lz_decode.py): flag
                    extraction, both read/write prefix sums, payload gather
                    and pointer-doubling copy resolution stay in VMEM per
                    chunk block; symbols are written to HBM exactly once.
                    The sections still reach it via two XLA
                    ``deflate.gather_section`` gathers staged through HBM.
  ``fused-mono``    the decode-side workflow (d): ONE Pallas launch per
                    decompress (kernels/lz_decode_mono.py).  The container
                    blob is read straight from HBM (``memory_space=ANY``)
                    through per-chunk DMA windows at scalar-prefetched
                    section offsets, so the gathers fuse into the decode
                    chain and ``deflate.gather_section`` drops out of the
                    decode path entirely.  Owns the whole container->symbols
                    path via the optional ``decode_blob`` hook (the decode
                    mirror of the compressor's ``compress`` hook).
  ``sharded``       decode-side mirror of the sharded compressor: batched
                    decompression shard-mapped over the mesh passed at
                    dispatch, platform decoder per shard.  Entropy containers
                    shard too: ``lzss.decompress_many`` forwards the inner
                    per-shard decoder (``inner_decoder=``) through the
                    ``decompress_many`` hook.
  ``deflate-full``  decoder for method-1 (entropy) containers: gap-array
                    parallel Huffman bitstream decode (Pallas kernel on TPU,
                    vectorized lax.scan elsewhere) rebuilds the raw sections,
                    then hands off to the platform LZSS decode chain.  Raw
                    containers raise a ValueError under it, and entropy
                    containers raise under every raw decoder —
                    ``lzss.decompress`` routes on the method byte.

``LZSSConfig.decoder`` accepts a registry key, ``"auto"`` (the single-launch
``fused-mono`` decoder on TPU, xla-parallel elsewhere — resolved at
dispatch, like ``default_backend()``) or the legacy aliases
``"parallel"``/``"scan"``, which are normalized to registry keys at
construction.

On TPU the single-kernel ``fused-mono`` paths are the default in BOTH
directions (``REPRO_FUSED_MONO=0`` falls back to the split ``fused-deflate``
compressor / ``fused`` decoder, e.g. while auditing the mono kernels'
Mosaic lowering on new hardware); elsewhere the kernels execute in
interpret mode, so the defaults stay ``xla`` / ``xla-parallel`` (identical
bytes, no interpreter overhead).  Kernel block geometry
(``chunks_per_block``, and prospectively ``chunk_symbols`` via
``tuned_config``) resolves through the ``core/autotune.py`` chooser.  All
backends produce byte-identical containers and all decoders identical
symbols — property- and sweep-tested in tests/test_pipeline.py,
tests/test_decoders.py, tests/test_conformance.py, tests/test_decode_mono.py
and the golden corpus under tests/golden/.
"""

from __future__ import annotations

import dataclasses
import functools
import inspect
import math
import os
from typing import Dict, Protocol

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core import decode as decode_mod
from repro.core import deflate, encode, format as fmt, match

# --------------------------------------------------------------- config


def default_backend() -> str:
    """The preferred compressor backend for the current accelerator.

    On TPU the single-kernel ``fused-mono`` compressor is the hot path;
    setting ``REPRO_FUSED_MONO=0`` falls back to the split ``fused-deflate``
    pipeline (byte-identical output, three launches instead of one).
    """
    if jax.default_backend() != "tpu":
        return "xla"
    if os.environ.get("REPRO_FUSED_MONO", "1") == "0":
        return "fused-deflate"
    return "fused-mono"


def default_decoder() -> str:
    """The preferred decoder for the current accelerator.

    On TPU the single-launch ``fused-mono`` decoder is the hot path;
    ``REPRO_FUSED_MONO=0`` falls back to the split ``fused`` decoder
    (gathered sections + per-chunk kernel — identical symbols, two extra
    HBM-staged gathers), the same audit escape hatch as the compress side.
    """
    if jax.default_backend() != "tpu":
        return "xla-parallel"
    if os.environ.get("REPRO_FUSED_MONO", "1") == "0":
        return "fused"
    return "fused-mono"


def resolve_chunk_geometry(cfg: "LZSSConfig") -> "LZSSConfig":
    """Pin ``chunks_per_block`` eagerly, *before* any jit trace.

    The autotuner's timed sweep is only meaningful outside a trace
    (``autotune.best_geometry`` refuses to sweep under one — in-trace
    timings measure tracing, not kernels).  The host wrappers
    (``lzss.compress`` / ``compress_many``) call this right before the
    jitted cores: with tuning enabled and no user pin, the tuned g is
    resolved here — eagerly, kernels actually executing — and baked into
    the config as a static pin, so no call site inside the trace ever
    needs the tuner.  With tuning disabled (or an explicit pin) the config
    passes through unchanged: the in-trace fallback is deterministic.
    """
    if cfg.chunks_per_block is not None or not autotune.enabled():
        return cfg
    g = autotune.block_geometry(
        symbol_size=cfg.symbol_size,
        chunk_symbols=cfg.chunk_symbols,
        direction="compress",
        window=cfg.window,
    )
    return dataclasses.replace(cfg, chunks_per_block=g)


def resolve_decode_geometry(
    chunks_per_block, *, symbol_size: int, chunk_symbols: int, decoder="auto"
):
    """Decode-side mirror of ``resolve_chunk_geometry``.

    Returns the ``chunks_per_block`` value to pass (statically) into
    ``decompress_chunks`` / ``decompress_many_chunks``: the caller's pin if
    given, the eagerly tuned g when tuning is enabled, else ``None`` (the
    in-trace deterministic fallback).  Called by ``lzss.decompress`` /
    ``decompress_many`` with the container header's geometry, before the
    jit boundary.  Decoders that never tile a kernel (the pure-XLA entries
    mark themselves ``uses_block_geometry = False``) skip the sweep — a
    tuned g would be dead weight there.
    """
    if chunks_per_block is not None or not autotune.enabled():
        return chunks_per_block
    if not getattr(get_decoder(decoder), "uses_block_geometry", True):
        return None  # geometry never reaches a kernel: nothing to tune
    return autotune.block_geometry(
        symbol_size=symbol_size,
        chunk_symbols=chunk_symbols,
        direction="decompress",
    )


@dataclasses.dataclass(frozen=True)
class LZSSConfig:
    """Paper parameters: S (symbol bytes), W (window), C (chunk symbols).

    ``backend`` selects the Kernel-I execution strategy and ``decoder`` the
    decompression strategy (see module docstring); both are registry keys,
    and both accept ``"auto"`` (resolved per-platform at dispatch time).
    The legacy decoder aliases ``"parallel"``/``"scan"`` normalize to their
    registry keys here.

    ``chunks_per_block`` pins the Pallas kernels' block geometry (how many
    chunks ride one grid step's sublane dimension); the default ``None``
    defers to the ``core/autotune.py`` chooser (tuned cache on TPU,
    deterministic static fallback elsewhere).  The config travels with the
    *compress* direction; decode entry points take the same pin as their
    own ``chunks_per_block=`` argument (it is format-invisible, so the
    containers decode identically either way) — consumers holding a config
    forward it, e.g. ``KVBlockStore.restore_many`` and
    ``CheckpointManager`` restores.  The
    (chunk_symbols, chunks_per_block) pair is validated against the VMEM
    block budget here — ``autotune.validate_block_geometry`` — so an
    oversized geometry fails at config construction with the offending pair
    named instead of as an opaque Mosaic allocation error inside Pallas.

    ``mesh``/``batch_axis`` configure the shard-mapped multi-device batch
    layer (``sharding/batch.py``): the ``"sharded"`` compressor/decoder pair
    partitions the B dimension of the batched entry points over the named
    mesh axis (or axes; default: the logical batch axes from
    ``sharding/rules.py``) and runs the platform-default backend per shard.
    Only those registry entries consult ``mesh`` — setting it with any other
    backend/decoder would be silently ignored, so it is rejected here.
    """

    symbol_size: int = 2  # S in {1, 2, 4}
    window: int = 128  # W in [1, 255]; levels 1-4 = 32/64/128/255
    chunk_symbols: int = 2048  # C; VMEM-resident chunk
    chunks_per_block: object = None  # g; None = autotune (core/autotune.py)
    backend: str = "xla"  # registry key, see available_backends()
    decoder: str = "auto"  # registry key, see available_decoders()
    mesh: object = None  # jax.sharding.Mesh for "sharded" entries
    batch_axis: object = None  # axis name (or tuple) carrying B; None=auto
    lossy_eb: object = None  # error bound for backend="lossy-fz" (0=lossless)
    lossy_inner: str = "auto"  # lossless stage inside a lossy-fz container

    def __post_init__(self):
        if self.symbol_size not in (1, 2, 4):
            raise ValueError(f"symbol_size must be 1, 2 or 4: {self.symbol_size}")
        if not 1 <= self.window <= 255:
            raise ValueError(f"window must be in [1, 255]: {self.window}")
        if self.chunk_symbols % 8:
            raise ValueError("chunk_symbols must be a multiple of 8")
        # VMEM block-fit check: chunks_per_block=None is validated against
        # the deterministic fallback geometry (the autotuner's candidate
        # filter enforces the same budget on anything it would pick later).
        autotune.validate_block_geometry(
            self.chunk_symbols,
            self.chunks_per_block
            if self.chunks_per_block is not None
            else autotune.DEFAULT_CHUNKS_PER_BLOCK,
            self.symbol_size,
        )
        if self.backend != "auto" and self.backend not in _BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; "
                f"registered: {available_backends()} (also accepted: 'auto')"
            )
        object.__setattr__(
            self, "decoder", _DECODER_ALIASES.get(self.decoder, self.decoder)
        )
        if self.decoder != "auto" and self.decoder not in _DECODERS:
            raise ValueError(
                f"unknown decoder {self.decoder!r}; "
                f"registered: {available_decoders()} "
                f"(also accepted: 'auto', {sorted(_DECODER_ALIASES)})"
            )
        # the entropy pair is a container *format*, not just an execution
        # strategy: method-1 containers decode only through their own
        # decoder, so pin the pairing here instead of failing at dispatch
        if self.backend == "deflate-full" and self.decoder == "auto":
            object.__setattr__(self, "decoder", "deflate-full")
        if self.decoder == "deflate-full" and self.backend != "deflate-full":
            raise ValueError(
                "decoder='deflate-full' decodes method-1 (entropy) containers "
                "only; pair it with backend='deflate-full'"
            )
        # the lossy pair is likewise a container format: method-2 blobs
        # decode only through their own decoder, and the error bound is
        # part of the config contract, not an optional knob
        if self.backend == "lossy-fz":
            if self.symbol_size != 4:
                raise ValueError(
                    "backend='lossy-fz' quantizes f32 elements: "
                    f"symbol_size must be 4, got {self.symbol_size}"
                )
            eb = self.lossy_eb
            if eb is None or not isinstance(eb, (int, float)):
                raise ValueError(
                    "backend='lossy-fz' requires lossy_eb=<float error "
                    "bound> (0.0 selects the bit-exact lossless mode)"
                )
            if not math.isfinite(eb) or eb < 0:
                raise ValueError(
                    f"lossy_eb must be a finite bound >= 0: {eb}"
                )
            object.__setattr__(self, "lossy_eb", float(eb))
            inner = resolve_backend(self.lossy_inner)
            if container_method(inner) == fmt.METHOD_LOSSY:
                raise ValueError(
                    f"lossy_inner={self.lossy_inner!r} is not a lossless "
                    "stage; pick a raw or deflate-full backend"
                )
            if self.decoder == "auto":
                object.__setattr__(self, "decoder", "lossy-fz")
        elif self.lossy_eb is not None:
            raise ValueError(
                f"lossy_eb is only consulted by backend='lossy-fz' "
                f"(got backend={self.backend!r})"
            )
        if self.decoder == "lossy-fz" and self.backend != "lossy-fz":
            raise ValueError(
                "decoder='lossy-fz' decodes method-2 (lossy) containers "
                "only; pair it with backend='lossy-fz'"
            )
        if isinstance(self.batch_axis, list):
            # jit static-arg hashability: axis collections must be tuples
            object.__setattr__(self, "batch_axis", tuple(self.batch_axis))
        if self.mesh is None:
            if self.batch_axis is not None:
                raise ValueError("batch_axis requires mesh=...")
            return
        if (
            self.backend not in ("sharded", "deflate-full", "lossy-fz")
            and self.decoder != "sharded"
        ):
            raise ValueError(
                "mesh=... is only consulted by the 'sharded' compressor/"
                "decoder and the batched 'deflate-full'/'lossy-fz' "
                "dispatches; set backend='sharded'/'deflate-full'/'lossy-fz' "
                "and/or decoder='sharded'"
            )
        if self.batch_axis is not None:
            # single source of truth for axis validation (same check the
            # runner applies at dispatch); lazy import to avoid a cycle
            from repro.sharding import batch as shbatch

            shbatch.normalize_batch_axes(self.mesh, self.batch_axis)

    @property
    def min_match(self) -> int:
        return encode.min_match_length(self.symbol_size)


# ------------------------------------------------------------- backends


class CompressorBackend(Protocol):
    """Kernel-I contract: match + select + local prefix sum for all chunks.

    ``kernel1`` maps (nc, C) int32 symbols to a dict (``Kernel1Result``):

      lengths, offsets   (nc, C) int32  best match per position
      emitted            (nc, C) bool   token emitted at this position
      use_match          (nc, C) bool   emitted token is a pointer
      sizes              (nc, C) int32  encoded bytes at this position
      local_off          (nc, C) int32  exclusive prefix sum of sizes
      payload_sizes      (nc,)   int32  compressed payload bytes per chunk
      n_tokens           (nc,)   int32  tokens per chunk (= flag bits)

    A backend may additionally define ``emit(symbols, k1, cfg, orig_bytes)``
    -> ``(buffer u8[cap], total_bytes)`` to own the Kernel-II/III tail
    (global prefix sums + deflate-scatter + header); ``compress_chunks``
    falls back to the shared XLA tail ``emit_xla`` when absent, so
    Kernel-I-only backends keep working unchanged.

    A backend that fuses the *entire* pipeline (there is no Kernel-I/emit
    seam left to split at) may instead define
    ``compress(symbols, cfg, orig_bytes)`` -> ``(buffer u8[cap],
    total_bytes)`` and own the whole single-buffer path — checked before
    ``kernel1``/``emit`` by ``compress_chunks``.  ``fused-mono`` is the
    canonical user.
    """

    name: str

    def kernel1(self, symbols: jnp.ndarray, cfg: LZSSConfig) -> dict: ...


Kernel1Result = Dict[str, jnp.ndarray]

_BACKENDS: Dict[str, CompressorBackend] = {}


def register_backend(
    backend: CompressorBackend, *, overwrite: bool = False
) -> CompressorBackend:
    """Register a backend *instance* under ``backend.name``.

    Duplicate names raise unless ``overwrite=True`` — silently replacing a
    registered backend was an easy way to corrupt a pipeline another module
    had already configured.  Caveat when overwriting: ``compress_chunks``
    jit-caches on the config (which carries only the backend *name*), so
    replacing a backend in place does not invalidate already-traced calls —
    call ``jax.clear_caches()`` after, or register under a fresh name.
    """
    if backend.name in _BACKENDS and not overwrite:
        raise ValueError(
            f"backend {backend.name!r} already registered; "
            f"pass overwrite=True to replace it"
        )
    _BACKENDS[backend.name] = backend
    return backend


def resolve_backend(name: str) -> str:
    """Normalize a backend selector to a registered key.

    Accepts registry keys and ``auto`` (the single-kernel ``fused-mono``
    compressor on TPU, xla elsewhere) — the compress-side mirror of
    ``resolve_decoder``.
    """
    if name == "auto":
        name = default_backend()
    if name not in _BACKENDS:
        raise ValueError(
            f"unknown backend {name!r}; registered: {available_backends()} "
            f"(also accepted: 'auto')"
        )
    return name


def get_backend(name: str) -> CompressorBackend:
    return _BACKENDS[resolve_backend(name)]


def available_backends() -> list:
    return sorted(_BACKENDS)


def _derive_fields(lengths, emitted, use_match, *, symbol_size):
    """The per-position byte sizes implied by a selection."""
    return jnp.where(emitted, jnp.where(use_match, 2, symbol_size), 0).astype(
        jnp.int32
    )


class _XlaBackendBase:
    """Unfused XLA path: matching, selection and prefix sums as separate ops
    staged through HBM — the paper's workflow-(c) baseline."""

    selector = staticmethod(encode.select_tokens_doubling)

    def _matches(self, symbols, cfg):
        return match.find_matches(symbols, window=cfg.window)

    def kernel1(self, symbols, cfg):
        lengths, offsets = self._matches(symbols, cfg)
        emitted = self.selector(lengths, min_match=cfg.min_match)
        fields = encode.token_fields(
            lengths, emitted, min_match=cfg.min_match, symbol_size=cfg.symbol_size
        )
        return dict(lengths=lengths, offsets=offsets, emitted=emitted, **fields)


class XlaBackend(_XlaBackendBase):
    name = "xla"


class XlaScanBackend(_XlaBackendBase):
    """Paper-faithful sequential selection walk (equivalence oracle)."""

    name = "xla-scan"
    selector = staticmethod(encode.select_tokens_scan)


class PallasMatchBackend(_XlaBackendBase):
    """Pallas matching kernel + unfused XLA select/prefix sums."""

    name = "pallas-match"

    def _matches(self, symbols, cfg):
        from repro.kernels import ops  # lazy: kernels are optional at import

        return ops.lz_match(
            symbols, window=cfg.window, chunks_per_block=cfg.chunks_per_block
        )


class FusedBackend:
    """Fused Pallas Kernel I: selection and the local prefix sum stay in
    VMEM with the match intermediates; only the final token metadata is
    written back.  Skips ``encode.select_tokens_*`` and the cumsum in
    ``encode.token_fields`` entirely.  The emit tail stays XLA."""

    name = "fused"

    def kernel1(self, symbols, cfg):
        from repro.kernels import ops  # lazy: kernels are optional at import

        out = ops.lz_kernel1(
            symbols,
            window=cfg.window,
            min_match=cfg.min_match,
            symbol_size=cfg.symbol_size,
            chunks_per_block=cfg.chunks_per_block,
        )
        use_match = out["emitted"] & (out["lengths"] >= cfg.min_match)
        sizes = _derive_fields(
            out["lengths"], out["emitted"], use_match, symbol_size=cfg.symbol_size
        )
        return dict(out, use_match=use_match, sizes=sizes)


class FusedDeflateBackend(FusedBackend):
    """Workflow (d) end to end: fused Kernel I plus the fused Kernel II+III
    (kernels/lz_scatter.py).  One kernel computes both global exclusive
    prefix sums; a second rebuilds the flag/payload sections in VMEM per
    chunk block and scatters each chunk's compact prefix into the blob at
    scalar-prefetched per-chunk offsets — the aligned (nc, C//8)/(nc, C*S)
    section arrays of the XLA tail never materialize in HBM."""

    name = "fused-deflate"

    def emit(self, symbols, k1, cfg, orig_bytes=None):
        from repro.kernels import ops  # lazy: kernels are optional at import

        nc, c = symbols.shape
        s = cfg.symbol_size
        out, flag_total, pay_total = ops.lz_scatter(
            symbols,
            k1["lengths"],
            k1["offsets"],
            k1["emitted"],
            k1["use_match"],
            k1["local_off"],
            k1["n_tokens"],
            k1["payload_sizes"],
            symbol_size=s,
            cap=fmt.max_compressed_bytes(nc * c * s, s, c),
            sec_flags=fmt.HEADER_BYTES + 8 * nc,
            chunks_per_block=cfg.chunks_per_block,
        )
        return _finalize_container(
            out,
            cfg,
            orig_bytes,
            nc=nc,
            c=c,
            n_tokens=k1["n_tokens"],
            payload_sizes=k1["payload_sizes"],
            flag_total=flag_total,
            pay_total=pay_total,
        )


class FusedMonoBackend(FusedBackend):
    """The whole compressor in ONE Pallas kernel (kernels/lz_fused.py):
    Kernel I per chunk block, both global prefix sums as an SMEM carry over
    the sequential grid, section rebuild in VMEM and the blob scatter
    through per-chunk DMA windows into an HBM-resident buffer.  Nothing —
    not even the (nc, C) Kernel-I outputs — round-trips through HBM, and
    the output is tiled, so containers are not bounded by VMEM.

    Owns the full single-buffer path via the ``compress`` hook; the
    inherited ``kernel1`` (fused Kernel I) exists only for callers that
    want the match metadata by itself."""

    name = "fused-mono"

    def compress(self, symbols, cfg, orig_bytes=None):
        from repro.kernels import ops  # lazy: kernels are optional at import

        nc, c = symbols.shape
        s = cfg.symbol_size
        out, n_tokens, payload_sizes, flag_total, pay_total = ops.lz_fused_mono(
            symbols,
            window=cfg.window,
            min_match=cfg.min_match,
            symbol_size=s,
            cap=fmt.max_compressed_bytes(nc * c * s, s, c),
            sec_flags=fmt.HEADER_BYTES + 8 * nc,
            chunks_per_block=cfg.chunks_per_block,
        )
        return _finalize_container(
            out,
            cfg,
            orig_bytes,
            nc=nc,
            c=c,
            n_tokens=n_tokens,
            payload_sizes=payload_sizes,
            flag_total=flag_total,
            pay_total=pay_total,
        )


class ShardedCompressor:
    """Shard-mapped multi-device batch execution (``sharding/batch.py``).

    The batched entry point dispatches here via the optional
    ``compress_many`` hook: the B dimension is partitioned over
    ``cfg.mesh``'s batch axis and every shard runs the auto-resolved
    platform backend — byte-identical to the single-device dispatch by
    construction.  Single-buffer calls (``compress_chunks``) and
    ``mesh=None`` degenerate to the platform backend directly.
    """

    name = "sharded"

    def kernel1(self, symbols, cfg):
        return get_backend("auto").kernel1(symbols, cfg)

    def compress(self, symbols, cfg, orig_bytes=None):
        return _compress_via(get_backend("auto"), symbols, cfg, orig_bytes)

    def compress_many(self, symbols, cfg, orig_bytes):
        from repro.sharding import batch as shbatch  # lazy: avoid cycle

        runner = shbatch.ShardedBatchRunner(cfg.mesh, cfg.batch_axis)
        return runner.compress_many(symbols, cfg, orig_bytes)


class EntropyBackend:
    """Entropy-coded container (core/entropy.py): platform LZSS + canonical
    Huffman over both sections, emitted as a method-1 VERSION-2 container
    with gap-array parallel entry points.  The only backend whose container
    bytes differ from the raw family (``container_method`` marks it);
    ``compress_many`` honors ``cfg.mesh`` so batched entropy compression
    shards exactly like the ``"sharded"`` entry."""

    name = "deflate-full"
    container_method = fmt.METHOD_HUFFMAN

    def kernel1(self, symbols, cfg):
        # the LZSS stage is the platform pipeline; entropy is emit-side only
        return get_backend("auto").kernel1(symbols, cfg)

    def compress(self, symbols, cfg, orig_bytes=None):
        from repro.core import entropy  # lazy: entropy imports this module

        return entropy.compress_entropy(symbols, cfg, orig_bytes)

    def compress_many(self, symbols, cfg, orig_bytes):
        if cfg.mesh is not None:
            from repro.sharding import batch as shbatch  # lazy: avoid cycle

            runner = shbatch.ShardedBatchRunner(cfg.mesh, cfg.batch_axis)
            return runner.compress_many(symbols, cfg, orig_bytes)
        return jax.vmap(lambda s_, o_: compress_chunks(s_, cfg, o_))(
            symbols, orig_bytes
        )


class LossyFzBackend:
    """Error-bounded lossy container (core/lossy.py): cuSZ dual-quant ->
    bitshuffle -> the ``cfg.lossy_inner`` lossless stage, emitted as a
    method-2 container carrying the error bound + exact outlier pairs.
    ``lossy_eb == 0`` selects the bit-exact lossless passthrough mode.
    ``compress_many`` honors ``cfg.mesh`` exactly like the entropy entry."""

    name = "lossy-fz"
    container_method = fmt.METHOD_LOSSY

    def kernel1(self, symbols, cfg):
        # the inner LZSS stage is the platform pipeline; the lossy
        # transform wraps it container-level, not kernel-level
        return get_backend("auto").kernel1(symbols, cfg)

    def compress(self, symbols, cfg, orig_bytes=None):
        from repro.core import lossy  # lazy: lossy imports this module

        return lossy.compress_lossy(symbols, cfg, orig_bytes)

    def compress_many(self, symbols, cfg, orig_bytes):
        if cfg.mesh is not None:
            from repro.sharding import batch as shbatch  # lazy: avoid cycle

            runner = shbatch.ShardedBatchRunner(cfg.mesh, cfg.batch_axis)
            return runner.compress_many(symbols, cfg, orig_bytes)
        return jax.vmap(lambda s_, o_: compress_chunks(s_, cfg, o_))(
            symbols, orig_bytes
        )


register_backend(XlaBackend())
register_backend(XlaScanBackend())
register_backend(PallasMatchBackend())
register_backend(FusedBackend())
register_backend(FusedDeflateBackend())
register_backend(FusedMonoBackend())
register_backend(ShardedCompressor())
register_backend(EntropyBackend())
register_backend(LossyFzBackend())


def container_method(name: str) -> int:
    """The container method a registry entry produces/consumes.

    ``fmt.METHOD_RAW`` for the byte-identical LZSS family,
    ``fmt.METHOD_HUFFMAN`` for the entropy pair — looked up on the
    registered instance (``container_method`` attribute, default raw), so
    tests and benchmarks can pair compressors with decoders generically
    instead of name-matching.  Works for both registries (backend names
    win on collisions only in the sense that methods agree by design).
    """
    entry = _BACKENDS.get(name) or _DECODERS.get(name)
    if entry is None:
        # not a direct key: accept the same selectors the registries do
        # ("auto", legacy decoder aliases) before giving up
        for resolve, table in (
            (resolve_backend, _BACKENDS),
            (resolve_decoder, _DECODERS),
        ):
            try:
                entry = table[resolve(name)]
                break
            except ValueError:
                continue
        else:
            raise ValueError(f"unknown backend/decoder {name!r}")
    return getattr(entry, "container_method", fmt.METHOD_RAW)


# ------------------------------------------------------------- decoders


class DecoderBackend(Protocol):
    """Decode contract: per-chunk aligned sections -> symbols.

    ``decode`` maps the (nc, C//8) int32 flag bytes, (nc, C*S) int32 payload
    bytes and (nc,) int32 token counts (the arrays ``deflate.gather_section``
    rebuilds from a container) to (nc, C) int32 symbols.
    ``chunks_per_block`` pins the kernel block geometry for decoders that
    tile (the Pallas entries); ``None`` defers to the autotuner, and the
    XLA decoders ignore it — it is format-invisible either way.  The kwarg
    is forwarded only to hooks that accept it (``_geometry_kw``), so
    decoders registered against the pre-pin signature keep working.

    A decoder that fuses the section gathers into its kernel may instead
    define ``decode_blob(blob, n_tokens, payload_sizes, *, symbol_size,
    chunk_symbols, n_chunks)`` -> (nc, C) symbols and own the whole
    container->symbols path — checked before the gather+``decode`` split by
    ``decompress_chunks`` (the decode mirror of the compressor's
    ``compress`` hook).  ``fused-mono`` is the canonical user.
    """

    name: str

    def decode(
        self,
        flag_bytes: jnp.ndarray,
        payload: jnp.ndarray,
        n_tokens: jnp.ndarray,
        *,
        symbol_size: int,
        chunks_per_block=None,
    ) -> jnp.ndarray: ...


_DECODERS: Dict[str, DecoderBackend] = {}

# Legacy LZSSConfig.decoder values from before the registry existed.
_DECODER_ALIASES = {"parallel": "xla-parallel", "scan": "xla-scan"}


def register_decoder(
    decoder: DecoderBackend, *, overwrite: bool = False
) -> DecoderBackend:
    """Register a decoder *instance* under ``decoder.name``.

    Duplicate names raise unless ``overwrite=True``, mirroring
    ``register_backend``.  Same jit-cache caveat when overwriting:
    ``decompress_chunks`` caches on the decoder *name*, so replacing a
    registered decoder in place requires ``jax.clear_caches()`` (or a
    fresh name).
    """
    if decoder.name in _DECODERS and not overwrite:
        raise ValueError(
            f"decoder {decoder.name!r} already registered; "
            f"pass overwrite=True to replace it"
        )
    _DECODERS[decoder.name] = decoder
    return decoder


def resolve_decoder(name: str) -> str:
    """Normalize a decoder selector to a registered key.

    Accepts registry keys, the legacy aliases ``parallel``/``scan`` and
    ``auto`` (the single-launch ``fused-mono`` decoder on TPU, xla-parallel
    elsewhere).
    """
    name = _DECODER_ALIASES.get(name, name)
    if name == "auto":
        name = default_decoder()
    if name not in _DECODERS:
        raise ValueError(
            f"unknown decoder {name!r}; registered: {available_decoders()} "
            f"(also accepted: 'auto', {sorted(_DECODER_ALIASES)})"
        )
    return name


def get_decoder(name: str) -> DecoderBackend:
    return _DECODERS[resolve_decoder(name)]


def available_decoders() -> list:
    return sorted(_DECODERS)


class XlaParallelDecoder:
    """Beyond-paper fully parallel XLA decoder (two prefix sums + pointer
    doubling as separate XLA ops — see core/decode.py)."""

    name = "xla-parallel"
    uses_block_geometry = False  # pure XLA: no Pallas tiling to pin/tune

    def decode(
        self, flag_bytes, payload, n_tokens, *, symbol_size, chunks_per_block=None
    ):
        return decode_mod.decode_parallel(
            flag_bytes, payload, n_tokens, symbol_size=symbol_size
        )


class XlaScanDecoder:
    """Paper-faithful sequential token walk (equivalence oracle)."""

    name = "xla-scan"
    uses_block_geometry = False  # pure XLA: no Pallas tiling to pin/tune

    def decode(
        self, flag_bytes, payload, n_tokens, *, symbol_size, chunks_per_block=None
    ):
        return decode_mod.decode_scan(
            flag_bytes, payload, n_tokens, symbol_size=symbol_size
        )


class FusedDecoder:
    """Fused Pallas decoder (kernels/lz_decode.py): flag extraction, the two
    read/write prefix sums, payload gather and pointer-doubling copy
    resolution stay in VMEM per chunk block; decoded symbols are written to
    HBM exactly once."""

    name = "fused"

    def decode(
        self, flag_bytes, payload, n_tokens, *, symbol_size, chunks_per_block=None
    ):
        from repro.kernels import ops  # lazy: kernels are optional at import

        return ops.lz_decode(
            flag_bytes,
            payload,
            n_tokens,
            symbol_size=symbol_size,
            chunks_per_block=chunks_per_block,
        )


class FusedMonoDecoder:
    """Single-launch decoder (kernels/lz_decode_mono.py): the container blob
    stays HBM-resident (``memory_space=ANY``) and each grid step DMAs its
    chunks' flag/payload windows straight into VMEM at scalar-prefetched
    section offsets before running the fused decode chain — the gathers fuse
    into the kernel, so ``deflate.gather_section`` never runs and decode is
    exactly ONE Pallas launch.

    Owns the whole container->symbols path via the ``decode_blob`` hook;
    the section-level ``decode`` (for callers that already gathered the
    sections, e.g. a custom pipeline tail) delegates to the split fused
    kernel — identical symbols either way."""

    name = "fused-mono"

    def decode(
        self, flag_bytes, payload, n_tokens, *, symbol_size, chunks_per_block=None
    ):
        from repro.kernels import ops  # lazy: kernels are optional at import

        return ops.lz_decode(
            flag_bytes,
            payload,
            n_tokens,
            symbol_size=symbol_size,
            chunks_per_block=chunks_per_block,
        )

    def decode_blob(
        self,
        blob,
        n_tokens,
        payload_sizes,
        *,
        symbol_size,
        chunk_symbols,
        n_chunks,
        chunks_per_block=None,
    ):
        from repro.kernels import ops  # lazy: kernels are optional at import

        return ops.lz_decode_mono(
            blob,
            n_tokens,
            payload_sizes,
            symbol_size=symbol_size,
            chunk_symbols=chunk_symbols,
            n_chunks=n_chunks,
            chunks_per_block=chunks_per_block,
        )


class ShardedDecoder:
    """Decode-side mirror of ``ShardedCompressor``: the batched entry point
    dispatches through the optional ``decompress_many`` hook, which shards
    the B dimension over the mesh passed at dispatch and runs the platform
    decoder per shard.  Per-chunk ``decode`` calls (and ``mesh=None``)
    degenerate to the platform decoder directly."""

    name = "sharded"

    def decode(
        self, flag_bytes, payload, n_tokens, *, symbol_size, chunks_per_block=None
    ):
        return get_decoder("auto").decode(
            flag_bytes,
            payload,
            n_tokens,
            symbol_size=symbol_size,
            chunks_per_block=chunks_per_block,
        )

    def decompress_many(
        self,
        blobs,
        n_tokens,
        payload_sizes,
        *,
        symbol_size,
        chunk_symbols,
        n_chunks,
        chunks_per_block,
        mesh,
        batch_axis,
        inner_decoder=None,
        method_params=(),
    ):
        from repro.sharding import batch as shbatch  # lazy: avoid cycle

        runner = shbatch.ShardedBatchRunner(mesh, batch_axis)
        return runner.decompress_many(
            blobs,
            n_tokens,
            payload_sizes,
            symbol_size=symbol_size,
            chunk_symbols=chunk_symbols,
            n_chunks=n_chunks,
            chunks_per_block=chunks_per_block,
            decoder="auto" if inner_decoder is None else inner_decoder,
            method_params=method_params,
        )


class EntropyDecoder:
    """Decoder for method-1 (entropy) containers: gap-array parallel Huffman
    bitstream decode (core/entropy.py) rebuilds the raw flag/payload
    sections, then the platform LZSS decode chain finishes.  Owns the whole
    container->symbols path via ``decode_blob``; the section-level
    ``decode`` (sections already un-entropied by definition) delegates to
    the platform decoder."""

    name = "deflate-full"
    container_method = fmt.METHOD_HUFFMAN

    def decode(
        self, flag_bytes, payload, n_tokens, *, symbol_size, chunks_per_block=None
    ):
        dec = get_decoder("auto")
        return dec.decode(
            flag_bytes,
            payload,
            n_tokens,
            symbol_size=symbol_size,
            **_geometry_kw(dec.decode, chunks_per_block),
        )

    def decode_blob(
        self,
        blob,
        n_tokens,
        payload_sizes,
        *,
        symbol_size,
        chunk_symbols,
        n_chunks,
        chunks_per_block=None,
    ):
        from repro.core import entropy  # lazy: entropy imports this module

        return entropy.decode_blob_entropy(
            blob,
            n_tokens,
            payload_sizes,
            symbol_size=symbol_size,
            chunk_symbols=chunk_symbols,
            n_chunks=n_chunks,
            chunks_per_block=chunks_per_block,
        )


class LossyFzDecoder:
    """Decoder for method-2 (lossy) containers (core/lossy.py): inner
    lossless decode -> bit-plane untranspose -> Lorenzo reconstruction +
    exact-outlier overlay.  Owns the whole container->symbols path via
    ``decode_blob``; the static ``(mode, inner_method)`` pair — trace-shape
    relevant but stored in the container — arrives through the
    ``method_params`` pin, recovered host-side from the header by
    ``static_params`` (see lzss.decompress)."""

    name = "lossy-fz"
    container_method = fmt.METHOD_LOSSY

    def static_params(self, header):
        from repro.core import lossy  # lazy: lossy imports this module

        return lossy.static_params(header)

    def decode(
        self, flag_bytes, payload, n_tokens, *, symbol_size, chunks_per_block=None
    ):
        raise ValueError(
            "lossy-fz containers (method byte 2) have no flag/payload "
            "sections; decode them through decode_blob (lzss.decompress)"
        )

    def decode_blob(
        self,
        blob,
        n_tokens,
        payload_sizes,
        *,
        symbol_size,
        chunk_symbols,
        n_chunks,
        chunks_per_block=None,
        method_params=(),
    ):
        from repro.core import lossy  # lazy: lossy imports this module

        if symbol_size != 4:
            raise ValueError(
                "lossy-fz containers hold f32 element streams "
                f"(symbol_size=4); got symbol_size={symbol_size}"
            )
        if len(method_params) != 2:
            raise ValueError(
                "lossy-fz decode requires method_params=(mode, inner_method) "
                "recovered from the container header; decode through "
                "lzss.decompress, or pass method_params explicitly"
            )
        mode, inner_method = method_params
        return lossy.decode_blob_lossy(
            blob,
            chunk_symbols=chunk_symbols,
            n_chunks=n_chunks,
            mode=mode,
            inner_method=inner_method,
        )


register_decoder(XlaParallelDecoder())
register_decoder(XlaScanDecoder())
register_decoder(FusedDecoder())
register_decoder(FusedMonoDecoder())
register_decoder(ShardedDecoder())
register_decoder(EntropyDecoder())
register_decoder(LossyFzDecoder())


# ------------------------------------------------------- symbol packing


def pack_symbols(data: jnp.ndarray, symbol_size: int) -> jnp.ndarray:
    """(n_bytes,) uint8 -> (n_sym,) int32 little-endian symbols (n_bytes % S == 0)."""
    d = data.reshape(-1, symbol_size).astype(jnp.int32)
    sym = d[:, 0]
    for b in range(1, symbol_size):
        sym = sym | (d[:, b] << (8 * b))
    return sym


def unpack_symbols(symbols: jnp.ndarray, symbol_size: int) -> jnp.ndarray:
    """(n_sym,) int32 -> (n_sym * S,) uint8 little-endian."""
    cols = [((symbols >> (8 * b)) & 0xFF) for b in range(symbol_size)]
    return jnp.stack(cols, axis=-1).reshape(-1).astype(jnp.uint8)


def _geometry_kw(method, chunks_per_block) -> dict:
    """kwargs forwarding the decode-side geometry pin to a decoder hook.

    The registry is an extension point: decoders registered before
    ``chunks_per_block`` reached the decode path don't take the kwarg, and
    must keep working.  The pin is forwarded only when the hook accepts it
    (explicitly or via ``**kwargs``); a decoder without the parameter never
    tiled on it anyway.  Runs at trace time only.
    """
    params = inspect.signature(method).parameters
    accepts = "chunks_per_block" in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()
    )
    return {"chunks_per_block": chunks_per_block} if accepts else {}


def _optional_kw(method, **kv) -> dict:
    """Forward each kwarg only if ``method`` accepts it — the general form
    of ``_geometry_kw`` for registry hooks that predate newer pins
    (``chunks_per_block``, ``method_params``, ...).  Runs at trace time
    only."""
    params = inspect.signature(method).parameters
    var_kw = any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()
    )
    return {k: v for k, v in kv.items() if var_kw or k in params}


# ------------------------------------------------------- jittable cores


def _finalize_container(
    out, cfg, orig_bytes, *, nc, c, n_tokens, payload_sizes, flag_total, pay_total
):
    """Write header + A/B tables into a section-filled byte buffer.

    ``out`` is a (cap,) int32 buffer whose flag/payload sections are already
    in place and whose header/table region [0, HEADER_BYTES + 8*nc) carries
    no live bytes — every emit tail produces exactly that.  Returns the
    finished ``(buffer u8, total_bytes)``.
    """
    s = cfg.symbol_size
    out = fmt.write_header_and_tables(
        out,
        symbol_size=s,
        window=cfg.window,
        chunk_symbols=c,
        n_chunks=nc,
        orig_bytes=nc * c * s if orig_bytes is None else orig_bytes,
        payload_total=pay_total,
        flag_total=flag_total,
        n_tokens=n_tokens,
        payload_sizes=payload_sizes,
    )
    total = fmt.HEADER_BYTES + 8 * nc + flag_total + pay_total
    return out.astype(jnp.uint8), total


def emit_xla(symbols, k1, cfg, orig_bytes=None):
    """Shared workflow-(c) emit tail: Kernels II+III as separate XLA ops.

    Packs flags and builds per-chunk payload buffers in HBM
    (``deflate.pack_flags`` / ``build_chunk_payloads``), runs the two global
    exclusive prefix sums (``deflate.global_offsets``, Kernel II), and
    scatters both sections into the container (``deflate.scatter_section``,
    Kernel III).  Backends without their own ``emit`` use this tail.
    """
    nc, c = symbols.shape
    s = cfg.symbol_size
    flag_bytes, flag_sizes = deflate.pack_flags(
        k1["emitted"], k1["use_match"], n_tokens=k1["n_tokens"]
    )
    payload = deflate.build_chunk_payloads(
        symbols, k1["lengths"], k1["offsets"], k1, symbol_size=s
    )
    pay_off, pay_total, flag_off, flag_total = deflate.global_offsets(
        k1["payload_sizes"], flag_sizes
    )
    cap = fmt.max_compressed_bytes(nc * c * s, s, c)
    sec_flags = fmt.HEADER_BYTES + 8 * nc
    out = jnp.zeros((cap,), jnp.int32)
    out = deflate.scatter_section(out, sec_flags, flag_bytes, flag_sizes, flag_off)
    out = deflate.scatter_section(
        out, sec_flags + flag_total, payload, k1["payload_sizes"], pay_off
    )
    return _finalize_container(
        out,
        cfg,
        orig_bytes,
        nc=nc,
        c=c,
        n_tokens=k1["n_tokens"],
        payload_sizes=k1["payload_sizes"],
        flag_total=flag_total,
        pay_total=pay_total,
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def compress_chunks(symbols: jnp.ndarray, cfg: LZSSConfig, orig_bytes=None):
    """Jittable core: (nc, C) int32 symbols -> (buffer u8[cap], total_bytes).

    The buffer holds a complete container (header + tables + flags + payload);
    bytes past ``total_bytes`` are zero.  ``orig_bytes`` (scalar, may be
    traced) is the true pre-padding byte count recorded in the header; when
    omitted the padded size ``nc * C * S`` is recorded.

    Both pipeline stages dispatch through the backend registry: Kernel I via
    ``backend.kernel1`` and the emit tail (Kernels II+III + header) via the
    backend's optional ``emit`` method, defaulting to the shared XLA tail
    ``emit_xla``.  A backend with no Kernel-I/emit seam (the single-kernel
    ``fused-mono``) owns the whole path via the optional ``compress`` hook
    instead.
    """
    return _compress_via(get_backend(cfg.backend), symbols, cfg, orig_bytes)


def _compress_via(backend, symbols, cfg, orig_bytes=None):
    """Run one backend's single-buffer pipeline, honoring its hooks."""
    whole = getattr(backend, "compress", None)
    if whole is not None:
        return whole(symbols, cfg, orig_bytes)
    k1 = backend.kernel1(symbols, cfg)
    emit = getattr(backend, "emit", emit_xla)
    return emit(symbols, k1, cfg, orig_bytes)


@functools.partial(
    jax.jit,
    static_argnames=(
        "symbol_size",
        "chunk_symbols",
        "n_chunks",
        "decoder",
        "chunks_per_block",
        "method_params",
    ),
)
def decompress_chunks(
    blob,
    n_tokens,
    payload_sizes,
    *,
    symbol_size,
    chunk_symbols,
    n_chunks,
    decoder="auto",
    chunks_per_block=None,
    method_params=(),
):
    """Jittable core: container bytes -> (nc, C) int32 symbols.

    ``blob`` may be any buffer that covers the container's live bytes — the
    section gathers are bounds-checked (clipped + masked), so no worst-case
    zero padding is required.  ``decoder`` is a registry key (or ``"auto"`` /
    a legacy alias), dispatched through ``get_decoder``.
    ``chunks_per_block`` pins the decode kernels' block geometry (``None``
    = the autotuner); it is format-invisible, so the pin only changes this
    function's static jit arguments, never the decoded symbols.

    A decoder owning the whole container->symbols path (the single-launch
    ``fused-mono``) is dispatched through its ``decode_blob`` hook here —
    the split gather+decode path below never runs for it.
    ``method_params`` carries static, trace-shape-relevant per-method
    parameters recovered from the container header (the lossy decoder's
    ``(mode, inner_method)``); it is forwarded only to hooks that accept it.
    """
    c, s, nc = chunk_symbols, symbol_size, n_chunks
    dec = get_decoder(decoder)
    whole = getattr(dec, "decode_blob", None)
    if whole is not None:
        return whole(
            blob,
            n_tokens,
            payload_sizes,
            symbol_size=s,
            chunk_symbols=c,
            n_chunks=nc,
            **_optional_kw(
                whole,
                chunks_per_block=chunks_per_block,
                method_params=method_params,
            ),
        )
    blob = blob.astype(jnp.int32)
    flag_sizes = (n_tokens + 7) // 8
    fcsum = jnp.cumsum(flag_sizes)
    pcsum = jnp.cumsum(payload_sizes)
    flag_off = fcsum - flag_sizes
    pay_off = pcsum - payload_sizes
    sec_flags = fmt.HEADER_BYTES + 8 * nc
    flag_bytes = deflate.gather_section(
        blob, sec_flags, flag_sizes, flag_off, (c + 7) // 8
    )
    payload = deflate.gather_section(
        blob, sec_flags + fcsum[-1], payload_sizes, pay_off, c * s
    )
    return dec.decode(
        flag_bytes,
        payload,
        n_tokens,
        symbol_size=s,
        **_geometry_kw(dec.decode, chunks_per_block),
    )


# --------------------------------------------------------- batched cores


@functools.partial(jax.jit, static_argnames=("cfg",))
def compress_many_chunks(symbols: jnp.ndarray, cfg: LZSSConfig, orig_bytes=None):
    """Batched in-graph compression: (B, nc, C) -> ((B, cap) u8, (B,) totals).

    One dispatch compresses B independent buffers; Kernel I runs for all
    B * nc chunks at once (the backend sees a vmapped batch), which is the
    paper's many-buffer scenario (cf. Sitaridi et al.'s massively-parallel
    batch decompression).  ``orig_bytes`` is an optional (B,) int32 vector of
    true per-buffer byte counts for the headers.

    A backend may own the whole batched dispatch via an optional
    ``compress_many`` method (the multi-device ``"sharded"`` entry partitions
    B over a mesh axis this way); the default is the vmapped single-buffer
    core — the same optional-hook pattern as ``emit``.
    """
    if orig_bytes is None:
        b, nc, c = symbols.shape
        orig_bytes = jnp.full((b,), nc * c * cfg.symbol_size, jnp.int32)
    backend = get_backend(cfg.backend)
    many = getattr(backend, "compress_many", None)
    if many is not None:
        return many(symbols, cfg, orig_bytes)
    return jax.vmap(lambda s_, o_: compress_chunks(s_, cfg, o_))(symbols, orig_bytes)


@functools.partial(
    jax.jit,
    static_argnames=(
        "symbol_size",
        "chunk_symbols",
        "n_chunks",
        "decoder",
        "chunks_per_block",
        "mesh",
        "batch_axis",
        "inner_decoder",
        "method_params",
    ),
)
def decompress_many_chunks(
    blobs,
    n_tokens,
    payload_sizes,
    *,
    symbol_size,
    chunk_symbols,
    n_chunks,
    decoder="auto",
    chunks_per_block=None,
    mesh=None,
    batch_axis=None,
    inner_decoder=None,
    method_params=(),
):
    """Batched inverse: (B, L) blobs + (B, nc) tables -> (B, nc, C) symbols.

    A decoder may own the whole batched dispatch via an optional
    ``decompress_many`` method — ``mesh``/``batch_axis`` are forwarded to it
    (the ``"sharded"`` entry partitions B over the mesh axis; other decoders
    never see them).  The default is the vmapped single-buffer core.
    ``chunks_per_block`` pins the decode kernels' block geometry, exactly
    as on ``decompress_chunks``.  ``inner_decoder`` names the per-shard
    decoder a batch-owning hook should run (``None`` = platform default;
    ``lzss.decompress_many`` sets it to ``"deflate-full"`` when a sharded
    batch holds entropy containers) — forwarded only to hooks that accept
    it, so decoders registered against the older hook signature keep
    working.
    """
    dec = get_decoder(decoder)
    many = getattr(dec, "decompress_many", None)
    if many is not None:
        inner_kw = {}
        if inner_decoder is not None:
            inner_kw = _optional_kw(many, inner_decoder=inner_decoder)
        if method_params:
            inner_kw.update(_optional_kw(many, method_params=method_params))
        return many(
            blobs,
            n_tokens,
            payload_sizes,
            symbol_size=symbol_size,
            chunk_symbols=chunk_symbols,
            n_chunks=n_chunks,
            mesh=mesh,
            batch_axis=batch_axis,
            **_geometry_kw(many, chunks_per_block),
            **inner_kw,
        )
    return jax.vmap(
        lambda b_, t_, p_: decompress_chunks(
            b_,
            t_,
            p_,
            symbol_size=symbol_size,
            chunk_symbols=chunk_symbols,
            n_chunks=n_chunks,
            decoder=decoder,
            chunks_per_block=chunks_per_block,
            method_params=method_params,
        )
    )(blobs, n_tokens, payload_sizes)


def tuned_config(symbol_size: int = 2, window: int = 128, **overrides) -> LZSSConfig:
    """An ``LZSSConfig`` with autotuned (chunk_symbols, chunks_per_block).

    Consults ``autotune.tuned_chunk_geometry`` — the joint sweep — for the
    current accelerator; with tuning disabled (CPU default, or
    ``REPRO_AUTOTUNE=0``) this is exactly ``LZSSConfig(...)`` with the
    static defaults.  ``chunk_symbols`` changes container bytes, so use
    this only when *creating* containers, never to reinterpret existing
    ones (their geometry is in the header).  Explicit ``chunk_symbols`` /
    ``chunks_per_block`` overrides win over the tuner.
    """
    c, g = autotune.tuned_chunk_geometry(symbol_size=symbol_size, window=window)
    overrides.setdefault("chunk_symbols", c)
    overrides.setdefault("chunks_per_block", g)
    return LZSSConfig(symbol_size=symbol_size, window=window, **overrides)


DEFAULT_CONFIG = LZSSConfig()  # paper default: C=2048, S=2, W=128

# window "levels" exposed to users (paper §3.2.3: level 1-4 trade ratio/speed)
WINDOW_LEVELS = {1: 32, 2: 64, 3: 128, 4: 255}
