"""Host-side physical block allocation + prefetch bookkeeping for paged KV.

The in-graph side of paging (pool scatter/gather through block tables) lives
in models/attention.py; this module owns the host half: which physical slot
each logical block occupies, how many are resident, and which evicted blocks
to restore ahead of demand.

``BlockPoolAllocator`` hands out the lowest free slot first, so slot
assignment — and with it the whole eviction/restore trace — is a pure
function of the access sequence (same property the tracker's logical clock
gives eviction order).
"""

from __future__ import annotations

import heapq


class BlockPoolAllocator:
    """Fixed-budget physical slot allocator (lowest free slot first)."""

    def __init__(self, budget_blocks: int):
        if budget_blocks < 1:
            raise ValueError(f"budget_blocks must be >= 1, got {budget_blocks}")
        self.budget = budget_blocks
        self._free = list(range(budget_blocks))  # heap
        self._used: set = set()
        self.high_water = 0

    @property
    def allocated(self) -> int:
        return len(self._used)

    @property
    def free_blocks(self) -> int:
        return self.budget - len(self._used)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError(
                f"KV block pool exhausted: budget={self.budget} blocks all "
                "resident (raise budget_blocks or evict first)"
            )
        slot = heapq.heappop(self._free)
        self._used.add(slot)
        self.high_water = max(self.high_water, len(self._used))
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._used:
            raise ValueError(f"double free of physical block {slot}")
        self._used.remove(slot)
        heapq.heappush(self._free, slot)


class PrefetchQueue:
    """Ordered queue of predicted-hot evicted blocks to restore early.

    The serving engine pushes next-in-sequence predictions after each layer
    step and drains the queue into batched restores between steps —
    "async" here is issue-ahead-of-need (restores overlap the python-side
    step loop), not a background thread; the restore dispatch itself is the
    same batched ``decompress_many`` the demand path uses.
    """

    def __init__(self, lookahead: int = 1):
        self.lookahead = lookahead
        self._pending: dict = {}  # ordered set of block keys
        self.issued = 0   # blocks restored by prefetch
        self.hits = 0     # demand accesses served from a prefetched block

    def push(self, key) -> None:
        self._pending[key] = None

    def pop_all(self) -> list:
        keys = list(self._pending)
        self._pending.clear()
        return keys

    def __len__(self):
        return len(self._pending)
