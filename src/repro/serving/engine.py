"""Batched serving engine: prefill-by-decode + greedy generation loop.

Small-scale reference engine over transformer.decode_step: fixed batch of
sequences, per-step greedy sampling, optional KV block offload through
serving/kvcache.py.  When ``kv_offload`` is on, cold blocks (LRU past the
tracker budget) are copied to the host-side block store each eviction round
— every round's blocks compressed in ONE batched GPULZ dispatch
(``KVBlockStore.evict_many``), not one ``compress()`` per block.  The
compiled serve path for roofline purposes is launch/steps.py:make_decode_step;
this engine is the correctness harness and example driver.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.serving.kvcache import KVBlockStore, PagedKVTracker


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray      # (B, T_out)
    steps: int


class ServingEngine:
    def __init__(self, cfg, params, max_len: int = 512, kv_compress=False,
                 kv_offload: bool = False, block_tokens: int = 256,
                 budget_blocks: int = 1024, evict_every: int = 8,
                 kv_decoder: str = "auto", kv_backend: str = "auto",
                 kv_mesh=None, kv_batch_axis=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.kv_offload = kv_offload
        self.evict_every = evict_every
        # kv_backend / kv_decoder: compressor/decoder registry keys for the
        # cold-block eviction and restore dispatches ("auto" = the
        # single-kernel fused-mono pair on TPU: one Pallas launch per
        # direction, restores read the stored blobs straight from HBM).
        # kv_mesh shards each cold-block round's batch dim over a device
        # mesh — KVBlockStore maps "auto" onto the "sharded" registry pair
        # when a mesh is given (see sharding/batch.py).
        self.kv_store = KVBlockStore(compress=kv_compress, backend=kv_backend,
                                     decoder=kv_decoder, mesh=kv_mesh,
                                     batch_axis=kv_batch_axis)
        self.tracker = PagedKVTracker(block_tokens=block_tokens,
                                      budget_blocks=budget_blocks)
        self._step = jax.jit(
            lambda p, c, t, pos: transformer.decode_step(p, cfg, c, t, pos)
        )

    def _offload_cold_blocks(self, caches) -> int:
        """Copy every cold KV block to the store in one batched dispatch."""
        cands = self.tracker.eviction_candidates()
        if not cands:
            return 0
        bt = self.tracker.block_tokens
        items = []
        for sid, blk in cands:
            parts = []
            for layer in caches:
                kv = layer.get("attn")
                if not kv:
                    continue
                for name in ("k", "v"):
                    if name in kv:
                        block = np.asarray(kv[name][sid, blk * bt:(blk + 1) * bt])
                        parts.append(block.reshape(-1).view(np.uint8))
            if parts:
                items.append(((sid, blk), np.concatenate(parts)))
            self.tracker.drop((sid, blk))
        self.kv_store.evict_many(items)
        return len(items)

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32,
                 eos_id: int = -1) -> GenerationResult:
        """prompts: (B, Tp) int32.  Greedy decode."""
        b, tp = prompts.shape
        caches = transformer.init_cache(self.cfg, b, self.max_len)
        toks = jnp.asarray(prompts[:, 0])
        outs = [np.asarray(toks)]
        logits = None
        n_steps = 0
        for pos in range(min(tp + max_new_tokens - 1, self.max_len - 1)):
            logits, caches = self._step(
                self.params, caches, toks, jnp.int32(pos)
            )
            n_steps += 1
            for sid in range(b):
                self.tracker.touch(sid, pos)
            if self.kv_offload and n_steps % self.evict_every == 0:
                self._offload_cold_blocks(caches)
            if pos + 1 < tp:
                toks = jnp.asarray(prompts[:, pos + 1])  # teacher-forced prefill
            else:
                toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            outs.append(np.asarray(toks))
            if eos_id >= 0 and bool(jnp.all(toks == eos_id)):
                break
        return GenerationResult(tokens=np.stack(outs, axis=1), steps=n_steps)
