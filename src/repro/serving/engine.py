"""Batched serving engine: prefill-by-decode + greedy generation loop.

Small-scale reference engine over the per-layer decode launches of
models/transformer.py.  Two KV tiers:

* ``kv_offload=False`` — dense per-sequence caches (reference path).
* ``kv_offload=True``  — the paged capacity tier: K/V lives in a physical
  block pool of exactly ``budget_blocks`` slots, addressed through
  per-(layer, sequence) block tables.  Evicting a cold block GPULZ-
  compresses it into ``KVBlockStore`` (one batched ``evict_many`` dispatch
  per round) AND frees its physical slot; touching an evicted block
  restores it through batched ``decompress_many`` into a freshly allocated
  slot, with a prefetch queue restoring predicted-hot blocks (next access
  group in the layer-major sequence) ahead of demand.

The tier is *layer-streaming*: each decode step launches one jitted graph
per layer, so only the current layer's block working set must be resident
and the budget can sit well below the all-layers working set while staying
exact.  Both tiers drive the SAME per-layer launch granularity — XLA rounds
bf16 intermediates at jit boundaries, so equal granularity makes generated
tokens bit-identical between them (EXPERIMENTS.md §Serving).

With ``async_prefetch=True`` the prefetch restore runs on a background
worker: ``_drain_prefetch`` allocates target slots on the main thread,
hands the ``decompress_many`` dispatch to the worker, and the next access
group's ``_ensure_resident`` is the deterministic barrier that joins the
worker and installs the restored blocks into the pool BEFORE any kernel
reads them — so the decompression overlaps the previous layer's attention
launch while paged-vs-dense stays bit-identical (the pool contents at
every kernel launch are exactly the sync path's).

The compiled single-graph serve paths for roofline purposes are
launch/steps.py:make_decode_step / make_paged_decode_step; this engine is
the correctness harness and example driver.
"""

from __future__ import annotations

import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention, common, ssm, transformer
from repro.serving.kvcache import KVBlockStore, PagedKVTracker
from repro.serving.paging import BlockPoolAllocator, PrefetchQueue


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray      # (B, T_out)
    steps: int


class ServingEngine:
    def __init__(self, cfg, params, max_len: int = 512, kv_compress=False,
                 kv_offload: bool = False, block_tokens: int = 256,
                 budget_blocks: int = 1024,
                 kv_decoder: str = "auto", kv_backend: str = "auto",
                 kv_mesh=None, kv_batch_axis=None,
                 kv_prefetch: bool = True, prefetch_lookahead: int = 1,
                 async_prefetch: bool = False):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.kv_offload = kv_offload
        self.block_tokens = block_tokens
        self.budget_blocks = budget_blocks
        self.kv_prefetch = kv_prefetch
        self.prefetch_lookahead = prefetch_lookahead
        # async_prefetch: run the prefetch restore (decompress_many + host
        # reshape) on a background worker; the next access group's
        # _ensure_resident is the barrier that installs the result before
        # any kernel reads it (bit-identical to the sync path by
        # construction — same blocks, same pool state at every launch)
        self.async_prefetch = async_prefetch
        self._pf_pending = None
        # kv_backend / kv_decoder: compressor/decoder registry keys for the
        # cold-block eviction and restore dispatches ("auto" = the
        # single-kernel fused-mono pair on TPU: one Pallas launch per
        # direction, restores read the stored blobs straight from HBM).
        # kv_mesh shards each cold-block round's batch dim over a device
        # mesh — KVBlockStore maps "auto" onto the "sharded" registry pair
        # when a mesh is given (see sharding/batch.py).
        self.kv_store = KVBlockStore(compress=kv_compress, backend=kv_backend,
                                     decoder=kv_decoder, mesh=kv_mesh,
                                     batch_axis=kv_batch_axis)
        self.tracker = PagedKVTracker(block_tokens=block_tokens,
                                      budget_blocks=budget_blocks)
        if kv_offload:
            if cfg.mixer not in ("attention", "hybrid"):
                raise NotImplementedError(
                    f"paged KV tier supports attention/hybrid mixers, not "
                    f"{cfg.mixer!r}"
                )
            if cfg.kv_quant:
                raise NotImplementedError(
                    "paged KV tier does not support kv_quant"
                )
            if max_len % block_tokens:
                raise ValueError(
                    f"max_len={max_len} not a multiple of "
                    f"block_tokens={block_tokens}"
                )

        ell = cfg.num_layers
        self._layer_params = [transformer._layer_slice(params, i)
                              for i in range(ell)]
        self._is_global = [transformer.layer_is_global(cfg, i)
                           for i in range(ell)]
        self._embed = jax.jit(
            lambda p, t: transformer.decode_embed(p, cfg, t)
        )
        self._finish = jax.jit(
            lambda p, x: transformer.decode_finish(p, cfg, x)
        )
        self._layer_step = jax.jit(
            lambda lp, c, x, pos, g: transformer.decode_layer(
                lp, cfg, c, x, pos, g
            ),
            static_argnums=(4,), donate_argnums=(1,),
        )
        self._paged_layer_step = jax.jit(
            lambda lp, pool, table, extra, x, pos, g:
                transformer.decode_layer_paged(
                    lp, cfg, pool, table, extra, x, pos, g
                ),
            static_argnums=(6,), donate_argnums=(1,),
        )
        # KVBlockStore round-trips flat uint8; the engine owns the real
        # dtype (np.dtype(bf16).str is lossy '<V2', so (dtype.str, shape)
        # meta cannot carry it)
        self._np_kv_dtype = np.asarray(
            jnp.zeros((), common.dtype_of(cfg))
        ).dtype
        self._gen_id = 0
        self._stats = {"demand_restores": 0, "async_prefetch_batches": 0}

    # ------------------------------------------------- paged-tier host side

    def _needed_blocks(self, layer, pos):
        """Logical block ids layer ``layer`` reads/writes at step ``pos``."""
        bt = self.block_tokens
        hi = pos // bt
        lo = 0
        w = self.cfg.sliding_window
        if w and not self._is_global[layer]:
            lo = max(0, pos - w + 1) // bt
        return list(range(lo, hi + 1))

    def _store_key(self, key):
        # generation-counter namespace: keys from a previous generate()
        # can never alias this one's
        return (self._gen_id,) + key

    def _begin_paged(self, batch, horizon):
        cfg = self.cfg
        ell = cfg.num_layers
        self._join_prefetch()  # a stale worker must never outlive its pool
        self._batch = batch
        self._horizon = horizon
        n_logical = -(-horizon // self.block_tokens)
        peak = batch * max(
            len(self._needed_blocks(i, horizon - 1)) for i in range(ell)
        )
        if self.budget_blocks < peak:
            raise ValueError(
                f"budget_blocks={self.budget_blocks} below the peak "
                f"per-layer working set ({peak} blocks for batch={batch}, "
                f"{horizon} positions): exact paged decode impossible"
            )
        dt = common.dtype_of(cfg)
        self._pool = attention.init_paged_kv_pool(
            cfg, self.budget_blocks, self.block_tokens, dt
        )
        self._tables = np.full(
            (ell, batch, max(n_logical, 1)), -1, np.int32
        )
        self._extra = []
        for _ in range(ell):
            e = {}
            if cfg.mixer == "hybrid":
                e["ssm"] = ssm.init_ssm_cache(cfg, batch, dt)
            self._extra.append(e)
        self._alloc = BlockPoolAllocator(self.budget_blocks)
        self._slot = {}          # (layer, sid, blk) -> physical slot
        self._stored = set()     # keys currently compressed in kv_store
        self._prefetched = set()  # restored ahead of demand, not yet touched
        self._retired_upto = {}  # (layer, sid) -> first non-dead SWA block
        self._ever = set()       # every key ever materialized (working set)
        self._pq = PrefetchQueue(lookahead=self.prefetch_lookahead)
        self.tracker = PagedKVTracker(self.block_tokens, self.budget_blocks)
        # static block geometry, captured once so the async worker never
        # reads self._pool (whose buffers the layer step donates)
        bt = self.block_tokens
        kvh, dh = self._pool["k"].shape[2], self._pool["k"].shape[3]
        self._blk_shape = (bt, kvh, dh)
        self._blk_half = bt * kvh * dh * self._np_kv_dtype.itemsize
        self._gen_id += 1
        for k in self.kv_store.keys():  # drop stale-generation blocks
            if isinstance(k, tuple) and len(k) == 4 and k[0] != self._gen_id:
                self.kv_store.discard(k)
        self._stats = {"demand_restores": 0, "async_prefetch_batches": 0}

    def _evict_blocks(self, victims):
        """Compress + free a batch of resident blocks (one dispatch)."""
        if not victims:
            return
        slots = jnp.asarray(np.array([self._slot[k] for k in victims]))
        ks = np.asarray(self._pool["k"][slots])
        vs = np.asarray(self._pool["v"][slots])
        items = []
        for j, key in enumerate(victims):
            blob = np.concatenate([
                ks[j].reshape(-1).view(np.uint8),
                vs[j].reshape(-1).view(np.uint8),
            ])
            items.append((self._store_key(key), blob))
        self.kv_store.evict_many(items)
        for key in victims:
            layer, sid, blk = key
            self._tables[layer, sid, blk] = -1
            self._alloc.free(self._slot.pop(key))
            self._stored.add(key)
            self.tracker.drop(key)
            self._prefetched.discard(key)

    def _stack_blobs(self, blobs):
        """Host-side reshape of restored blobs into K/V stacks.  Reads only
        static geometry (``_blk_shape``/``_blk_half``), so it is safe on
        the async prefetch worker while the main thread owns the pool."""
        half, shape = self._blk_half, self._blk_shape
        kstack = np.stack([
            b[:half].view(self._np_kv_dtype).reshape(shape) for b in blobs
        ])
        vstack = np.stack([
            b[half:].view(self._np_kv_dtype).reshape(shape) for b in blobs
        ])
        return kstack, vstack

    def _install_blocks(self, keys, slots, kstack, vstack, *, prefetch):
        """Scatter restored blocks into their (pre-allocated) slots and
        publish the mapping.  Main thread only."""
        idx = jnp.asarray(np.array(slots))
        self._pool["k"] = self._pool["k"].at[idx].set(jnp.asarray(kstack))
        self._pool["v"] = self._pool["v"].at[idx].set(jnp.asarray(vstack))
        for key, slot in zip(keys, slots):
            layer, sid, blk = key
            self._tables[layer, sid, blk] = slot
            self._slot[key] = slot
            self._stored.discard(key)
            self.tracker.touch_block(key)
            if prefetch:
                self._prefetched.add(key)
        if prefetch:
            self._pq.issued += len(keys)

    def _restore_blocks(self, keys, *, prefetch=False):
        """Decompress stored blocks into fresh slots (one dispatch round,
        one pool scatter per direction)."""
        if not keys:
            return
        slots = [self._alloc.alloc() for _ in keys]
        blobs = self.kv_store.restore_many(
            [self._store_key(k) for k in keys]
        )
        kstack, vstack = self._stack_blobs(blobs)
        self._install_blocks(keys, slots, kstack, vstack, prefetch=prefetch)

    def _join_prefetch(self):
        """Deterministic barrier for the async prefetch worker: wait for
        the in-flight restore, install its blocks, re-raise its error.
        Called before ANY pool/table/store mutation or read can observe
        prefetch state, so async-on and sync-on see identical pool
        contents at every kernel launch."""
        pending, self._pf_pending = self._pf_pending, None
        if pending is None:
            return
        th, box, keys, slots = pending
        th.join()
        if "err" in box:
            raise box["err"]
        kstack, vstack = box["kv"]
        self._install_blocks(keys, slots, kstack, vstack, prefetch=True)

    def _retire_dead_blocks(self, layer, lo):
        """Free SWA blocks that slid wholly out of the attention window —
        nothing will ever read them again, resident or stored."""
        for sid in range(self._batch):
            start = self._retired_upto.get((layer, sid), 0)
            for blk in range(start, lo):
                key = (layer, sid, blk)
                if key in self._slot:
                    self._tables[layer, sid, blk] = -1
                    self._alloc.free(self._slot.pop(key))
                    self.tracker.drop(key)
                self._stored.discard(key)
                self._prefetched.discard(key)
                self.kv_store.discard(self._store_key(key))
            self._retired_upto[(layer, sid)] = max(start, lo)

    def _ensure_resident(self, layer, pos):
        """Make every block layer ``layer`` touches at ``pos`` resident:
        evict LRU non-needed blocks for room, restore stored blocks in one
        batched dispatch, allocate zero-history slots for new blocks."""
        self._join_prefetch()  # barrier: async restores land before any use
        needed = self._needed_blocks(layer, pos)
        if needed[0] > 0:
            self._retire_dead_blocks(layer, needed[0])
        nkeys = [(layer, sid, blk)
                 for sid in range(self._batch) for blk in needed]
        for k in nkeys:
            if k in self._prefetched:  # first demand touch since prefetch
                self._prefetched.discard(k)
                self._pq.hits += 1
        demand = [k for k in nkeys if k in self._stored]
        new = [k for k in nkeys
               if k not in self._stored and k not in self._slot]
        deficit = len(demand) + len(new) - self._alloc.free_blocks
        if deficit > 0:
            victims = self.tracker.candidates(deficit, protected=nkeys)
            if len(victims) < deficit:
                raise RuntimeError(
                    f"budget_blocks={self.budget_blocks} cannot hold layer "
                    f"{layer}'s working set at pos={pos} "
                    f"({len(nkeys)} blocks needed)"
                )
            self._evict_blocks(victims)
        if demand:
            self._restore_blocks(demand)
            self._stats["demand_restores"] += len(demand)
        for k in new:
            slot = self._alloc.alloc()
            self._slot[k] = slot
            layer_, sid, blk = k
            self._tables[layer_, sid, blk] = slot
        for k in nkeys:
            self.tracker.touch_block(k)
        self._ever.update(nkeys)

    def _next_groups(self, layer, pos):
        """The next ``prefetch_lookahead`` (layer, pos) access groups after
        ``(layer, pos)`` in layer-major order — crossing a step boundary
        this is the next-block-in-sequence prediction."""
        groups = []
        li, p = layer, pos
        for _ in range(self.prefetch_lookahead):
            li += 1
            if li >= self.cfg.num_layers:
                li, p = 0, p + 1
                if p >= self._horizon:
                    break
            groups.append((li, p))
        return groups

    def _push_prefetch(self, layer, pos):
        for li, p in self._next_groups(layer, pos):
            for sid in range(self._batch):
                for blk in self._needed_blocks(li, p):
                    key = (li, sid, blk)
                    if key in self._stored:
                        self._pq.push(key)

    def _drain_prefetch(self, layer, pos):
        """Restore queued predicted-hot blocks.  Best-effort: evicts only
        LRU blocks outside the imminent working set, never raises — a full
        pool just drops the remainder of the queue for this round.

        Async mode: slots are allocated and victims evicted here (main
        thread owns allocator/pool), then the decompress dispatch runs on
        a background worker so it overlaps the just-launched layer's
        attention; ``_join_prefetch`` installs the result at the next
        access group's barrier."""
        self._join_prefetch()
        targets = [k for k in self._pq.pop_all() if k in self._stored]
        if not targets:
            return
        protected = set(targets)
        for li, p in self._next_groups(layer, pos):
            protected.update(
                (li, sid, blk) for sid in range(self._batch)
                for blk in self._needed_blocks(li, p)
            )
        deficit = len(targets) - self._alloc.free_blocks
        if deficit > 0:
            self._evict_blocks(
                self.tracker.candidates(deficit, protected=protected)
            )
        take = targets[: self._alloc.free_blocks]
        if not take:
            return
        if not self.async_prefetch:
            self._restore_blocks(take, prefetch=True)
            return
        slots = [self._alloc.alloc() for _ in take]
        store_keys = [self._store_key(k) for k in take]
        box = {}

        def work():
            try:
                blobs = self.kv_store.restore_many(store_keys)
                box["kv"] = self._stack_blobs(blobs)
            except BaseException as exc:  # surfaced at the join barrier
                box["err"] = exc

        th = threading.Thread(target=work, name="kv-prefetch", daemon=True)
        self._pf_pending = (th, box, take, slots)
        self._stats["async_prefetch_batches"] += 1
        th.start()

    def paging_stats(self) -> dict:
        """Capacity-tier counters for the last/current generate() call."""
        s = dict(self._stats)
        pq = getattr(self, "_pq", None)
        alloc = getattr(self, "_alloc", None)
        s["prefetch_issued"] = pq.issued if pq is not None else 0
        s["prefetch_hits"] = pq.hits if pq is not None else 0
        s["budget_blocks"] = self.budget_blocks
        s["async_prefetch"] = self.async_prefetch
        s["high_water"] = alloc.high_water if alloc is not None else 0
        s["resident_blocks"] = alloc.allocated if alloc is not None else 0
        s["working_set_blocks"] = len(getattr(self, "_ever", ()))
        return s

    # ------------------------------------------------------------ generate

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32,
                 eos_id: int = -1) -> GenerationResult:
        """prompts: (B, Tp) int32.  Greedy decode."""
        b, tp = prompts.shape
        horizon = min(tp + max_new_tokens - 1, self.max_len - 1)
        paged = self.kv_offload
        if paged:
            self._begin_paged(b, horizon)
            caches = None
        else:
            caches = transformer.init_cache(self.cfg, b, self.max_len)
        toks = jnp.asarray(prompts[:, 0])
        outs = [np.asarray(toks)]
        n_steps = 0
        for pos in range(horizon):
            posj = jnp.int32(pos)
            x = self._embed(self.params, toks)
            for i in range(self.cfg.num_layers):
                if paged:
                    self._ensure_resident(i, pos)
                    x, self._pool, self._extra[i] = self._paged_layer_step(
                        self._layer_params[i], self._pool,
                        jnp.asarray(self._tables[i]), self._extra[i],
                        x, posj, self._is_global[i],
                    )
                    assert self._alloc.allocated <= self.budget_blocks
                    if self.kv_prefetch:
                        self._push_prefetch(i, pos)
                        self._drain_prefetch(i, pos)
                else:
                    x, caches[i] = self._layer_step(
                        self._layer_params[i], caches[i], x, posj,
                        self._is_global[i],
                    )
            logits = self._finish(self.params, x)
            n_steps += 1
            if pos + 1 < tp:
                toks = jnp.asarray(prompts[:, pos + 1])  # teacher-forced
            else:
                toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            outs.append(np.asarray(toks))
            if eos_id >= 0 and bool(jnp.all(toks == eos_id)):
                break
        if paged:
            self._join_prefetch()  # no worker outlives the generate call
        return GenerationResult(tokens=np.stack(outs, axis=1), steps=n_steps)
