"""KV-cache block manager with GPULZ eviction compression.

The in-graph decode caches live in launch/steps.py; this module is the
host-side block manager a serving deployment wraps around them: fixed-size
blocks, LRU eviction of cold blocks to host memory, evicted blocks GPULZ-
compressed (S=2 over bf16 — the paper's multi-byte rule for 2-byte data).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import lzss

KV_LZ = lzss.LZSSConfig(symbol_size=2, window=64, chunk_symbols=2048)


@dataclasses.dataclass
class BlockStats:
    evictions: int = 0
    restores: int = 0
    evicted_bytes_raw: int = 0
    evicted_bytes_stored: int = 0

    @property
    def eviction_ratio(self) -> float:
        return self.evicted_bytes_raw / max(1, self.evicted_bytes_stored)


class KVBlockStore:
    """Host-side store of evicted KV blocks, compressed with GPULZ."""

    def __init__(self, compress: bool = True, config=KV_LZ):
        self.compress = compress
        self.config = config
        self._store: dict = {}
        self.stats = BlockStats()

    def evict(self, key, block: np.ndarray):
        raw = np.ascontiguousarray(block)
        meta = (raw.dtype.str, raw.shape)
        if self.compress:
            res = lzss.compress(raw.view(np.uint8).reshape(-1), self.config)
            self._store[key] = ("gpulz", meta, res.data)
            self.stats.evicted_bytes_stored += res.total_bytes
        else:
            self._store[key] = ("raw", meta, raw.tobytes())
            self.stats.evicted_bytes_stored += raw.nbytes
        self.stats.evictions += 1
        self.stats.evicted_bytes_raw += raw.nbytes

    def restore(self, key) -> np.ndarray:
        codec, (dtype, shape), payload = self._store.pop(key)
        self.stats.restores += 1
        if codec == "gpulz":
            raw = lzss.decompress(payload)
            return raw.view(np.dtype(dtype)).reshape(shape)
        return np.frombuffer(payload, np.dtype(dtype)).reshape(shape)

    def __contains__(self, key):
        return key in self._store

    def __len__(self):
        return len(self._store)


class PagedKVTracker:
    """Block-granular access tracking -> eviction candidates (LRU)."""

    def __init__(self, block_tokens: int = 256, budget_blocks: int = 1024):
        self.block_tokens = block_tokens
        self.budget = budget_blocks
        self._last_access: dict = {}

    def touch(self, seq_id: int, pos: int):
        blk = pos // self.block_tokens
        self._last_access[(seq_id, blk)] = time.monotonic()

    def eviction_candidates(self):
        if len(self._last_access) <= self.budget:
            return []
        n = len(self._last_access) - self.budget
        items = sorted(self._last_access.items(), key=lambda kv: kv[1])
        return [k for k, _ in items[:n]]

    def drop(self, key):
        self._last_access.pop(key, None)
