"""KV-cache block manager with GPULZ eviction compression.

The in-graph decode caches live in launch/steps.py; this module is the
host-side block manager a serving deployment wraps around them: fixed-size
blocks, LRU eviction of cold blocks to host memory, evicted blocks GPULZ-
compressed (S=2 over bf16 — the paper's multi-byte rule for 2-byte data).

Eviction is batched: ``evict_many`` compresses every cold block of an
eviction round in ONE jitted dispatch (``lzss.compress_many``) instead of one
``compress()`` call per block, and ``restore_many`` is the batched inverse.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import lzss

# Geometry for KV blocks (S=2 over bf16).  backend/decoder stay "auto" —
# resolved per-platform at dispatch time ("auto" = the single-kernel
# fused-mono compressor on TPU) — so importing this module never
# initializes the JAX platform as a side effect.
KV_LZ = lzss.LZSSConfig(
    symbol_size=2, window=64, chunk_symbols=2048, backend="auto"
)


@dataclasses.dataclass
class BlockStats:
    evictions: int = 0
    restores: int = 0
    evicted_bytes_raw: int = 0
    evicted_bytes_stored: int = 0
    eviction_dispatches: int = 0    # jitted compression calls issued
    restore_dispatches: int = 0     # jitted decompression calls issued
                                    # (raw-codec blocks restore with zero)

    @property
    def eviction_ratio(self) -> float:
        return self.evicted_bytes_raw / max(1, self.evicted_bytes_stored)


class KVBlockStore:
    """Host-side store of evicted KV blocks, compressed with GPULZ.

    ``backend`` overrides the eviction-path compressor strategy and
    ``decoder`` the restore-path decode strategy (registry keys; default
    ``"auto"`` = the single-kernel ``fused-mono`` pair on TPU — restores,
    the KV-onlining hot path, decode in ONE Pallas launch straight from the
    stored blobs) — batched evictions and restores dispatch through
    ``config.backend`` / ``config.decoder``.

    ``mesh``/``batch_axis`` shard each eviction/restore round's batch
    dimension over a device mesh (``sharding/batch.py``): backend and
    decoder default to the ``"sharded"`` registry pair, which runs the
    platform pipeline per shard — stored blobs stay byte-identical to the
    single-device dispatch.

    ``lossy_eb`` selects the error-bounded ``lossy-fz`` codec for evicted
    blocks (f32 blocks ONLY — rejected otherwise): each restored element is
    within ``eb`` of the evicted value (non-finite elements exact), traded
    for a better eviction ratio.  An explicit ``backend`` then names the
    codec's *inner* lossless stage.
    """

    def __init__(self, compress: bool = True, config=None, decoder=None,
                 backend=None, mesh=None, batch_axis=None, lossy_eb=None):
        self.compress = compress
        if config is None:
            config = KV_LZ
        if mesh is None and batch_axis is not None:
            # match LZSSConfig: a silently ignored batch_axis would read as
            # "sharding configured" while dispatching single-device
            raise ValueError("batch_axis requires mesh=...")
        overrides = {}
        if backend is not None:
            overrides["backend"] = backend
        if decoder is not None:
            overrides["decoder"] = decoder
        if lossy_eb is not None:
            # the named backend becomes the inner lossless stage of the
            # lossy container (mirrors optim/grad_compress.lossy_grad_config)
            inner = overrides.get("backend", "auto")
            overrides["lossy_inner"] = (
                "auto" if inner in ("lossy-fz", "sharded") else inner
            )
            overrides["backend"] = "lossy-fz"
            overrides["symbol_size"] = 4
            overrides["lossy_eb"] = float(lossy_eb)
        if mesh is not None:
            # a mesh implies the sharded registry pair unless this call
            # explicitly picked a different strategy ("auto" is not one)
            if overrides.get("backend", "auto") == "auto":
                overrides["backend"] = "sharded"
            if overrides.get("decoder", "auto") == "auto":
                overrides["decoder"] = "sharded"
            overrides["mesh"] = mesh
            overrides["batch_axis"] = batch_axis
        if overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        self._store: dict = {}
        self.stats = BlockStats()

    def evict_many(self, items) -> None:
        """Batch-evict ``[(key, block), ...]`` — one compression dispatch.

        Blocks may be ragged (different shapes/sizes); the batched pipeline
        pads them to a common chunk count and every header records the true
        size.
        """
        items = list(items)
        if not items:
            return
        keys = [k for k, _ in items]
        raws = [np.ascontiguousarray(b) for _, b in items]
        metas = [(r.dtype.str, r.shape) for r in raws]
        if self.compress and self.config.backend == "lossy-fz":
            bad = [
                (k, str(r.dtype)) for k, r in zip(keys, raws)
                if r.dtype != np.float32
            ]
            if bad:
                raise ValueError(
                    f"lossy_eb eviction codec (lossy-fz) bounds the error of "
                    f"float32 blocks only; got {bad} — evict these through a "
                    f"lossless store (lossy_eb=None)"
                )
        if self.compress:
            batch = lzss.compress_many(
                [r.view(np.uint8).reshape(-1) for r in raws], self.config
            )
            self.stats.eviction_dispatches += 1
            for i, (key, meta) in enumerate(zip(keys, metas)):
                res = batch[i]
                # copy: res.data is a view into the batch's (B, cap) buffer;
                # storing the view would pin the whole padded batch in memory
                self._store[key] = ("gpulz", meta, res.data.copy())
                self.stats.evicted_bytes_stored += res.total_bytes
        else:
            for key, meta, raw in zip(keys, metas, raws):
                self._store[key] = ("raw", meta, raw.tobytes())
                self.stats.evicted_bytes_stored += raw.nbytes
        self.stats.evictions += len(raws)
        self.stats.evicted_bytes_raw += sum(r.nbytes for r in raws)

    def evict(self, key, block: np.ndarray) -> None:
        self.evict_many([(key, block)])

    def _reassemble(self, meta, raw_bytes: np.ndarray) -> np.ndarray:
        dtype, shape = meta
        return raw_bytes.view(np.dtype(dtype)).reshape(shape)

    def restore_many(self, keys) -> list:
        """Batch-restore blocks — one decompression dispatch per geometry."""
        keys = list(keys)
        missing = [k for k in keys if k not in self._store]
        if missing:  # validate before mutating: a bad key must not lose data
            raise KeyError(f"blocks not in store: {missing}")
        popped = [self._store.pop(k) for k in keys]
        self.stats.restores += len(keys)
        out = [None] * len(keys)
        groups: dict = {}  # container geometry + codec id -> block indices
        for i, (codec, _, blob) in enumerate(popped):
            if codec == "gpulz":
                h = lzss.fmt.parse_header(blob)
                # version + method byte are part of the batching key: a
                # store holding raw, deflate-full and lossy blobs (codec
                # changed between rounds) must not land a mixed-method batch
                # in one decompress_many call; lossy blobs additionally
                # split on their static decode params (mode, inner method)
                key = (h.version, h.method, h.symbol_size, h.chunk_symbols,
                       h.n_chunks, h.lossy_mode, h.inner_method)
                groups.setdefault(key, []).append(i)
        # an explicitly non-sharded decoder + mesh means compress-side
        # sharding only: restore single-device rather than conflicting
        sharded = self.config.decoder in ("auto", "sharded")
        method_only = {
            lzss.fmt.METHOD_HUFFMAN: "deflate-full",
            lzss.fmt.METHOD_LOSSY: "lossy-fz",
        }
        for gkey, idxs in groups.items():
            decoder = self.config.decoder
            if decoder not in ("auto", "sharded") and decoder != \
                    method_only.get(gkey[1]) and (
                        decoder in method_only.values()
                        or gkey[1] in method_only
                    ):
                # decoder/method mismatch (codec changed between eviction
                # rounds): fall back per group — the method byte routes
                decoder = "auto"
            raws = lzss.decompress_many(
                [popped[i][2] for i in idxs], decoder=decoder,
                mesh=self.config.mesh if sharded else None,
                batch_axis=self.config.batch_axis if sharded else None,
                # the config's geometry pin applies to BOTH directions
                chunks_per_block=self.config.chunks_per_block,
            )
            self.stats.restore_dispatches += 1
            for i, raw in zip(idxs, raws):
                out[i] = self._reassemble(popped[i][1], raw)
        for i, (codec, meta, payload) in enumerate(popped):
            if codec == "raw":
                out[i] = self._reassemble(
                    meta, np.frombuffer(payload, np.uint8)
                )
        return out

    def restore(self, key) -> np.ndarray:
        return self.restore_many([key])[0]

    def discard(self, key) -> None:
        """Drop a stored block without restoring it (stale generation)."""
        self._store.pop(key, None)

    def keys(self):
        return list(self._store.keys())

    def __contains__(self, key):
        return key in self._store

    def __len__(self):
        return len(self._store)


class PagedKVTracker:
    """Block-granular access tracking -> eviction candidates (LRU).

    Recency is a monotonic *logical* access counter, not a wall clock:
    eviction order is a pure function of the access sequence, so tests can
    pin candidate order and same-round ties break by touch order instead of
    timer resolution.
    """

    def __init__(self, block_tokens: int = 256, budget_blocks: int = 1024):
        self.block_tokens = block_tokens
        self.budget = budget_blocks
        self._last_access: dict = {}
        self._clock = 0

    def touch_block(self, key) -> None:
        """Mark one (opaque) block key as just-accessed."""
        self._clock += 1
        self._last_access[key] = self._clock

    def touch(self, seq_id: int, pos: int):
        self.touch_block((seq_id, pos // self.block_tokens))

    def eviction_candidates(self):
        if len(self._last_access) <= self.budget:
            return []
        n = len(self._last_access) - self.budget
        items = sorted(self._last_access.items(), key=lambda kv: kv[1])
        return [k for k, _ in items[:n]]

    def candidates(self, n: int, protected=()):
        """The n least-recently-used tracked keys outside ``protected``."""
        protected = set(protected)
        items = sorted(self._last_access.items(), key=lambda kv: kv[1])
        out = []
        for k, _ in items:
            if k in protected:
                continue
            out.append(k)
            if len(out) == n:
                break
        return out

    def drop(self, key):
        self._last_access.pop(key, None)
