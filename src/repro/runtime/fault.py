"""Fault tolerance: step guard (straggler detection), restart policy,
heartbeats, and the injectable filesystem seam the crash-consistency
harness drives.

On a real multi-pod deployment each host runs the training loop under a
``StepGuard``; the coordinator (or GKE/Borg health checks) watches the
heartbeat file.  Recovery is always restart-from-checkpoint: the data
pipeline is a pure function of (seed, step) and checkpoints are mesh-
agnostic, so a restart — even onto a different number of pods (elastic.py) —
reproduces the exact training trajectory from the last saved step.

Filesystem seam (``HostFS`` / ``FaultyFS``): every byte the checkpoint
writers put on disk goes through one of these objects, so tests can inject
EIO/ENOSPC/delays/crash-before-rename at an exact write boundary — the
Nth matching filesystem call — deterministically (per-spec counters) or
seeded-randomly (``FaultSpec.probability``).  ``SimulatedCrash`` derives
from ``BaseException`` on purpose: retry policies and the ``except
Exception`` fallback ladders (e.g. ``restore_latest``) must never swallow
a simulated process death, only the test harness catches it.
"""

from __future__ import annotations

import dataclasses
import errno
import json
import os
import random
import shutil
import time


class SimulatedCrash(BaseException):
    """Process death injected by ``FaultyFS``.

    A ``BaseException`` so no ``except Exception`` recovery path (retry
    policies, ``restore_latest``'s walk-back) can accidentally absorb it:
    the crash must propagate to the test harness exactly like a real
    SIGKILL would leave the disk — partial bytes, no cleanup.
    """

    def __init__(self, op: str, path: str):
        super().__init__(f"simulated crash during {op}({path})")
        self.op = op
        self.path = path


class HostFS:
    """Real-filesystem backend of the write seam.

    Checkpoint/blob writers call these instead of ``open``/``os.rename``
    directly so ``FaultyFS`` can interpose.  The surface is deliberately
    tiny: exactly the operations whose failure order matters for crash
    consistency.
    """

    def write_bytes(self, path: str, data) -> None:
        with open(path, "wb") as f:
            f.write(data)

    def read_bytes(self, path: str) -> bytes:
        with open(path, "rb") as f:
            return f.read()

    def rename(self, src: str, dst: str) -> None:
        os.rename(src, dst)

    def makedirs(self, path: str, exist_ok: bool = False) -> None:
        os.makedirs(path, exist_ok=exist_ok)

    def rmtree(self, path: str, ignore_errors: bool = False) -> None:
        shutil.rmtree(path, ignore_errors=ignore_errors)

    def exists(self, path: str) -> bool:
        return os.path.exists(path)

    def isdir(self, path: str) -> bool:
        return os.path.isdir(path)

    def listdir(self, path: str):
        return os.listdir(path)


@dataclasses.dataclass
class FaultSpec:
    """One injected failure: trigger on the ``nth``..``nth+count-1``-th
    call (1-based, counted per spec over *matching* calls) of ``op``
    whose path contains ``path_substr``.

    mode:
      * ``"error"`` — raise ``OSError(error, ...)`` (EIO default;
        ``count`` bounds it, so transient-then-success is
        ``count=2`` + a retrying writer);
      * ``"delay"`` — sleep ``delay_s`` then succeed (slow-disk model,
        used to force writer backpressure deterministically);
      * ``"crash"`` — flush ``partial`` of the bytes (writes only), then
        raise ``SimulatedCrash``: the process "died" at this boundary.

    ``probability`` > 0 switches the spec from counter-triggered to
    seeded-random: each matching call fires with that probability from
    the owning ``FaultyFS``'s ``random.Random(seed)`` — identical seeds
    replay identical fault sequences.
    """

    op: str = "write"          # "write" | "rename" | "makedirs" | "rmtree" | "*"
    nth: int = 1
    count: int = 1
    error: int = errno.EIO
    mode: str = "error"        # "error" | "delay" | "crash"
    delay_s: float = 0.0
    partial: float = 0.0
    path_substr: str = ""
    probability: float = 0.0
    hits: int = 0              # times this spec actually fired (observable)
    _seen: int = 0             # matching calls observed (internal counter)


class FaultyFS(HostFS):
    """Deterministic, seedable fault injection over the ``HostFS`` seam.

    Every instrumented call is appended to ``self.log`` as ``(op, path)``
    even when no fault fires, so tests can *enumerate* a save's write
    boundaries from a clean run and then replay with a crash planted at
    each one.  ``calls`` counts per-op totals (retry-attempt assertions).
    """

    _OPS = ("write", "rename", "makedirs", "rmtree")

    def __init__(self, faults=(), seed: int = 0):
        self.faults = list(faults)
        self.seed = seed
        self._rng = random.Random(seed)
        self.calls = {op: 0 for op in self._OPS}
        self.log: list = []

    def _fire(self, f: FaultSpec, op: str, path: str, data=None):
        f.hits += 1
        if f.mode == "delay":
            time.sleep(f.delay_s)
            return
        if f.mode == "crash":
            if op == "write" and data is not None and f.partial > 0:
                n = int(len(data) * f.partial)
                super().write_bytes(path, bytes(data[:n]))
            raise SimulatedCrash(op, path)
        raise OSError(f.error, os.strerror(f.error), path)

    def _check(self, op: str, path: str, data=None):
        self.calls[op] += 1
        self.log.append((op, path))
        for f in self.faults:
            if f.op != "*" and f.op != op:
                continue
            if f.path_substr and f.path_substr not in path:
                continue
            f._seen += 1
            if f.probability > 0.0:
                if self._rng.random() < f.probability:
                    self._fire(f, op, path, data)
            elif f.nth <= f._seen < f.nth + f.count:
                self._fire(f, op, path, data)

    def write_bytes(self, path, data):
        self._check("write", path, data)
        super().write_bytes(path, data)

    def rename(self, src, dst):
        self._check("rename", src)
        super().rename(src, dst)

    def makedirs(self, path, exist_ok=False):
        self._check("makedirs", path)
        super().makedirs(path, exist_ok=exist_ok)

    def rmtree(self, path, ignore_errors=False):
        self._check("rmtree", path)
        super().rmtree(path, ignore_errors=ignore_errors)


@dataclasses.dataclass
class StragglerStats:
    steps: int = 0
    slow_steps: int = 0
    mean_s: float = 0.0
    worst_s: float = 0.0
    # async-writer backpressure: wall-clock the loop spent blocked on
    # checkpoint I/O (enqueue waits / sync write time), tracked as its own
    # axis so a slow disk is never misread as a slow accelerator step
    io_wait_steps: int = 0
    io_wait_s: float = 0.0
    io_stalls: int = 0


class StepGuard:
    """Wall-clock watchdog around train steps.

    * keeps an EWMA of step time; a step slower than ``threshold`` x EWMA is
      flagged (straggler signal — on real fleets this triggers hot-spare
      swap-in / slice reconfiguration);
    * after ``max_consecutive_slow`` flags, ``should_restart`` turns True and
      the launcher falls back to checkpoint-restart;
    * async-checkpoint-writer backpressure (``io_wait_s``: time the loop
      spent blocked handing a step to ``runtime/async_io.AsyncBlobWriter``)
      is accounted as its OWN straggler axis — an ``io_stall`` when the
      wait exceeds the step-time EWMA — and never feeds the compute EWMA
      or ``should_restart``: a slow disk wants throttled checkpoint
      cadence, not a checkpoint-restart.
    """

    def __init__(self, threshold: float = 3.0, max_consecutive_slow: int = 3,
                 heartbeat_path: str = ""):
        self.threshold = threshold
        self.max_slow = max_consecutive_slow
        self.heartbeat_path = heartbeat_path
        self.ewma = None
        self.consecutive_slow = 0
        self.stats = StragglerStats()

    def observe(self, step: int, seconds: float,
                io_wait_s: float = 0.0) -> bool:
        """Record one step; returns True if the step was a straggler.

        ``seconds`` is pure step compute (excludes checkpoint I/O, as the
        train loop times it); ``io_wait_s`` is how long the loop blocked on
        checkpoint writes since the previous observe — the async writer's
        enqueue backpressure, or the full write time in sync mode.
        """
        self.stats.steps += 1
        self.stats.worst_s = max(self.stats.worst_s, seconds)
        self.stats.mean_s += (seconds - self.stats.mean_s) / self.stats.steps
        if io_wait_s > 0.0:
            self.stats.io_wait_steps += 1
            self.stats.io_wait_s += io_wait_s
            if self.ewma is not None and io_wait_s > self.ewma:
                # the loop lost more than a whole step's compute waiting on
                # the writer: the disk, not a device, is the straggler
                self.stats.io_stalls += 1
        slow = False
        if self.ewma is not None and seconds > self.threshold * self.ewma:
            slow = True
            self.consecutive_slow += 1
            self.stats.slow_steps += 1
        else:
            self.consecutive_slow = 0
        a = 0.1
        self.ewma = seconds if self.ewma is None else (
            (1 - a) * self.ewma + a * seconds
        )
        if self.heartbeat_path:
            tmp = self.heartbeat_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"step": step, "t": time.time(),
                           "step_s": seconds, "io_wait_s": io_wait_s,
                           "io_stalls": self.stats.io_stalls}, f)
            os.replace(tmp, self.heartbeat_path)
        return slow

    @property
    def io_stalled(self) -> bool:
        return self.stats.io_stalls > 0

    @property
    def should_restart(self) -> bool:
        return self.consecutive_slow >= self.max_slow


def run_with_restarts(make_loop, max_restarts: int = 2):
    """Supervisor: run ``make_loop()`` (which resumes from the latest
    checkpoint internally); on exception, restart up to ``max_restarts``."""
    attempt = 0
    while True:
        try:
            return make_loop()
        except Exception as exc:
            attempt += 1
            if attempt > max_restarts:
                raise
            print(f"[fault] loop failed ({exc!r}); restart {attempt}/"
                  f"{max_restarts} from latest checkpoint")
