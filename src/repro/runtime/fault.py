"""Fault tolerance: step guard (straggler detection), restart policy,
heartbeats.

On a real multi-pod deployment each host runs the training loop under a
``StepGuard``; the coordinator (or GKE/Borg health checks) watches the
heartbeat file.  Recovery is always restart-from-checkpoint: the data
pipeline is a pure function of (seed, step) and checkpoints are mesh-
agnostic, so a restart — even onto a different number of pods (elastic.py) —
reproduces the exact training trajectory from the last saved step.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time


@dataclasses.dataclass
class StragglerStats:
    steps: int = 0
    slow_steps: int = 0
    mean_s: float = 0.0
    worst_s: float = 0.0


class StepGuard:
    """Wall-clock watchdog around train steps.

    * keeps an EWMA of step time; a step slower than ``threshold`` x EWMA is
      flagged (straggler signal — on real fleets this triggers hot-spare
      swap-in / slice reconfiguration);
    * after ``max_consecutive_slow`` flags, ``should_restart`` turns True and
      the launcher falls back to checkpoint-restart.
    """

    def __init__(self, threshold: float = 3.0, max_consecutive_slow: int = 3,
                 heartbeat_path: str = ""):
        self.threshold = threshold
        self.max_slow = max_consecutive_slow
        self.heartbeat_path = heartbeat_path
        self.ewma = None
        self.consecutive_slow = 0
        self.stats = StragglerStats()

    def observe(self, step: int, seconds: float) -> bool:
        """Record one step; returns True if the step was a straggler."""
        self.stats.steps += 1
        self.stats.worst_s = max(self.stats.worst_s, seconds)
        self.stats.mean_s += (seconds - self.stats.mean_s) / self.stats.steps
        slow = False
        if self.ewma is not None and seconds > self.threshold * self.ewma:
            slow = True
            self.consecutive_slow += 1
            self.stats.slow_steps += 1
        else:
            self.consecutive_slow = 0
        a = 0.1
        self.ewma = seconds if self.ewma is None else (
            (1 - a) * self.ewma + a * seconds
        )
        if self.heartbeat_path:
            tmp = self.heartbeat_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"step": step, "t": time.time(),
                           "step_s": seconds}, f)
            os.replace(tmp, self.heartbeat_path)
        return slow

    @property
    def should_restart(self) -> bool:
        return self.consecutive_slow >= self.max_slow


def run_with_restarts(make_loop, max_restarts: int = 2):
    """Supervisor: run ``make_loop()`` (which resumes from the latest
    checkpoint internally); on exception, restart up to ``max_restarts``."""
    attempt = 0
    while True:
        try:
            return make_loop()
        except Exception as exc:
            attempt += 1
            if attempt > max_restarts:
                raise
            print(f"[fault] loop failed ({exc!r}); restart {attempt}/"
                  f"{max_restarts} from latest checkpoint")
