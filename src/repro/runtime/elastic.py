"""Elastic scaling: restore a checkpoint onto a different mesh.

Checkpoints are mesh-agnostic (full logical arrays, checkpoint/manager.py),
and shardings are *derived* from logical axis rules per mesh — so scaling
from 1 pod to 2 (or 16x16 to 8x32, or recovering with a dead slice cordoned
off) is: build the new mesh, recompute shardings, restore.  Batch math
(per-pod microbatching) rescales so the global batch — and therefore the
training trajectory — is preserved.  The GPULZ blobs themselves are
mesh-agnostic too: when the manager's batched dispatch is shard-mapped
(``lz_mesh``), ``restore_onto_mesh`` re-points decode sharding at the
restore-side mesh, so a checkpoint compressed on an 8-device mesh restores
on a 2-device one.
"""

from __future__ import annotations

import dataclasses


from repro.launch import steps as steps_lib


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_axes: dict
    new_axes: dict
    microbatch_scale: float  # multiply TrainConfig.microbatches by this

    def describe(self) -> str:
        return (
            f"remesh {self.old_axes} -> {self.new_axes}; "
            f"microbatches x{self.microbatch_scale:g}"
        )


def plan_remesh(old_mesh, new_mesh) -> ElasticPlan:
    oa = dict(zip(old_mesh.axis_names, old_mesh.devices.shape))
    na = dict(zip(new_mesh.axis_names, new_mesh.devices.shape))
    old_dp = oa.get("pod", 1) * oa.get("data", 1)
    new_dp = na.get("pod", 1) * na.get("data", 1)
    # fewer data-parallel ranks => more microbatches to hold global batch
    return ElasticPlan(oa, na, microbatch_scale=old_dp / max(1, new_dp))


def restore_onto_mesh(manager, cfg, traincfg, new_mesh, template=None):
    """Restore the latest checkpoint with shardings for ``new_mesh``.

    When the manager's batched compression dispatch is shard-mapped
    (``lz_mesh`` set, or the ``"sharded"`` decoder selected), the decode
    shards must track the mesh we are restoring ONTO — not the (possibly
    larger, possibly gone) mesh the checkpoint was written on.  Blobs are
    mesh-agnostic bytes, so a step compressed on an 8-device mesh restores
    on 2 devices by simply re-pointing ``lz_mesh`` here.
    """
    if template is None:
        template = steps_lib.abstract_train_state(cfg, traincfg)
    shardings = steps_lib.train_state_shardings(cfg, traincfg, new_mesh)
    if (
        getattr(manager, "lz_mesh", None) is not None
        or getattr(manager, "lz_decoder", None) == "sharded"
    ):
        # lz_batch_axis must track the mesh swap: the axis the checkpoint
        # was written with may not exist on the restore-side mesh (e.g. a
        # ("pod", "data") save restoring onto a ("data",) mesh).  Keep an
        # explicitly configured axis when the new mesh still has it; only
        # when it is gone fall back to None so normalize_batch_axes
        # re-derives the batch axes from the restore-side mesh.
        axis = getattr(manager, "lz_batch_axis", None)
        if axis is not None:
            from repro.sharding.batch import normalize_batch_axes

            try:
                normalize_batch_axes(new_mesh, axis)
            except ValueError:
                axis = None
        manager = dataclasses.replace(
            manager, lz_mesh=new_mesh, lz_batch_axis=axis
        )
    state, step = manager.restore_latest(template, shardings)
    return state, step
