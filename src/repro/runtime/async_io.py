"""Async double-buffered blob writing for checkpoint/KV host I/O.

GPULZ exists so compression never becomes the bottleneck it was built to
remove — but a host-synchronous ``CheckpointManager.save`` stalls the train
step on blob I/O anyway.  This module is the CPU-testable slice of the
ROADMAP's pod-scale item: ``AsyncBlobWriter`` is a single background thread
draining a bounded FIFO of write ops, so the loop hands a step's compressed
blobs off and keeps training while the bytes hit disk.

Ordering and atomicity:
  * one worker thread means a total order over ops — a step's
    ``blobs -> manifest -> commit marker -> rename(tmp, final)`` sequence
    can never interleave or reorder;
  * the commit marker is written *last* inside the tmp dir and the rename
    is the publish point: a crash at any earlier boundary leaves either a
    ``*.tmp`` dir or a marker-less dir, both of which readers
    (``CheckpointManager.steps``) treat as nonexistent;
  * the bounded in-flight window IS the double buffer: with
    ``max_pending_steps=2`` the loop can compress/enqueue step N+1 while
    step N's bytes are still being written, and only blocks (backpressure,
    surfaced to ``StepGuard`` via ``last_blocked_s``) when it runs a full
    step ahead of the disk.

Failure contract:
  * transient ``OSError``s retry under ``RetryPolicy`` (bounded attempts,
    exponential backoff, deterministic); non-retryable errnos (ENOSPC) fail
    immediately;
  * a failed op marks its step failed, drops the step's remaining queued
    ops (its tmp dir is never renamed, so it can never be restored), and
    the error re-raises on the NEXT ``submit``/``wait_until_finished`` as
    an ``AsyncWriteError`` naming the step and path — never a silent drop.
    Surfacing clears the error: later steps proceed (disk may have
    recovered);
  * a ``SimulatedCrash`` from the ``FaultyFS`` seam kills the worker where
    it stands — no cleanup, no retry, mimicking process death — so the
    crash-consistency suite can probe every write boundary.
"""

from __future__ import annotations

import dataclasses
import errno
import queue
import threading
import time

from repro.runtime.fault import HostFS, SimulatedCrash


class AsyncWriteError(RuntimeError):
    """A background write failed; raised on the next enqueue/wait."""

    def __init__(self, label, path: str, cause: BaseException):
        super().__init__(
            f"async write failed for step {label} (path {path!r}): {cause!r}"
        )
        self.label = label
        self.path = path
        self.cause = cause


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded-attempt exponential backoff over transient ``OSError``s.

    Deterministic: attempt count and sleep schedule depend only on the
    policy fields, so a seeded ``FaultyFS`` exercising
    fail-fail-succeed always resolves on the same attempt.
    """

    max_attempts: int = 3
    backoff_s: float = 0.005
    backoff_mult: float = 2.0
    # EIO: flaky device; EAGAIN/EINTR: transient kernel conditions.
    # ENOSPC is deliberately absent — a full disk does not heal by waiting.
    retryable: tuple = (errno.EIO, errno.EAGAIN, errno.EINTR)

    def run(self, fn):
        delay = self.backoff_s
        for attempt in range(1, self.max_attempts + 1):
            try:
                return fn()
            except OSError as exc:
                if exc.errno not in self.retryable:
                    raise
                if attempt == self.max_attempts:
                    raise
                time.sleep(delay)
                delay *= self.backoff_mult


@dataclasses.dataclass
class _Op:
    kind: str            # "write" | "commit"
    label: object        # step id this op belongs to
    path: str = ""       # write: destination file
    data: bytes = b""
    tmp: str = ""        # commit: staging dir renamed to final
    final: str = ""
    after: object = None  # commit: callback run post-rename (e.g. GC)


_STOP = object()


class AsyncBlobWriter:
    """Bounded-queue background writer with per-step commit semantics.

    Usage (one step)::

        writer.begin_step(step)              # blocks if 2 steps in flight
        writer.put_write(step, path, data)   # as blobs become ready
        ...
        writer.put_write(step, marker_path, b"")   # commit marker last
        writer.put_commit(step, tmp_dir, final_dir, after=gc_fn)

    ``in_flight()`` exposes the registered-but-not-yet-committed steps so
    GC never deletes a directory the worker still owns.
    """

    def __init__(self, fs=None, max_pending_steps: int = 2, retry=None):
        self._fs = fs if fs is not None else HostFS()
        self._retry = retry if retry is not None else RetryPolicy()
        self.max_pending_steps = max_pending_steps
        self._q: queue.Queue = queue.Queue()
        self._cv = threading.Condition()
        self._inflight: list = []      # labels begun, not committed/failed
        self._failed: set = set()      # labels whose remaining ops we drop
        self._pending_ops = 0
        self._error: AsyncWriteError | None = None
        self._dead: BaseException | None = None  # SimulatedCrash/fatal
        self._closed = False
        self.writes = 0
        self.commits = 0
        self.blocked_s = 0.0           # cumulative enqueue backpressure
        self.last_blocked_s = 0.0      # backpressure of the latest begin
        self._thread = threading.Thread(
            target=self._run, name="async-blob-writer", daemon=True
        )
        self._thread.start()

    # ------------------------------------------------------------ producer

    def _raise_pending_locked(self):
        if self._dead is not None:
            raise self._dead
        if self._error is not None:
            err, self._error = self._error, None  # surfaced once, cleared
            raise err

    def check_error(self):
        """Raise (and clear) any pending background failure."""
        with self._cv:
            self._raise_pending_locked()

    def begin_step(self, label) -> float:
        """Register a step; block while ``max_pending_steps`` are already
        in flight (the double-buffer bound).  Returns seconds blocked."""
        t0 = time.monotonic()
        with self._cv:
            self._raise_pending_locked()
            if self._closed:
                raise RuntimeError("AsyncBlobWriter is closed")
            while (
                len(self._inflight) >= self.max_pending_steps
                and self._dead is None
                and self._error is None
            ):
                self._cv.wait(0.05)
            self._raise_pending_locked()
            self._inflight.append(label)
            blocked = time.monotonic() - t0
            self.blocked_s += blocked
            self.last_blocked_s = blocked
            return blocked

    def put_write(self, label, path: str, data) -> None:
        # only a dead worker raises here: OSError-class failures surface at
        # the deterministic points (next begin_step / wait_until_finished),
        # never mid-enqueue — the worker already drops the rest of a failed
        # step's ops, so enqueueing on is harmless
        with self._cv:
            if self._dead is not None:
                raise self._dead
            self._pending_ops += 1
        self._q.put(_Op("write", label, path=path, data=bytes(data)))

    def put_commit(self, label, tmp: str, final: str, after=None) -> None:
        with self._cv:
            if self._dead is not None:
                raise self._dead
            self._pending_ops += 1
        self._q.put(_Op("commit", label, tmp=tmp, final=final, after=after))

    def in_flight(self) -> set:
        with self._cv:
            return set(self._inflight)

    def wait_until_finished(self) -> None:
        """Block until every queued op has been processed; raise any
        pending failure.  Never hangs on a dead worker: a simulated crash
        re-raises immediately."""
        with self._cv:
            while self._pending_ops > 0 and self._dead is None:
                self._cv.wait(0.05)
            self._raise_pending_locked()

    def stats(self) -> dict:
        with self._cv:
            return {
                "writes": self.writes,
                "commits": self.commits,
                "pending_ops": self._pending_ops,
                "in_flight_steps": len(self._inflight),
                "blocked_s": self.blocked_s,
                "last_blocked_s": self.last_blocked_s,
                "alive": self._dead is None,
            }

    def close(self, wait: bool = True) -> None:
        if wait:
            self.wait_until_finished()
        with self._cv:
            self._closed = True
        self._q.put(_STOP)
        self._thread.join(timeout=10.0)

    # ------------------------------------------------------------- worker

    def _finish_op(self, op, failed: BaseException | None = None):
        with self._cv:
            self._pending_ops -= 1
            if failed is not None:
                if self._error is None:  # first failure wins the report
                    self._error = AsyncWriteError(
                        op.label, op.path or op.tmp, failed
                    )
                self._failed.add(op.label)
                if op.label in self._inflight:
                    self._inflight.remove(op.label)
            elif op.kind == "commit" and op.label in self._inflight:
                self._inflight.remove(op.label)
            self._cv.notify_all()

    def _run(self):
        while True:
            op = self._q.get()
            if op is _STOP:
                return
            if op.label in self._failed:
                # the step already failed: drop its remaining ops so the
                # tmp dir is never renamed (never restorable)
                self._finish_op(op)
                continue
            try:
                if op.kind == "write":
                    self._retry.run(lambda: self._fs.write_bytes(op.path, op.data))
                    self.writes += 1
                else:

                    def _commit():
                        # re-saving an existing step replaces it, exactly
                        # like the sync path
                        if self._fs.exists(op.final):
                            self._fs.rmtree(op.final)
                        self._fs.rename(op.tmp, op.final)

                    self._retry.run(_commit)
                    self.commits += 1
            except SimulatedCrash as exc:
                # process death: stop dead, no bookkeeping beyond the flag
                with self._cv:
                    self._dead = exc
                    self._cv.notify_all()
                return
            except BaseException as exc:
                self._finish_op(op, failed=exc)
                continue
            if op.kind == "commit" and op.after is not None:
                # run BEFORE _finish_op so wait_until_finished() cannot
                # return while this callback (GC) is still mutating disk
                try:
                    op.after()
                except SimulatedCrash as exc:
                    with self._cv:
                        self._dead = exc
                        self._cv.notify_all()
                    return
                except Exception:
                    # GC/debris callbacks are best-effort; a failure there
                    # must not poison the committed step
                    pass
            self._finish_op(op)
