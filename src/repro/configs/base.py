"""Config dataclasses for the model zoo, training, serving and compression.

Mesh-divisibility padding: the production mesh fixes the model axis at 16, so
head/vocab counts that do not divide 16 are padded up (zero-init extra heads /
rows — the MaxText convention).  ``true_*`` properties keep the unpadded
numbers for MODEL_FLOPS accounting; the padded/true FLOP ratio is reported in
the roofline analysis rather than hidden.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

MODEL_AXIS = 16  # model-parallel degree of the production mesh


def pad_to(n: int, m: int = MODEL_AXIS) -> int:
    return -(-n // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | hybrid | moe | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // num_heads
    mixer: str = "attention"         # attention | mla | ssm | hybrid
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    qk_norm: bool = False
    sliding_window: int = 0          # 0 => full attention everywhere
    global_attn_layers: Tuple[int, ...] = ()
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    frontend: str = "text"           # text | audio_stub | vision_stub
    subquadratic: bool = False       # can run long_500k decode
    model_axis: int = MODEL_AXIS     # padding granularity (1 = no padding)
    kv_quant: bool = False           # int8 KV cache (decode memory lever)

    # ----- derived (padded for the model axis) -----
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def padded_heads(self) -> int:
        return pad_to(self.num_heads, self.model_axis)

    @property
    def padded_kv_heads(self) -> int:
        return pad_to(self.num_kv_heads, self.model_axis) if self.num_kv_heads else 0

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, self.model_axis)

    @property
    def ssm_heads(self) -> int:
        assert self.ssm is not None
        return (self.d_model * self.ssm.expand) // self.ssm.head_dim

    @property
    def padded_ssm_heads(self) -> int:
        return pad_to(self.ssm_heads, self.model_axis)

    def param_count(self, padded: bool = False) -> int:
        """Analytic parameter count (true or padded)."""
        h = self.padded_heads if padded else self.num_heads
        kv = self.padded_kv_heads if padded else self.num_kv_heads
        v = self.padded_vocab if padded else self.vocab_size
        d, dh, L = self.d_model, self.hd, self.num_layers
        per_layer = 0
        if self.mixer in ("attention", "hybrid"):
            per_layer += d * h * dh + 2 * d * kv * dh + h * dh * d
        if self.mixer == "mla":
            m = self.mla
            per_layer += (
                d * m.q_lora_rank
                + m.q_lora_rank * h * (m.qk_nope_dim + m.qk_rope_dim)
                + d * m.kv_lora_rank
                + d * m.qk_rope_dim
                + m.kv_lora_rank * h * (m.qk_nope_dim + m.v_head_dim)
                + h * m.v_head_dim * d
            )
        if self.mixer in ("ssm", "hybrid"):
            s = self.ssm
            heads = (
                self.padded_ssm_heads if padded else self.ssm_heads
            )
            di = heads * s.head_dim
            per_layer += (
                d * (2 * di + 2 * s.d_state)  # in_proj: x, z, B, C
                + d * heads                    # dt proj
                + s.conv_width * (di + 2 * s.d_state)
                + 2 * heads                    # A_log, D
                + di * d                       # out_proj
            )
        if self.moe is not None:
            e = self.moe
            per_layer += d * e.num_experts  # router
            per_layer += 3 * d * self.d_ff * (e.num_experts + e.num_shared)
        elif self.d_ff > 0:
            per_layer += 3 * d * self.d_ff  # SwiGLU
        per_layer += 2 * d  # norms
        emb = v * d * (1 if self.tie_embeddings else 2)
        return L * per_layer + emb + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        d = self.d_model
        skipped = 3 * d * self.d_ff * (e.num_experts - e.top_k)
        return self.param_count() - self.num_layers * skipped


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Framework-level GPULZ integration knobs."""

    checkpoint: bool = True          # GPULZ on checkpoint shards
    checkpoint_symbol_size: int = 4  # fp32 shards
    grad_cross_pod: bool = False     # quantize+LZSS the pod-axis grad exchange
    grad_ratio_cap: float = 2.0      # fixed buffer = quantized_size / cap
    lossy_eb: Optional[float] = None  # error-bounded lossy GRADIENT exchange
                                     # (optim/grad_compress.py lossy-fz path:
                                     # max |g' - g| <= eb per element when the
                                     # slab fits its wire budget); optimizer
                                     # state and checkpoints stay lossless —
                                     # None = the u16-quantize legacy path
    kv_eviction: bool = False        # compress cold KV blocks on eviction
    lz_backend: str = "auto"         # compressor backend registry key
                                     # (core/pipeline.py); "auto" = the
                                     # single-kernel fused-mono compressor
                                     # on TPU, unfused xla elsewhere
    lz_decoder: str = "auto"         # decode registry key; "auto" = fused
                                     # Pallas decoder on TPU, xla-parallel
                                     # elsewhere


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatches: int = 1            # gradient accumulation
    remat: str = "full"              # full | dots | none
    unroll_layers: bool = False      # python layer loop (dry-run cost mode)
    fsdp: str = "on"                 # on | off | auto (by model size)
    seq_parallel: bool = False       # Megatron SP on the residual stream
    zero_opt_state: bool = True      # shard opt state over data axis too
    seed: int = 0
    compression: CompressionConfig = CompressionConfig()
