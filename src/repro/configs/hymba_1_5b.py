"""hymba-1.5b — hybrid: parallel attention + mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Hymba details kept: sliding-window attention everywhere except 3 global
layers (first/middle/last).  Meta tokens are omitted (DESIGN.md §7).
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    mixer="hybrid",
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, conv_width=4, chunk=256),
    sliding_window=1024,
    global_attn_layers=(0, 15, 31),
    rope_theta=10_000.0,
    subquadratic=True,  # SWA + 3 global layers: long_500k decode is feasible
)
