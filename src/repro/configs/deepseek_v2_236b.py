"""deepseek-v2-236b — MoE with multi-head latent attention [arXiv:2405.04434].

60L d_model=5120 128H, MLA kv_lora=512, 2 shared + 160 routed experts top-6,
expert d_ff=1536, vocab=102400.  Per the assignment spec all layers are MoE
(the HF release keeps layer 0 dense — noted deviation, spec-driven).
"""

from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,   # MLA: latent cache, kv head count unused in params
    d_ff=1536,
    vocab_size=102_400,
    mixer="mla",
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(num_experts=160, top_k=6, num_shared=2),
    rope_theta=10_000.0,
)
