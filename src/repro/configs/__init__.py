"""Config registry: ``get_config("<arch>")`` + the assigned 40-cell matrix."""

from __future__ import annotations

import dataclasses

from repro.configs import (
    chameleon_34b,
    deepseek_7b,
    deepseek_v2_236b,
    hymba_1_5b,
    llama3_2_1b,
    llama3_8b,
    llama4_scout_17b_a16e,
    mamba2_2_7b,
    musicgen_medium,
    phi3_medium_14b,
)
from repro.configs.base import (
    SHAPES,
    CompressionConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    TrainConfig,
)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        deepseek_7b,
        llama3_8b,
        phi3_medium_14b,
        llama3_2_1b,
        hymba_1_5b,
        deepseek_v2_236b,
        llama4_scout_17b_a16e,
        mamba2_2_7b,
        musicgen_medium,
        chameleon_34b,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")
    return SHAPES[name]


def cell_is_runnable(arch: str, shape: str) -> bool:
    """long_500k needs a sub-quadratic decode path (DESIGN.md §7)."""
    if shape == "long_500k":
        return ARCHS[arch].subquadratic
    return True


def all_cells(include_skipped: bool = False):
    for arch in ARCHS:
        for shape in SHAPES:
            if include_skipped or cell_is_runnable(arch, shape):
                yield arch, shape


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    updates = dict(
        num_layers=2,
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        rope_theta=10_000.0,
        model_axis=1,  # no mesh padding in single-device smoke tests
    )
    if cfg.num_heads:
        updates.update(num_heads=4, num_kv_heads=min(cfg.num_kv_heads, 2) or 2,
                       head_dim=16)
    if cfg.mla is not None:
        updates["mla"] = MLAConfig(
            kv_lora_rank=32, q_lora_rank=48, qk_nope_dim=16, qk_rope_dim=8,
            v_head_dim=16,
        )
        updates.update(num_heads=4, num_kv_heads=4, head_dim=16)
    if cfg.ssm is not None:
        updates["ssm"] = SSMConfig(
            d_state=16, head_dim=16, expand=2, conv_width=4, chunk=32
        )
    if cfg.moe is not None:
        updates["moe"] = MoEConfig(
            num_experts=4, top_k=min(cfg.moe.top_k, 2),
            num_shared=cfg.moe.num_shared and 1,
        )
    if cfg.global_attn_layers:
        updates["global_attn_layers"] = (0,)
        updates["sliding_window"] = 16
    return dataclasses.replace(cfg, **updates)
