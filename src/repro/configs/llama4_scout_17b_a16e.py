"""llama4-scout-17b-a16e — MoE 16e top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.  Image tokens come
pre-embedded via the vision stub (early fusion).
"""

from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    moe=MoEConfig(num_experts=16, top_k=1, num_shared=1),
    rope_theta=500_000.0,
    frontend="vision_stub",
)
