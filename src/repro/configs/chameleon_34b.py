"""chameleon-34b — early-fusion VLM over VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8 per assignment spec) d_ff=22016 vocab=65536.
QK-norm kept (chameleon's divergence fix).  The VQ-VAE image tokenizer is a
stub: input_specs() provides pre-tokenized patch embeddings.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65_536,
    qk_norm=True,
    rope_theta=10_000.0,
    frontend="vision_stub",
)
