"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048.  The EnCodec frontend is a
stub: input_specs() provides precomputed frame embeddings; the 4-codebook
delay pattern is collapsed to a single stream (DESIGN.md §7).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    rope_theta=10_000.0,
    frontend="audio_stub",
)
