"""mamba2-2.7b — attention-free SSD (state-space duality) [arXiv:2405.21060].

64L d_model=2560, d_ff=0 (no MLP), vocab=50280, ssm_state=128, headdim 64
=> 80 SSM heads.  Sub-quadratic: runs the long_500k decode cell.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    mixer="ssm",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    subquadratic=True,
)
