"""Shared model components: norms, RoPE, init, param-tree utilities.

Params are plain dict pytrees.  Every initializer returns ``(params, axes)``
where ``axes`` mirrors the param tree with tuples of *logical* axis names
("embed", "heads", "ffn", "experts", "vocab", ...); sharding/rules.py maps
logical axes to mesh axes per arch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def rms_norm(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def qk_head_norm(x, eps):
    """Parameter-free per-head RMS norm (chameleon divergence fix)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, theta: float):
    """Rotate pairs (llama convention: split halves).

    x: (..., T, H, dh); positions: broadcastable to (..., T).
    """
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)  # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, dh/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, dtype, in_axis_size=None, scale=1.0):
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = scale / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


def tree_size_bytes(tree) -> int:
    return sum(
        x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree)
    )
