"""Model facade: abstract shapes for dry-runs + concrete init/apply helpers.

``input_specs`` follows the assignment contract: ShapeDtypeStruct stand-ins
for every model input (weak-type-correct, shardable, no device allocation).
Audio/VLM archs receive precomputed frame/patch embeddings from the modality
frontend stub; text archs receive token ids.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import common, transformer


def init_params(cfg: ModelConfig, seed: int = 0):
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(seed))
    return params


def _trace_init(cfg: ModelConfig):
    """(abstract params, axes) without allocating anything.

    Axes are plain-python metadata, so they are captured by side effect while
    eval_shape traces the initializer.
    """
    box = {}

    def f(key):
        p, a = transformer.init_params(cfg, key)
        box["axes"] = a
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["axes"]


@functools.lru_cache(maxsize=None)
def _trace_init_cached(cfg: ModelConfig):
    return _trace_init(cfg)


def param_axes(cfg: ModelConfig):
    return _trace_init_cached(cfg)[1]


def abstract_params(cfg: ModelConfig):
    return _trace_init_cached(cfg)[0]


def abstract_cache(cfg: ModelConfig, batch: int, seq_len: int):
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, batch, seq_len)
    )


def abstract_paged_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
                         block_tokens: int, pool_blocks=None):
    return jax.eval_shape(
        lambda: transformer.init_paged_cache(
            cfg, batch, seq_len, block_tokens=block_tokens,
            pool_blocks=pool_blocks,
        )
    )


def uses_embedding_frontend(cfg: ModelConfig) -> bool:
    return cfg.frontend in ("audio_stub", "vision_stub")


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs for the batch of a given (arch x shape) cell."""
    b, t = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = common.dtype_of(cfg)
    if shape.kind in ("train", "prefill"):
        if uses_embedding_frontend(cfg):
            # frontend stub supplies frame/patch embeddings; labels are the
            # (audio-code / VQ / text) token targets in the shared vocab.
            return {
                "embeds": jax.ShapeDtypeStruct((b, t, cfg.d_model), dt),
                "labels": jax.ShapeDtypeStruct((b, t), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, t), i32)}
    # decode: one new token against a seq_len cache
    return {
        "tokens": jax.ShapeDtypeStruct((b,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def make_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
    """Concrete synthetic batch matching input_specs (for smoke tests)."""
    key = jax.random.PRNGKey(seed)
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        if s.dtype == jnp.int32 and name in ("tokens", "labels"):
            out[name] = jax.random.randint(key, s.shape, 0, cfg.vocab_size,
                                           dtype=jnp.int32)
        elif s.dtype == jnp.int32:
            out[name] = jnp.zeros(s.shape, jnp.int32)
        else:
            out[name] = jax.random.normal(key, s.shape, jnp.float32).astype(
                s.dtype
            )
    return out
