"""Mamba-2 SSD mixer (state-space duality, arXiv:2405.21060).

Training uses the chunked SSD algorithm: within a chunk the recurrence is
expanded into an attention-like (Q x Q) masked matrix (MXU-friendly matmuls);
across chunks a lax.scan carries the (H, N, P) state.  Decode is the O(1)
recurrent update.  Depthwise causal conv (width 4) on (x, B, C) is kept, with
its own ring state for decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common


def _dims(cfg):
    s = cfg.ssm
    heads = cfg.padded_ssm_heads
    return s, heads, heads * s.head_dim


def init_ssm(key, cfg):
    s, h, di = _dims(cfg)
    d, n, w = cfg.d_model, s.d_state, s.conv_width
    dt = common.dtype_of(cfg)
    ks = common.split_keys(key, 8)
    params = {
        "wx": common.dense_init(ks[0], (d, di), dt),
        "wz": common.dense_init(ks[1], (d, di), dt),
        "wB": common.dense_init(ks[2], (d, n), dt),
        "wC": common.dense_init(ks[3], (d, n), dt),
        "wdt": common.dense_init(ks[4], (d, h), dt),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "conv_w": common.dense_init(ks[5], (w, di + 2 * n), dt, in_axis_size=w),
        "conv_b": jnp.zeros((di + 2 * n,), dt),
        "norm_scale": jnp.ones((h, s.head_dim), dt),
        "wout": common.dense_init(ks[6], (di, d), dt, in_axis_size=di),
    }
    axes = {
        "wx": ("embed", "ssm_inner"),
        "wz": ("embed", "ssm_inner"),
        "wB": ("embed", "state"),
        "wC": ("embed", "state"),
        "wdt": ("embed", "ssm_heads"),
        "dt_bias": ("ssm_heads",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "conv_w": ("conv", "ssm_inner_conv"),
        "conv_b": ("ssm_inner_conv",),
        "norm_scale": ("ssm_heads", "head_dim"),
        "wout": ("ssm_inner", "embed"),
    }
    return params, axes


def _causal_conv(v, kernel, bias):
    """Depthwise causal conv: v (B,T,F), kernel (w,F) -> (B,T,F)."""
    w = kernel.shape[0]
    pad = jnp.pad(v, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(v)
    t = v.shape[1]
    for i in range(w):
        out = out + kernel[i] * lax.slice_in_dim(pad, i, i + t, axis=1)
    return out + bias


def _gated_norm(y, z, scale, eps):
    """y,z: (..., H, P).  y * silu(z) -> per-head RMS norm with scale."""
    g = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * lax.rsqrt(var + eps)) * scale.astype(jnp.float32)


def ssm_forward(params, cfg, x, positions=None, is_global=True):
    """Chunked SSD training/prefill pass.  Returns (out, final_state)."""
    s, h, di = _dims(cfg)
    n, p, q = s.d_state, s.head_dim, s.chunk
    b, t_in, _ = x.shape
    pad = (-t_in) % q
    if pad:  # zero-pad to a whole chunk; padded outputs sliced off below
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    t = t_in + pad
    nk = t // q

    u = jnp.einsum("btd,df->btf", x, params["wx"])
    z = jnp.einsum("btd,df->btf", x, params["wz"])
    bm = jnp.einsum("btd,dn->btn", x, params["wB"])
    cm = jnp.einsum("btd,dn->btn", x, params["wC"])
    conv_in = jnp.concatenate([u, bm, cm], axis=-1)
    conv_out = jax.nn.silu(
        _causal_conv(conv_in, params["conv_w"], params["conv_b"]).astype(
            jnp.float32
        )
    ).astype(x.dtype)
    u, bm, cm = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(
        jnp.einsum("btd,dh->bth", x, params["wdt"]).astype(jnp.float32)
        + params["dt_bias"]
    )  # (B,T,H) fp32
    a = jnp.exp(params["A_log"])  # (H,)
    log_a = -dt * a               # (B,T,H), <= 0

    xc = u.reshape(b, nk, q, h, p)
    bc = bm.reshape(b, nk, q, n)
    cc = cm.reshape(b, nk, q, n)
    dtc = dt.reshape(b, nk, q, h)
    la = jnp.cumsum(log_a.reshape(b, nk, q, h), axis=2)  # inclusive

    # ---- intra-chunk (attention-like masked matmul) ----
    srel = jnp.einsum("bkin,bkjn->bkij", cc, bc,
                      preferred_element_type=jnp.float32)
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]     # (b,nk,i,j,h)
    iq = jnp.arange(q)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    m = jnp.where(causal, jnp.exp(seg), 0.0) * dtc[:, :, None, :, :]
    m = m * srel[:, :, :, :, None]
    y_intra = jnp.einsum("bkijh,bkjhp->bkihp", m.astype(x.dtype), xc)

    # ---- chunk states + inter-chunk recurrence ----
    wj = jnp.exp(la[:, :, -1:, :] - la) * dtc             # (b,nk,q,h)
    g = jnp.einsum("bkjn,bkjh,bkjhp->bkhnp", bc, wj.astype(x.dtype), xc,
                   preferred_element_type=jnp.float32)
    total_decay = jnp.exp(la[:, :, -1, :])                # (b,nk,h)

    def step(st, inp):
        g_k, tk = inp
        return st * tk[..., None, None] + g_k, st

    init = jnp.zeros((b, h, n, p), jnp.float32)
    final_state, prev = lax.scan(
        step, init, (g.swapaxes(0, 1), total_decay.swapaxes(0, 1))
    )
    prev = prev.swapaxes(0, 1)                            # (b,nk,h,n,p)

    y_inter = jnp.einsum("bkin,bkhnp->bkihp", cc, prev.astype(x.dtype))
    y_inter = y_inter * jnp.exp(la)[..., None].astype(x.dtype)

    y = (y_intra + y_inter).reshape(b, t, h, p)
    y = y + params["D"].astype(x.dtype)[None, None, :, None] * u.reshape(
        b, t, h, p
    )
    zi = z.reshape(b, t, h, p)
    out = _gated_norm(y.astype(jnp.float32), zi, params["norm_scale"],
                      cfg.norm_eps).astype(x.dtype)
    out = jnp.einsum("btf,fd->btd", out.reshape(b, t, di), params["wout"])
    return out[:, :t_in], final_state


def init_ssm_cache(cfg, batch, dtype):
    s, h, di = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, di + 2 * s.d_state), dtype),
        "state": jnp.zeros((batch, h, s.d_state, s.head_dim), jnp.float32),
    }


def ssm_decode(params, cfg, cache, x, pos=None, is_global=True):
    """O(1) recurrent decode step.  x: (B,1,d)."""
    s, h, di = _dims(cfg)
    n, p = s.d_state, s.head_dim
    b = x.shape[0]

    u = jnp.einsum("btd,df->btf", x, params["wx"])
    bm = jnp.einsum("btd,dn->btn", x, params["wB"])
    cm = jnp.einsum("btd,dn->btn", x, params["wC"])
    v = jnp.concatenate([u, bm, cm], axis=-1)             # (B,1,F)
    full = jnp.concatenate([cache["conv"], v], axis=1)    # (B,w,F)
    conv = jnp.einsum("bwf,wf->bf", full, params["conv_w"]) + params["conv_b"]
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    u1, b1, c1 = jnp.split(conv, [di, di + n], axis=-1)

    dt = jax.nn.softplus(
        jnp.einsum("btd,dh->bth", x, params["wdt"])[:, 0].astype(jnp.float32)
        + params["dt_bias"]
    )                                                     # (B,H)
    a = jnp.exp(-dt * jnp.exp(params["A_log"]))           # (B,H)
    xh = u1.reshape(b, h, p).astype(jnp.float32)
    state = cache["state"] * a[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", b1.astype(jnp.float32), dt, xh
    )
    y = jnp.einsum("bn,bhnp->bhp", c1.astype(jnp.float32), state)
    y = y + params["D"][None, :, None] * xh
    z = jnp.einsum("btd,df->btf", x, params["wz"])[:, 0].reshape(b, h, p)
    out = _gated_norm(y, z, params["norm_scale"], cfg.norm_eps).astype(x.dtype)
    out = jnp.einsum("bf,fd->bd", out.reshape(b, di), params["wout"])
    return out[:, None, :], {"conv": full[:, 1:], "state": state}
