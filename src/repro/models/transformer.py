"""Unified decoder stack covering all 10 assigned architectures.

One layer implementation, parameterized by ``cfg.mixer``:
  attention        dense llama-family, musicgen, chameleon, llama4-scout
  mla              deepseek-v2 (latent attention)
  ssm              mamba2 (no MLP when d_ff == 0)
  hybrid           hymba (parallel attention + SSM heads, mean-combined)
plus SwiGLU or capacity-MoE feed-forward.

Training/forward scans over stacked layer params (jax.lax.scan + remat) to
keep the HLO small and memory bounded; prefill/decode unroll the layer loop
so per-layer caches may have non-uniform shapes (hymba: 1k-window SWA layers
vs full-length global layers).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention, common, mlp, ssm
from repro.sharding import rules as shrules


# ------------------------------------------------------------------ init


def init_layer(key, cfg):
    d = cfg.d_model
    dt = common.dtype_of(cfg)
    ks = common.split_keys(key, 6)
    params = {"ln1": jnp.ones((d,), dt)}
    axes = {"ln1": ("embed_unsharded",)}
    if cfg.mixer in ("attention", "hybrid"):
        params["attn"], axes["attn"] = attention.init_attention(ks[0], cfg)
    if cfg.mixer == "mla":
        params["mla"], axes["mla"] = attention.init_mla(ks[0], cfg)
    if cfg.mixer in ("ssm", "hybrid"):
        params["ssm"], axes["ssm"] = ssm.init_ssm(ks[1], cfg)
    if cfg.mixer == "hybrid":
        params["ln_ab"] = jnp.ones((d,), dt)
        params["ln_sb"] = jnp.ones((d,), dt)
        axes["ln_ab"] = axes["ln_sb"] = ("embed_unsharded",)
    if cfg.moe is not None:
        params["ln2"] = jnp.ones((d,), dt)
        axes["ln2"] = ("embed_unsharded",)
        params["moe"], axes["moe"] = mlp.init_moe(ks[2], cfg)
    elif cfg.d_ff > 0:
        params["ln2"] = jnp.ones((d,), dt)
        axes["ln2"] = ("embed_unsharded",)
        params["mlp"], axes["mlp"] = mlp.init_swiglu(ks[2], cfg)
    return params, axes


def init_params(cfg, key):
    """Returns (params, axes); layer params stacked (L, ...) for scan."""
    kemb, klayers, kout = jax.random.split(key, 3)
    dt = common.dtype_of(cfg)
    v, d = cfg.padded_vocab, cfg.d_model
    layer_keys = jax.random.split(klayers, cfg.num_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg)[0])(layer_keys)
    _, layer_axes = init_layer(layer_keys[0], cfg)
    layer_axes = jax.tree.map(
        lambda a: ("layers",) + a,
        layer_axes,
        is_leaf=lambda a: isinstance(a, tuple) and all(
            isinstance(s, str) for s in a),
    )
    params = {
        "layers": stacked,
        "ln_f": jnp.ones((d,), dt),
        "embed": common.dense_init(kemb, (v, d), dt, in_axis_size=d),
    }
    axes = {
        "layers": layer_axes,
        "ln_f": ("embed_unsharded",),
        "embed": ("vocab", "embed_out"),
    }
    if not cfg.tie_embeddings:
        params["embed_in"] = common.dense_init(kout, (v, d), dt,
                                               in_axis_size=d)
        axes["embed_in"] = ("vocab_in", "embed_sharded")
    return params, axes


# ------------------------------------------------------------------ layer


def _mixer_forward(lp, cfg, x, positions, is_global):
    """Pre-norm mixer residual.  Returns (x', cacheables)."""
    h = common.rms_norm(x, lp["ln1"], cfg.norm_eps)
    caches = {}
    if cfg.mixer == "attention":
        out, kv = attention.attention_forward(lp["attn"], cfg, h, positions,
                                              is_global)
        caches["attn"] = kv
    elif cfg.mixer == "mla":
        out, kv = attention.mla_forward(lp["mla"], cfg, h, positions)
        caches["mla"] = kv
    elif cfg.mixer == "ssm":
        out, st = ssm.ssm_forward(lp["ssm"], cfg, h)
        caches["ssm"] = st
    elif cfg.mixer == "hybrid":
        a_out, kv = attention.attention_forward(lp["attn"], cfg, h, positions,
                                                is_global)
        s_out, st = ssm.ssm_forward(lp["ssm"], cfg, h)
        caches["attn"], caches["ssm"] = kv, st
        out = 0.5 * (
            common.rms_norm(a_out, lp["ln_ab"], cfg.norm_eps)
            + common.rms_norm(s_out, lp["ln_sb"], cfg.norm_eps)
        )
    else:
        raise ValueError(cfg.mixer)
    return x + out, caches


def _mlp_forward(lp, cfg, x):
    """Pre-norm FFN residual.  Returns (x', aux_loss)."""
    if cfg.moe is not None:
        h = common.rms_norm(x, lp["ln2"], cfg.norm_eps)
        out, aux = mlp.moe_apply(lp["moe"], cfg, h)
        return x + out, aux
    if cfg.d_ff > 0:
        h = common.rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + mlp.swiglu(lp["mlp"], h), 0.0
    return x, 0.0


def layer_forward(lp, cfg, x, positions, is_global):
    x, caches = _mixer_forward(lp, cfg, x, positions, is_global)
    x, aux = _mlp_forward(lp, cfg, x)
    return x, aux, caches


# ---------------------------------------------------------------- forward


def _global_flags(cfg):
    if cfg.sliding_window and cfg.global_attn_layers:
        f = jnp.zeros((cfg.num_layers,), jnp.bool_)
        return f.at[jnp.array(cfg.global_attn_layers)].set(True)
    return jnp.ones((cfg.num_layers,), jnp.bool_)


def embed_tokens(params, cfg, tokens):
    table = params["embed"] if cfg.tie_embeddings else params["embed_in"]
    return jnp.take(table, tokens, axis=0)


def unembed(params, cfg, h):
    return jnp.einsum(
        "btd,vd->btv", h, params["embed"], preferred_element_type=jnp.float32
    )


def _maybe_gather_weights(lp, layer_specs):
    """FSDP: gather this layer's weights over the data axis (inside the scan
    body -> one small per-layer all-gather; re-gathered under remat)."""
    if layer_specs is None:
        return lp

    def one(w, spec):
        try:
            return jax.lax.with_sharding_constraint(w, spec)
        except (RuntimeError, ValueError, TypeError):
            return w

    return jax.tree.map(one, lp, layer_specs)


def forward(params, cfg, *, tokens=None, embeds=None, remat="full",
            unroll=False, compute_specs=None):
    """Full-sequence forward.  Returns (hidden, aux_loss).

    unroll=True replaces lax.scan with a python layer loop (and full, not
    query-blocked, attention).  Numerically identical; used by the dry-run's
    cost extrapolation because XLA's cost_analysis counts while-loop bodies
    once instead of x trip-count.

    compute_specs: optional pytree of PartitionSpecs ({"layers": ...}) giving
    weight layouts during compute (FSDP per-layer gather; sharding/rules.py).
    """
    x = embed_tokens(params, cfg, tokens) if embeds is None else embeds
    x = x.astype(common.dtype_of(cfg))
    x = shrules.constrain_batch(x)  # pin (B->batch axes, T, d) sharding
    t = x.shape[1]
    positions = jnp.arange(t, dtype=jnp.int32)
    layer_specs = None if compute_specs is None else compute_specs["layers"]

    def body(carry, xs):
        x, aux = carry
        lp, is_global = xs
        lp = _maybe_gather_weights(lp, layer_specs)
        x = shrules.constrain_batch(x)
        x, a, _ = layer_forward(lp, cfg, x, positions, is_global)
        return (shrules.constrain_batch(x), aux + a), None

    if remat == "full":
        body = jax.checkpoint(body, policy=None)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )

    flags = _global_flags(cfg)
    if unroll:
        aux = 0.0
        for i in range(cfg.num_layers):
            (x, aux), _ = body((x, aux), (_layer_slice(params, i), flags[i]))
    else:
        (x, aux), _ = lax.scan(body, (x, 0.0), (params["layers"], flags))
    return common.rms_norm(x, params["ln_f"], cfg.norm_eps), aux


# ------------------------------------------------------- prefill / decode


def _layer_slice(params, i):
    return jax.tree.map(lambda a: a[i], params["layers"])


def _cache_len(cfg, layer_idx, seq_len):
    if cfg.sliding_window and layer_idx not in cfg.global_attn_layers:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_cache(cfg, batch, seq_len):
    """Per-layer decode caches (list; shapes may differ per layer)."""
    dt = common.dtype_of(cfg)
    caches = []
    for i in range(cfg.num_layers):
        c = {}
        if cfg.mixer in ("attention", "hybrid"):
            c["attn"] = attention.init_kv_cache(
                cfg, batch, _cache_len(cfg, i, seq_len), dt
            )
        if cfg.mixer == "mla":
            c["mla"] = attention.init_mla_cache(cfg, batch, seq_len, dt)
        if cfg.mixer in ("ssm", "hybrid"):
            c["ssm"] = ssm.init_ssm_cache(cfg, batch, dt)
        caches.append(c)
    return caches


def layer_is_global(cfg, i) -> bool:
    return (not cfg.sliding_window) or (i in cfg.global_attn_layers)


def decode_embed(params, cfg, tokens):
    """Decode-step embedding.  tokens: (B,) int32 -> (B, 1, d)."""
    x = embed_tokens(params, cfg, tokens[:, None])
    return x.astype(common.dtype_of(cfg))


def _decode_tail(lp, cfg, x):
    """Shared FFN residual of one decode layer."""
    if cfg.moe is not None:
        hh = common.rms_norm(x, lp["ln2"], cfg.norm_eps)
        out, _ = mlp.moe_apply(lp["moe"], cfg, hh)
        return x + out
    if cfg.d_ff > 0:
        hh = common.rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + mlp.swiglu(lp["mlp"], hh)
    return x


def decode_layer(lp, cfg, c, x, pos, is_global):
    """One layer of decode_step.  Returns (x', new layer cache).

    The serving engine jits this per layer (layer-streaming paging);
    decode_step runs the identical python body under one jit.
    """
    h = common.rms_norm(x, lp["ln1"], cfg.norm_eps)
    nc = {}
    if cfg.mixer == "attention":
        out, nc["attn"] = attention.attention_decode(
            lp["attn"], cfg, c["attn"], h, pos, is_global
        )
    elif cfg.mixer == "mla":
        out, nc["mla"] = attention.mla_decode(lp["mla"], cfg, c["mla"], h,
                                              pos)
    elif cfg.mixer == "ssm":
        out, nc["ssm"] = ssm.ssm_decode(lp["ssm"], cfg, c["ssm"], h)
    elif cfg.mixer == "hybrid":
        a_out, nc["attn"] = attention.attention_decode(
            lp["attn"], cfg, c["attn"], h, pos, is_global
        )
        s_out, nc["ssm"] = ssm.ssm_decode(lp["ssm"], cfg, c["ssm"], h)
        out = 0.5 * (
            common.rms_norm(a_out, lp["ln_ab"], cfg.norm_eps)
            + common.rms_norm(s_out, lp["ln_sb"], cfg.norm_eps)
        )
    x = x + out
    return _decode_tail(lp, cfg, x), nc


def decode_finish(params, cfg, x):
    """Final norm + unembed of a decode step -> (B, V) logits."""
    h = common.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return unembed(params, cfg, h)[:, 0]


def decode_step(params, cfg, caches, tokens, pos):
    """One decode step.  tokens: (B,) int32; pos: scalar int32 position.

    Returns (logits (B, V), new_caches).
    """
    x = decode_embed(params, cfg, tokens)
    new_caches = []
    for i in range(cfg.num_layers):
        lp = _layer_slice(params, i)
        x, nc = decode_layer(lp, cfg, caches[i], x, pos, layer_is_global(cfg, i))
        new_caches.append(nc)
    return decode_finish(params, cfg, x), new_caches


# ------------------------------------------------------------- paged decode


def init_paged_cache(cfg, batch, seq_len, *, block_tokens, pool_blocks=None,
                     map_all=True):
    """Paged decode state: one shared physical KV pool + per-layer tables.

    Returns {"pool": {"k","v"} (P, block_tokens, KV, dh),
             "tables": (L, B, n_logical) int32 (-1 = unmapped),
             "extra": per-layer list of non-paged state (ssm)}.

    map_all=True builds identity tables (every logical block resident) —
    the drop-in dense-cache replacement.  map_all=False starts fully
    unmapped; a host-side allocator (serving/paging.py) assigns slots.
    """
    if cfg.mixer not in ("attention", "hybrid"):
        raise NotImplementedError(
            f"paged KV supports attention/hybrid mixers, not {cfg.mixer!r} "
            "(MLA latent-cache paging is a ROADMAP follow-up)"
        )
    if seq_len % block_tokens:
        raise ValueError(
            f"seq_len={seq_len} not a multiple of block_tokens={block_tokens}"
        )
    n_logical = seq_len // block_tokens
    total = cfg.num_layers * batch * n_logical
    if pool_blocks is None:
        pool_blocks = total
    dt = common.dtype_of(cfg)
    pool = attention.init_paged_kv_pool(cfg, pool_blocks, block_tokens, dt)
    if map_all:
        if pool_blocks < total:
            raise ValueError(
                f"map_all needs pool_blocks >= {total}, got {pool_blocks}"
            )
        tables = jnp.arange(total, dtype=jnp.int32).reshape(
            cfg.num_layers, batch, n_logical
        )
    else:
        tables = jnp.full((cfg.num_layers, batch, n_logical), -1, jnp.int32)
    extra = []
    for _ in range(cfg.num_layers):
        e = {}
        if cfg.mixer == "hybrid":
            e["ssm"] = ssm.init_ssm_cache(cfg, batch, dt)
        extra.append(e)
    return {"pool": pool, "tables": tables, "extra": extra}


def decode_layer_paged(lp, cfg, pool, table, extra, x, pos, is_global):
    """Paged twin of decode_layer.  Returns (x', pool', extra')."""
    h = common.rms_norm(x, lp["ln1"], cfg.norm_eps)
    ne = {}
    if cfg.mixer == "attention":
        out, pool = attention.paged_attention_decode(
            lp["attn"], cfg, pool, table, h, pos, is_global
        )
    elif cfg.mixer == "hybrid":
        a_out, pool = attention.paged_attention_decode(
            lp["attn"], cfg, pool, table, h, pos, is_global
        )
        s_out, ne["ssm"] = ssm.ssm_decode(lp["ssm"], cfg, extra["ssm"], h)
        out = 0.5 * (
            common.rms_norm(a_out, lp["ln_ab"], cfg.norm_eps)
            + common.rms_norm(s_out, lp["ln_sb"], cfg.norm_eps)
        )
    else:
        raise NotImplementedError(cfg.mixer)
    x = x + out
    return _decode_tail(lp, cfg, x), pool, ne


def decode_step_paged(params, cfg, paged, tokens, pos):
    """One decode step over the paged cache (single-graph twin).

    paged: init_paged_cache state.  Tables pass through unchanged — slot
    assignment is host-side; in-graph work is scatter (new token) + gather
    (attention reads) against the shared pool.
    """
    x = decode_embed(params, cfg, tokens)
    pool = paged["pool"]
    new_extra = []
    for i in range(cfg.num_layers):
        lp = _layer_slice(params, i)
        x, pool, ne = decode_layer_paged(
            lp, cfg, pool, paged["tables"][i], paged["extra"][i], x, pos,
            layer_is_global(cfg, i),
        )
        new_extra.append(ne)
    logits = decode_finish(params, cfg, x)
    return logits, {"pool": pool, "tables": paged["tables"],
                    "extra": new_extra}


def prefill(params, cfg, tokens=None, embeds=None, unroll=False,
            compute_specs=None):
    """Prefill: forward pass + last-position logits (serving path).

    Cache materialization for the decode phase is the decode engine's job
    (serving/engine.py feeds tokens through decode_step for correctness at
    small scale); the compiled prefill graph is the roofline object here.
    """
    h, _ = forward(params, cfg, tokens=tokens, embeds=embeds, remat="none",
                   unroll=unroll, compute_specs=compute_specs)
    return unembed(params, cfg, h[:, -1:, :])[:, 0]


# ------------------------------------------------------------------ loss


def loss_fn(params, cfg, batch, remat="full", unroll=False,
            compute_specs=None):
    """Next-token CE (+ MoE aux + z-loss).  batch: tokens or embeds+labels."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    labels = batch.get("labels", tokens)
    h, aux = forward(params, cfg, tokens=tokens, embeds=embeds, remat=remat,
                     unroll=unroll, compute_specs=compute_specs)
    logits = unembed(params, cfg, h)  # fp32
    logits = shrules.constrain_batch(logits, None, "model")  # (B, T, V/mp)
    logits = logits[:, :-1]
    targets = labels[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    mask = (targets >= 0).astype(jnp.float32)
    ce = jnp.sum((logz - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    z_loss = 1e-4 * jnp.sum(jnp.square(logz) * mask) / jnp.maximum(
        jnp.sum(mask), 1.0
    )
    total = ce + z_loss + aux
    return total, {"loss": total, "ce": ce, "aux": aux, "z": z_loss}
