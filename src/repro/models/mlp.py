"""Feed-forward mixers: SwiGLU and capacity-based MoE (GShard-style dropped
routing with sort-based dispatch — the production dropped-token regime).

MoE dispatch avoids the (tokens, E, capacity) one-hot einsum (infeasible at
1M tokens x 160 experts): slots are sorted by expert id, each slot's position
within its expert computed from the sorted order, slots beyond capacity
dropped, and tokens scattered into an (E, capacity, d) buffer that is sharded
experts->model, capacity->data.  Expert FFNs run as batched einsums.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common

MOE_AUX_ALPHA = 0.01


def _constrain(x, *spec):
    """Sharding hint that degrades to a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (RuntimeError, ValueError, TypeError):
        return x


def init_swiglu(key, cfg, d_ff=None, name_axes="ffn"):
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    dt = common.dtype_of(cfg)
    ks = common.split_keys(key, 3)
    params = {
        "wg": common.dense_init(ks[0], (d, ff), dt),
        "wu": common.dense_init(ks[1], (d, ff), dt),
        "wd": common.dense_init(ks[2], (ff, d), dt, in_axis_size=ff),
    }
    axes = {
        "wg": ("embed", name_axes),
        "wu": ("embed", name_axes),
        "wd": (name_axes, "embed"),
    }
    return params, axes


def swiglu(params, x):
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, params["wg"]))
    u = jnp.einsum("...d,df->...f", x, params["wu"])
    return jnp.einsum("...f,fd->...d", g * u, params["wd"])


def init_moe(key, cfg):
    e = cfg.moe
    d, ff = cfg.d_model, cfg.d_ff
    dt = common.dtype_of(cfg)
    ks = common.split_keys(key, 5)
    params = {
        "router": common.dense_init(ks[0], (d, e.num_experts), jnp.float32),
        "wg": common.dense_init(ks[1], (e.num_experts, d, ff), dt),
        "wu": common.dense_init(ks[2], (e.num_experts, d, ff), dt),
        "wd": common.dense_init(
            ks[3], (e.num_experts, ff, d), dt, in_axis_size=ff
        ),
    }
    axes = {
        "router": ("embed", "experts"),
        "wg": ("experts", "embed", "expert_ffn"),
        "wu": ("experts", "embed", "expert_ffn"),
        "wd": ("experts", "expert_ffn", "embed"),
    }
    if e.num_shared:
        sh, shx = init_swiglu(ks[4], cfg, d_ff=ff * e.num_shared)
        params["shared"] = sh
        axes["shared"] = shx
    return params, axes


def moe_capacity(n_tokens: int, cfg) -> int:
    e = cfg.moe
    cap = int(n_tokens * e.top_k * e.capacity_factor / e.num_experts)
    return max(8, -(-cap // 8) * 8)


# Dispatch strategy (§Perf lever).  "local": tokens are routed *per data
# shard* (vmap over a leading data-shard dim), so the dispatch scatter/gather
# never crosses the data axis — the only MoE collective left is the combine
# reduction over the model axis.  "global": single global dispatch buffer
# (iteration-0 baseline; XLA partitions the cross-shard scatter poorly —
# ~100x more collective bytes, see EXPERIMENTS.md §Perf).
DISPATCH = "local"


def _dispatch_one(xf, params, cfg, cap):
    """Sort-based dropped dispatch for one token shard. xf: (n, d)."""
    e = cfg.moe
    n, d = xf.shape
    k = e.top_k

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (n, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch/GShard form)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, e.num_experts), axis=1), axis=0
    ) / k
    aux = MOE_AUX_ALPHA * e.num_experts * jnp.sum(me * ce)

    # sort-based position-in-expert
    slot_expert = expert_idx.reshape(-1)                      # (n*k,)
    slot_token = jnp.arange(n * k, dtype=jnp.int32) // k
    order = jnp.argsort(slot_expert)                          # stable
    sorted_e = slot_expert[order]
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_sorted = jnp.arange(n * k, dtype=jnp.int32) - seg_start
    pos = jnp.zeros((n * k,), jnp.int32).at[order].set(pos_sorted)

    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)  # cap => dropped by scatter

    buf = jnp.zeros((e.num_experts, cap, d), xf.dtype)
    buf = buf.at[slot_expert, pos_c].add(
        jnp.where(keep[:, None], xf[slot_token], 0), mode="drop"
    )
    meta = (slot_expert, pos_c, keep, slot_token, gate_vals)
    return buf, meta, aux


def _combine_one(y, meta, n, d, dtype):
    slot_expert, pos_c, keep, slot_token, gate_vals = meta
    cap = y.shape[1]
    gathered = y[slot_expert, jnp.clip(pos_c, 0, cap - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0)
    weighted = gathered * gate_vals.reshape(-1)[:, None].astype(dtype)
    return jnp.zeros((n, d), dtype).at[slot_token].add(weighted)


def moe_apply(params, cfg, x):
    """x: (B, T, d) -> (out, aux_loss).  Dropped routing at static capacity."""
    e = cfg.moe
    b, t, d = x.shape
    n = b * t
    xf = x.reshape(n, d)

    from repro.sharding import rules as shrules

    shards = shrules.data_shard_count() if DISPATCH == "local" else 1
    if n % shards:
        shards = 1
    n_loc = n // shards
    cap = moe_capacity(n_loc, cfg)

    xs = _constrain(xf.reshape(shards, n_loc, d), "data", None, None)
    bufs, metas, auxs = jax.vmap(
        lambda xi: _dispatch_one(xi, params, cfg, cap)
    )(xs)
    # (D, E, cap, d): data-shard major, experts on model — dispatch is local
    bufs = _constrain(bufs, "data", "model", None, None)

    g = jax.nn.silu(jnp.einsum("Decd,edf->Decf", bufs, params["wg"]))
    u = jnp.einsum("Decd,edf->Decf", bufs, params["wu"])
    y = jnp.einsum("Decf,efd->Decd", g * u, params["wd"])
    y = _constrain(y, "data", "model", None, None)

    out = jax.vmap(
        lambda yi, mi: _combine_one(yi, mi, n_loc, d, x.dtype)
    )(y, metas)
    out = _constrain(out, "data", None, None).reshape(n, d)

    if e.num_shared:
        out = out + swiglu(params["shared"], xf)
    return out.reshape(b, t, d), jnp.mean(auxs)
