"""Attention mixers: GQA (with optional sliding window + qk-norm) and
DeepSeek-V2 MLA (expanded for training, absorbed for decode).

Long-sequence forward passes block over queries (lax.scan over q-blocks) so
the (B, H, T, T) score tensor never materializes — peak attention memory is
(B, H, q_block, T) per layer under remat.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common

NEG_INF = -1e30
Q_BLOCK = 512  # block queries above this sequence length (fp32-score budget)
UNROLL_BLOCKS = False  # dry-run cost mode: python loop over q-blocks so
                       # cost_analysis counts every block (see dryrun.py)


# --------------------------------------------------------------------- GQA


def init_attention(key, cfg):
    d, h, kv, dh = cfg.d_model, cfg.padded_heads, cfg.padded_kv_heads, cfg.hd
    dt = common.dtype_of(cfg)
    ks = common.split_keys(key, 4)
    params = {
        "wq": common.dense_init(ks[0], (d, h, dh), dt, in_axis_size=d),
        "wk": common.dense_init(ks[1], (d, kv, dh), dt, in_axis_size=d),
        "wv": common.dense_init(ks[2], (d, kv, dh), dt, in_axis_size=d),
        "wo": common.dense_init(ks[3], (h, dh, d), dt, in_axis_size=h * dh),
    }
    axes = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    return params, axes


def _mask(q_pos, k_pos, is_global, window):
    """Causal (+ optional sliding-window) mask; is_global may be traced."""
    causal = q_pos[:, None] >= k_pos[None, :]
    if window:
        in_window = (q_pos[:, None] - k_pos[None, :]) < window
        keep = causal & (is_global | in_window)
    else:
        keep = causal
    return keep


def _attend(q, k, v, q_pos, k_pos, is_global, window):
    """q: (B,Tq,H,dh)  k,v: (B,Tk,KV,dh)  ->  (B,Tq,H,dh)."""
    b, tq, h, dh = q.shape
    kvh = k.shape[2]
    group = h // kvh
    scale = dh ** -0.5
    qg = q.reshape(b, tq, kvh, group, dh)
    scores = jnp.einsum(
        "btkgd,bskd->bkgts", qg, k, preferred_element_type=jnp.float32
    ) * scale
    keep = _mask(q_pos, k_pos, is_global, window)
    scores = jnp.where(keep[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return out.reshape(b, tq, h, dh)


def attention_forward(params, cfg, x, positions, is_global=True):
    """Training/prefill attention.  Returns (out, (k, v)) — kv for caching."""
    b, t, _ = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    if cfg.qk_norm:
        q = common.qk_head_norm(q, cfg.norm_eps)
        k = common.qk_head_norm(k, cfg.norm_eps)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)

    if t <= Q_BLOCK:
        out = _attend(q, k, v, positions, positions, is_global,
                      cfg.sliding_window)
    else:
        nb = t // Q_BLOCK
        qb = q.reshape(b, nb, Q_BLOCK, *q.shape[2:])
        pb = positions.reshape(nb, Q_BLOCK)
        if UNROLL_BLOCKS:
            outs = jnp.stack([
                _attend(qb[:, i], k, v, pb[i], positions, is_global,
                        cfg.sliding_window)
                for i in range(nb)
            ])
        else:
            def body(_, xs):
                qi, pi = xs
                o = _attend(qi, k, v, pi, positions, is_global,
                            cfg.sliding_window)
                return None, o

            _, outs = lax.scan(body, None, (qb.swapaxes(0, 1), pb))
        out = outs.swapaxes(0, 1).reshape(b, t, *q.shape[2:])
    return jnp.einsum("bthk,hkd->btd", out, params["wo"]), (k, v)


def init_kv_cache(cfg, batch, cache_len, dtype):
    kv = cfg.padded_kv_heads
    cache = {
        "slot_pos": jnp.full((cache_len,), -1, jnp.int32),
    }
    if cfg.kv_quant:
        # int8 cache + per (token, head) scales: ~2x less HBM per decode
        # step read (the decode cells' dominant roofline term)
        cache["k"] = jnp.zeros((batch, cache_len, kv, cfg.hd), jnp.int8)
        cache["v"] = jnp.zeros((batch, cache_len, kv, cfg.hd), jnp.int8)
        cache["k_scale"] = jnp.zeros((batch, cache_len, kv), jnp.float32)
        cache["v_scale"] = jnp.zeros((batch, cache_len, kv), jnp.float32)
    else:
        cache["k"] = jnp.zeros((batch, cache_len, kv, cfg.hd), dtype)
        cache["v"] = jnp.zeros((batch, cache_len, kv, cfg.hd), dtype)
    return cache


def _quantize_kv(x):
    """(B, T, KV, dh) -> (int8 codes, (B, T, KV) scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    codes = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return codes, scale


def _dequantize_kv(codes, scale, dtype):
    return (codes.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _decode_qkv(params, cfg, x, pos):
    """Shared decode-side projections: q/k/v with qk-norm + rope applied.

    k comes back post-rope — both the dense and the paged cache store it
    that way, so a restored block never needs re-roping.
    """
    b = x.shape[0]
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    if cfg.qk_norm:
        q = common.qk_head_norm(q, cfg.norm_eps)
        k = common.qk_head_norm(k, cfg.norm_eps)
    posv = jnp.full((b, 1), pos, jnp.int32)
    q = common.apply_rope(q, posv, cfg.rope_theta)
    k = common.apply_rope(k, posv, cfg.rope_theta)
    return q, k, v


def _decode_attend(q, ck, cv, keep, out_dtype):
    """GQA single-token attention over a gathered cache view.

    q: (B,1,H,dh); ck/cv: (B,S,KV,dh); keep broadcasts against the
    (B,KV,G,S) score tensor.  Masked slots hit NEG_INF before the softmax,
    so their probability underflows to exactly 0.0 — whatever bytes sit in
    an unmapped cache slot contribute exactly nothing to the output.
    """
    b, _, h, dh = q.shape
    kvh = ck.shape[2]
    group = h // kvh
    qg = q.reshape(b, kvh, group, dh)
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qg, ck, preferred_element_type=jnp.float32
    ) * (dh ** -0.5)
    scores = jnp.where(keep, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(out_dtype)
    return jnp.einsum("bkgs,bskd->bkgd", probs, cv).reshape(b, 1, h, dh)


def attention_decode(params, cfg, cache, x, pos, is_global=True):
    """Single-token decode with (ring-buffered, for SWA) KV cache.

    x: (B, 1, d); pos: scalar int32 (current absolute position).
    """
    cache_len = cache["k"].shape[1]
    q, k, v = _decode_qkv(params, cfg, x, pos)  # k stored post-rope

    slot = pos % cache_len  # ring buffer (identity when cache covers all pos)
    new_cache = {}
    if cfg.kv_quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        ckq = lax.dynamic_update_slice_in_dim(cache["k"], kq, slot, axis=1)
        cvq = lax.dynamic_update_slice_in_dim(cache["v"], vq, slot, axis=1)
        cks = lax.dynamic_update_slice_in_dim(cache["k_scale"], ks, slot,
                                              axis=1)
        cvs = lax.dynamic_update_slice_in_dim(cache["v_scale"], vs, slot,
                                              axis=1)
        new_cache.update(k=ckq, v=cvq, k_scale=cks, v_scale=cvs)
        ck = _dequantize_kv(ckq, cks, x.dtype)
        cv = _dequantize_kv(cvq, cvs, x.dtype)
    else:
        ck = lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        cv = lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        new_cache.update(k=ck, v=cv)
    spos = lax.dynamic_update_slice_in_dim(
        cache["slot_pos"], jnp.full((1,), pos, jnp.int32), slot, axis=0
    )
    new_cache["slot_pos"] = spos

    valid = (spos >= 0) & (spos <= pos)
    if cfg.sliding_window:
        in_win = (pos - spos) < cfg.sliding_window
        valid = valid & (is_global | in_win)
    out = _decode_attend(q, ck, cv, valid[None, None, None], x.dtype)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, new_cache


# --------------------------------------------------------------- paged GQA


def init_paged_kv_pool(cfg, pool_blocks, block_tokens, dtype):
    """Physical KV block pool shared by every layer and sequence.

    Slots are (block_tokens, KV, dh) tiles addressed by per-(layer, seq)
    block tables; a slot's contents are garbage until a table maps it.
    """
    if cfg.kv_quant:
        raise NotImplementedError(
            "paged KV does not support kv_quant (int8 cache); "
            "use the dense cache or disable kv_quant"
        )
    kv = cfg.padded_kv_heads
    return {
        "k": jnp.zeros((pool_blocks, block_tokens, kv, cfg.hd), dtype),
        "v": jnp.zeros((pool_blocks, block_tokens, kv, cfg.hd), dtype),
    }


def paged_attention_decode(params, cfg, pool, table, x, pos, is_global=True):
    """Single-token decode reading K/V through a block table.

    pool: {"k","v"} of (P, block_tokens, KV, dh); table: (B, n_logical)
    int32 physical slot ids, -1 = unmapped.  The block holding ``pos`` must
    be mapped (the host allocator guarantees it).  Writes the new token into
    its slot, then attends over the gathered logical view; unmapped or
    future slots mask to exactly zero probability, so stale pool contents
    never reach the output (decode_attend masks pre-softmax at NEG_INF).
    """
    b = x.shape[0]
    bt = pool["k"].shape[1]
    n_logical = table.shape[1]
    kvh, dh = pool["k"].shape[2], pool["k"].shape[3]
    q, k, v = _decode_qkv(params, cfg, x, pos)  # k stored post-rope

    phys = table[jnp.arange(b), pos // bt]
    kp = pool["k"].at[phys, pos % bt].set(k[:, 0])
    vp = pool["v"].at[phys, pos % bt].set(v[:, 0])

    safe = jnp.maximum(table, 0)  # gather through slot 0 for unmapped rows
    ck = kp[safe].reshape(b, n_logical * bt, kvh, dh)
    cv = vp[safe].reshape(b, n_logical * bt, kvh, dh)
    t_idx = jnp.arange(n_logical * bt)  # logical slot index == position
    valid = jnp.repeat(table >= 0, bt, axis=1) & (t_idx <= pos)[None]
    if cfg.sliding_window:
        in_win = (pos - t_idx) < cfg.sliding_window
        valid = valid & (is_global | in_win[None])
    out = _decode_attend(q, ck, cv, valid[:, None, None, :], x.dtype)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, {"k": kp, "v": vp}


# --------------------------------------------------------------------- MLA


def init_mla(key, cfg):
    m = cfg.mla
    d, h = cfg.d_model, cfg.padded_heads
    dt = common.dtype_of(cfg)
    ks = common.split_keys(key, 6)
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    params = {
        "wdq": common.dense_init(ks[0], (d, m.q_lora_rank), dt),
        "wuq": common.dense_init(ks[1], (m.q_lora_rank, h, qk_dim), dt,
                                 in_axis_size=m.q_lora_rank),
        "wdkv": common.dense_init(ks[2], (d, m.kv_lora_rank), dt),
        "wkr": common.dense_init(ks[3], (d, m.qk_rope_dim), dt),
        "wukv": common.dense_init(
            ks[4], (m.kv_lora_rank, h, m.qk_nope_dim + m.v_head_dim), dt,
            in_axis_size=m.kv_lora_rank),
        "wo": common.dense_init(ks[5], (h, m.v_head_dim, d), dt,
                                in_axis_size=h * m.v_head_dim),
    }
    axes = {
        "wdq": ("embed", "lora"),
        "wuq": ("lora", "heads", "head_dim"),
        "wdkv": ("embed", "lora"),
        "wkr": ("embed", "head_dim"),
        "wukv": ("lora", "heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    return params, axes


def mla_forward(params, cfg, x, positions, is_global=True):
    """Training/prefill MLA (expanded form). Returns (out, (c_kv, k_rope))."""
    m = cfg.mla
    b, t, _ = x.shape
    cq = jnp.einsum("btd,dr->btr", x, params["wdq"])
    q = jnp.einsum("btr,rhk->bthk", cq, params["wuq"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    c_kv = jnp.einsum("btd,dr->btr", x, params["wdkv"])
    k_rope = jnp.einsum("btd,dr->btr", x, params["wkr"])[:, :, None, :]
    k_rope = common.apply_rope(k_rope, positions, cfg.rope_theta)
    kv = jnp.einsum("btr,rhk->bthk", c_kv, params["wukv"])
    k_nope, v = jnp.split(kv, [m.qk_nope_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (*k_nope.shape[:3], m.qk_rope_dim))],
        axis=-1,
    )

    b_, t_ = x.shape[:2]
    if t_ <= Q_BLOCK:
        out = _attend_mha(q, k, v, positions, positions)
    else:
        nb = t_ // Q_BLOCK
        qb = q.reshape(b_, nb, Q_BLOCK, *q.shape[2:]).swapaxes(0, 1)
        pb = positions.reshape(nb, Q_BLOCK)
        if UNROLL_BLOCKS:
            outs = jnp.stack(
                [_attend_mha(qb[i], k, v, pb[i], positions)
                 for i in range(nb)]
            )
        else:
            def body(_, xs):
                qi, pi = xs
                return None, _attend_mha(qi, k, v, pi, positions)

            _, outs = lax.scan(body, None, (qb, pb))
        out = outs.swapaxes(0, 1).reshape(b_, t_, *q.shape[2:3], m.v_head_dim)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, (c_kv, k_rope[:, :, 0, :])


def _attend_mha(q, k, v, q_pos, k_pos):
    dh = q.shape[-1]
    scores = jnp.einsum(
        "bthd,bshd->bhts", q, k, preferred_element_type=jnp.float32
    ) * (dh ** -0.5)
    keep = q_pos[:, None] >= k_pos[None, :]
    scores = jnp.where(keep[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhts,bshd->bthd", probs, v)


def init_mla_cache(cfg, batch, cache_len, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, m.qk_rope_dim), dtype),
    }


def mla_decode(params, cfg, cache, x, pos, is_global=True):
    """Absorbed single-token MLA decode: attention in the latent space.

    The up-projections fold into the query/output (DeepSeek-V2 §2.1.2), so the
    cache stays (kv_lora + rope_dim) per token — this is why MLA decode reads
    ~9x fewer cache bytes than GQA at kv=128 heads.
    """
    m = cfg.mla
    b = x.shape[0]
    cq = jnp.einsum("btd,dr->btr", x, params["wdq"])
    q = jnp.einsum("btr,rhk->bthk", cq, params["wuq"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    posv = jnp.full((b, 1), pos, jnp.int32)
    q_rope = common.apply_rope(q_rope, posv, cfg.rope_theta)

    c_kv_new = jnp.einsum("btd,dr->btr", x, params["wdkv"])
    k_rope_new = jnp.einsum("btd,dr->btr", x, params["wkr"])[:, :, None, :]
    k_rope_new = common.apply_rope(k_rope_new, posv, cfg.rope_theta)[:, :, 0, :]

    c_kv = lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv_new, pos, axis=1)
    k_rope = lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new, pos, axis=1
    )

    wuk = params["wukv"][..., : m.qk_nope_dim]      # (r, h, nope)
    wuv = params["wukv"][..., m.qk_nope_dim:]       # (r, h, v)
    q_abs = jnp.einsum("bthk,rhk->bthr", q_nope, wuk)  # latent-space query
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    scores = (
        jnp.einsum("bthr,bsr->bhts", q_abs, c_kv,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bthk,bsk->bhts", q_rope, k_rope,
                     preferred_element_type=jnp.float32)
    ) * scale
    t_idx = jnp.arange(c_kv.shape[1])
    scores = jnp.where((t_idx <= pos)[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhts,bsr->bthr", probs, c_kv)
    out = jnp.einsum("bthr,rhk->bthk", ctx, wuv)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, {"c_kv": c_kv, "k_rope": k_rope}
