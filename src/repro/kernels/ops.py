"""Jitted public wrappers for the Pallas kernels.

On non-TPU backends the kernels run in interpret mode (Python execution of the
kernel body) so the whole framework — including the `pallas-match`, `fused`,
`fused-deflate` and `fused-mono` pipeline backends (core/pipeline.py) — is
testable on CPU.  On TPU they compile via Mosaic.

Every wrapper takes ``chunks_per_block=None`` by default: the block geometry
then resolves through core/autotune.py (per-architecture tuned cache on TPU,
the deterministic static fallback elsewhere / under ``REPRO_AUTOTUNE=0``).
Passing an explicit integer pins the geometry and bypasses the autotuner.
"""

from __future__ import annotations

import jax

from repro.core import autotune
from repro.kernels import lz_bitshuffle as _bshuf_impl
from repro.kernels import lz_decode as _dec_impl
from repro.kernels import lz_decode_mono as _dmono_impl
from repro.kernels import lz_entropy as _ent_impl
from repro.kernels import lz_fused as _mono_impl
from repro.kernels import lz_match as _impl
from repro.kernels import lz_scatter as _scat_impl


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _blocks(chunks_per_block, *, symbol_size, chunk_symbols, direction, window=0):
    """Resolve a ``chunks_per_block=None`` default through the autotuner."""
    if chunks_per_block is not None:
        return chunks_per_block
    return autotune.block_geometry(
        symbol_size=symbol_size,
        chunk_symbols=chunk_symbols,
        direction=direction,
        window=window,
    )


def lz_match(
    symbols, *, window, max_len=_impl.MAX_LEN_CAP, chunks_per_block=None
):
    """(nc, C) int32 symbols -> (lengths, offsets)."""
    return _impl.lz_match_pallas(
        symbols,
        window=window,
        max_len=max_len,
        chunks_per_block=_blocks(
            chunks_per_block,
            symbol_size=4,
            chunk_symbols=symbols.shape[1],
            direction="compress",
            window=window,
        ),
        interpret=_interpret(),
    )


def lz_kernel1(
    symbols,
    *,
    window,
    min_match,
    symbol_size,
    max_len=_impl.MAX_LEN_CAP,
    chunks_per_block=None,
):
    """Fused Kernel I (match + select + local prefix sum)."""
    return _impl.lz_kernel1_pallas(
        symbols,
        window=window,
        min_match=min_match,
        symbol_size=symbol_size,
        max_len=max_len,
        chunks_per_block=_blocks(
            chunks_per_block,
            symbol_size=symbol_size,
            chunk_symbols=symbols.shape[1],
            direction="compress",
            window=window,
        ),
        interpret=_interpret(),
    )


def lz_scatter(
    symbols,
    lengths,
    offsets,
    emitted,
    use_match,
    local_off,
    n_tokens,
    payload_sizes,
    *,
    symbol_size,
    cap,
    sec_flags,
    chunks_per_block=None,
):
    """Fused Kernel II+III (global offsets + deflate-scatter).

    Returns ``(blob, flag_total, pay_total)``: a (cap,) int32 byte buffer
    holding the deflated flag/payload sections (header region left zero)
    plus the two traced section totals.
    """
    return _scat_impl.lz_scatter_pallas(
        symbols,
        lengths,
        offsets,
        emitted,
        use_match,
        local_off,
        n_tokens,
        payload_sizes,
        symbol_size=symbol_size,
        cap=cap,
        sec_flags=sec_flags,
        chunks_per_block=_blocks(
            chunks_per_block,
            symbol_size=symbol_size,
            chunk_symbols=symbols.shape[1],
            direction="compress",
        ),
        interpret=_interpret(),
    )


def lz_fused_mono(
    symbols,
    *,
    window,
    min_match,
    symbol_size,
    cap,
    sec_flags,
    max_len=_impl.MAX_LEN_CAP,
    chunks_per_block=None,
):
    """Single-kernel compressor (Kernels I+II+III folded, tiled output).

    Returns ``(blob, n_tokens, payload_sizes, flag_total, pay_total)``: one
    Pallas launch produces the deflated flag/payload sections of a container
    (header region left zero) plus the per-chunk tables and section totals.
    """
    return _mono_impl.lz_fused_mono_pallas(
        symbols,
        window=window,
        min_match=min_match,
        symbol_size=symbol_size,
        cap=cap,
        sec_flags=sec_flags,
        max_len=max_len,
        chunks_per_block=_blocks(
            chunks_per_block,
            symbol_size=symbol_size,
            chunk_symbols=symbols.shape[1],
            direction="compress",
            window=window,
        ),
        interpret=_interpret(),
    )


def lz_decode(
    flag_bytes, payload, n_tokens, *, symbol_size, chunks_per_block=None
):
    """Fused decoder (flag scan + payload gather + copy resolution)."""
    return _dec_impl.lz_decode_pallas(
        flag_bytes,
        payload,
        n_tokens,
        symbol_size=symbol_size,
        chunks_per_block=_blocks(
            chunks_per_block,
            symbol_size=symbol_size,
            chunk_symbols=flag_bytes.shape[1] * 8,
            direction="decompress",
        ),
        interpret=_interpret(),
    )


def byte_histogram(buf, start, length):
    """(n,) int32 byte buffer -> (256,) counts of [start, start+length).

    The entropy stage's code-length front end (core/entropy.py); the
    sequential-grid Pallas reduction, identical counts to the XLA
    scatter-add fallback by test."""
    return _ent_impl.byte_histogram_pallas(
        buf, start, length, interpret=_interpret()
    )


def huffman_gap_decode(blob, wstarts, rems, first, count, base, order, *, sub):
    """Gap-array parallel canonical-Huffman bitstream decode (one launch).

    See kernels/lz_entropy.py; block geometry is fixed (8 sub-block lanes
    per grid step) — sub-block windows are DMA-width-bound, not
    VMEM-budget-bound like the LZSS kernels, so the autotuner is not
    consulted here."""
    return _ent_impl.huffman_gap_decode_pallas(
        blob,
        wstarts,
        rems,
        first,
        count,
        base,
        order,
        sub=sub,
        interpret=_interpret(),
    )


def bitshuffle(units):
    """(N,) uint16 -> (2N,) uint8 bit-plane transpose (lossy-fz frontend).

    Fixed per-block geometry (512-unit blocks, 8 blocks per grid step) —
    a pure permutation with no VMEM-budget trade-off, so the autotuner is
    not consulted."""
    return _bshuf_impl.bitshuffle_pallas(units, interpret=_interpret())


def bitunshuffle(shuffled):
    """(2N,) uint8 -> (N,) uint16 inverse bit-plane transpose."""
    return _bshuf_impl.bitunshuffle_pallas(shuffled, interpret=_interpret())


def lz_decode_mono(
    blob,
    n_tokens,
    payload_sizes,
    *,
    symbol_size,
    chunk_symbols,
    n_chunks,
    chunks_per_block=None,
):
    """Single-launch decoder: whole container blob -> (nc, C) symbols.

    The flag/payload section gathers are fused into the decode kernel via
    scalar-prefetched per-chunk offsets — no ``deflate.gather_section``."""
    return _dmono_impl.lz_decode_mono_pallas(
        blob,
        n_tokens,
        payload_sizes,
        symbol_size=symbol_size,
        chunk_symbols=chunk_symbols,
        n_chunks=n_chunks,
        chunks_per_block=_blocks(
            chunks_per_block,
            symbol_size=symbol_size,
            chunk_symbols=chunk_symbols,
            direction="decompress",
        ),
        interpret=_interpret(),
    )
