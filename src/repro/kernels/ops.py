"""Jitted public wrappers for the Pallas kernels.

On non-TPU backends the kernels run in interpret mode (Python execution of the
kernel body) so the whole framework — including the `pallas-match`, `fused`
and `fused-deflate` pipeline backends (core/pipeline.py) — is testable on
CPU.  On TPU they compile via Mosaic.
"""

from __future__ import annotations

import jax

from repro.kernels import lz_decode as _dec_impl
from repro.kernels import lz_fused as _mono_impl
from repro.kernels import lz_match as _impl
from repro.kernels import lz_scatter as _scat_impl


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def lz_match(symbols, *, window, max_len=_impl.MAX_LEN_CAP, chunks_per_block=8):
    """(nc, C) int32 symbols -> (lengths, offsets)."""
    return _impl.lz_match_pallas(
        symbols,
        window=window,
        max_len=max_len,
        chunks_per_block=chunks_per_block,
        interpret=_interpret(),
    )


def lz_kernel1(
    symbols,
    *,
    window,
    min_match,
    symbol_size,
    max_len=_impl.MAX_LEN_CAP,
    chunks_per_block=8,
):
    """Fused Kernel I (match + select + local prefix sum)."""
    return _impl.lz_kernel1_pallas(
        symbols,
        window=window,
        min_match=min_match,
        symbol_size=symbol_size,
        max_len=max_len,
        chunks_per_block=chunks_per_block,
        interpret=_interpret(),
    )


def lz_scatter(
    symbols,
    lengths,
    offsets,
    emitted,
    use_match,
    local_off,
    n_tokens,
    payload_sizes,
    *,
    symbol_size,
    cap,
    sec_flags,
    chunks_per_block=8,
):
    """Fused Kernel II+III (global offsets + deflate-scatter).

    Returns ``(blob, flag_total, pay_total)``: a (cap,) int32 byte buffer
    holding the deflated flag/payload sections (header region left zero)
    plus the two traced section totals.
    """
    return _scat_impl.lz_scatter_pallas(
        symbols,
        lengths,
        offsets,
        emitted,
        use_match,
        local_off,
        n_tokens,
        payload_sizes,
        symbol_size=symbol_size,
        cap=cap,
        sec_flags=sec_flags,
        chunks_per_block=chunks_per_block,
        interpret=_interpret(),
    )


def lz_fused_mono(
    symbols,
    *,
    window,
    min_match,
    symbol_size,
    cap,
    sec_flags,
    max_len=_impl.MAX_LEN_CAP,
    chunks_per_block=8,
):
    """Single-kernel compressor (Kernels I+II+III folded, tiled output).

    Returns ``(blob, n_tokens, payload_sizes, flag_total, pay_total)``: one
    Pallas launch produces the deflated flag/payload sections of a container
    (header region left zero) plus the per-chunk tables and section totals.
    """
    return _mono_impl.lz_fused_mono_pallas(
        symbols,
        window=window,
        min_match=min_match,
        symbol_size=symbol_size,
        cap=cap,
        sec_flags=sec_flags,
        max_len=max_len,
        chunks_per_block=chunks_per_block,
        interpret=_interpret(),
    )


def lz_decode(flag_bytes, payload, n_tokens, *, symbol_size, chunks_per_block=8):
    """Fused decoder (flag scan + payload gather + copy resolution)."""
    return _dec_impl.lz_decode_pallas(
        flag_bytes,
        payload,
        n_tokens,
        symbol_size=symbol_size,
        chunks_per_block=chunks_per_block,
        interpret=_interpret(),
    )
