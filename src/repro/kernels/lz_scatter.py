"""Pallas TPU kernels for fused GPULZ deflating (Kernels II+III).

After Kernel I, the XLA pipeline tail still stages every intermediate
through HBM as separate ops (the paper's workflow (c)):
``deflate.pack_flags`` and ``deflate.build_chunk_payloads`` materialize the
(nc, C//8) flag and (nc, C*S) payload sections, ``deflate.global_offsets``
runs two XLA cumsums (Kernel II), and ``deflate.scatter_section`` re-reads
both sections to assemble the blob (Kernel III).  This module fuses that
whole emit path (workflow (d); cf. the stream-compaction lesson of
Sitaridi et al., *Massively-Parallel Lossless Data Decompression*): the
compressed sections are rebuilt in VMEM per chunk block straight from the
Kernel-I outputs and written to the output blob exactly once — the aligned
(nc, C//8) / (nc, C*S) section arrays never exist in HBM.

Two passes, mirroring the paper's Kernel II -> III split:

  pass 1 (``_offsets_kernel``)   ONE kernel computes BOTH exclusive prefix
      sums over the per-chunk flag/payload sizes (the paper calls CUB
      ``DeviceScan::ExclusiveSum`` twice) via lane-shift doubling, plus the
      two section totals; payload offsets come out pre-based past the flag
      section so pass 2 needs no extra scalar math.
  pass 2 (``_scatter_kernel``)   per chunk block, rebuilds the flag bytes
      and payload bytes in VMEM from the Kernel-I arrays (a rank->position
      binary search — the gather-friendly inverse of ``pack_flags``'s
      scatter-add, which has no efficient Mosaic lowering) and blends each
      chunk's compact prefix into the output blob at its global offset.
      The per-chunk offsets ride in as scalar-prefetch operands
      (``pltpu.PrefetchScalarGridSpec``), so every dynamic store address is
      an SMEM scalar read; the blob block is revisited across the grid and
      written back to HBM once.

Like the other kernels, correctness is validated in interpret mode against
the XLA tail (tests/test_kernels.py); byte-identity of full containers is
enforced by tests/test_pipeline.py.  Real-TPU caveats (VMEM residency of
the whole blob, Mosaic dynamic-lane-slice lowering) are tracked in
ROADMAP.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lz_decode import _ceil_log2, _prefix_sum_excl, _search_last_le


# ---------------------------------------------------- pass 1: Kernel II


def _offsets_kernel(nt_ref, ps_ref, fo_ref, po_ref, tot_ref, *, nc):
    _, n = nt_ref.shape
    idx = lax.broadcasted_iota(jnp.int32, (1, n), 1)
    fs = (nt_ref[...] + 7) // 8
    ps = ps_ref[...]
    f_excl = _prefix_sum_excl(fs, idx, n)
    p_excl = _prefix_sum_excl(ps, idx, n)
    f_tot = f_excl[0, nc - 1] + fs[0, nc - 1]
    p_tot = p_excl[0, nc - 1] + ps[0, nc - 1]
    fo_ref[...] = f_excl
    # payload offsets pre-based past the flag section
    po_ref[...] = p_excl + f_tot
    tot_ref[...] = jnp.where(idx == 0, f_tot, jnp.where(idx == 1, p_tot, 0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def lz_global_offsets_pallas(n_tokens, payload_sizes, *, interpret=False):
    """Fused Kernel II: (nc,) per-chunk sizes -> global section offsets.

    Returns ``(flag_off, pay_off, flag_total, pay_total)``: both exclusive
    prefix sums computed in ONE kernel (flag sizes are derived from
    ``n_tokens`` in-kernel); ``pay_off`` is pre-based past the flag section
    (``flag_total + excl_cumsum(payload_sizes)``).  The offset vectors come
    back at the kernel's 128-lane padding (>= nc); a consumer indexing past
    that (a different grid padding) must extend them itself — see
    ``lz_scatter_pallas``.
    """
    nt = n_tokens.astype(jnp.int32)
    ps = payload_sizes.astype(jnp.int32)
    nc = nt.shape[0]
    npad = -(-nc // 128) * 128
    pad = npad - nc
    if pad:
        nt = jnp.concatenate([nt, jnp.zeros((pad,), jnp.int32)])
        ps = jnp.concatenate([ps, jnp.zeros((pad,), jnp.int32)])
    spec = pl.BlockSpec((1, npad), lambda: (0, 0))
    fo, po, tot = pl.pallas_call(
        functools.partial(_offsets_kernel, nc=nc),
        in_specs=[spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((1, npad), jnp.int32)] * 3,
        interpret=interpret,
    )(nt.reshape(1, npad), ps.reshape(1, npad))
    return fo[0], po[0], tot[0, 0], tot[0, 1]


# --------------------------------------- pass 2: encode tail + Kernel III


def _build_sections(
    sym, lengths, offsets, emitted, um, local_off, ntok, psz, *, symbol_size
):
    """Rebuild the per-chunk compact section bytes from Kernel-I outputs.

    All inputs are int32 values: (g, C) per-position arrays plus the (g,)
    per-chunk ``ntok``/``psz`` reductions.  Returns ``(flag_bytes (g, C//8),
    payload (g, C*S))`` with zeros past each chunk's live size — everything
    stays in registers/VMEM (rank->position binary search instead of
    ``pack_flags``'s scatter-add, which has no efficient Mosaic lowering).
    Shared by the deflate-scatter kernel below and the single-kernel
    compressor (lz_fused.py).
    """
    g, c = sym.shape
    s = symbol_size
    cb = c // 8
    bufsz = c * s
    t = lax.broadcasted_iota(jnp.int32, (g, c), 1)

    # token rank -> chunk position: ranks[i] = tokens before position i is
    # nondecreasing, so the position of rank r is the last i with
    # ranks[i] <= r (pack_flags computes the same map as a scatter-add).
    ranks = _prefix_sum_excl(emitted, t, c)
    tok_pos = _search_last_le(ranks, t, c)

    valid_r = (t < ntok[:, None]).astype(jnp.int32)
    fbit = jnp.take_along_axis(um, tok_pos, axis=1) * valid_r

    # flag bytes: bit j of byte b is token (8b+j)'s kind (format.py layout)
    bidx = lax.broadcasted_iota(jnp.int32, (g, cb), 1)
    fbyte = jnp.zeros((g, cb), jnp.int32)
    for j in range(8):
        fbyte = fbyte + (jnp.take_along_axis(fbit, 8 * bidx + j, axis=1) << j)

    # token write offsets in rank space (sentinel bufsz keeps the row
    # sorted past n_tokens), then payload byte p -> covering token
    lo_r = jnp.take_along_axis(local_off, tok_pos, axis=1)
    tok_off = jnp.where(valid_r == 1, lo_r, bufsz)
    p = lax.broadcasted_iota(jnp.int32, (g, bufsz), 1)
    r_of_p = _search_last_le(tok_off, p, c)
    i_p = jnp.take_along_axis(tok_pos, r_of_p, axis=1)
    b_p = p - jnp.take_along_axis(tok_off, r_of_p, axis=1)
    um_p = jnp.take_along_axis(um, i_p, axis=1)
    ptr = jnp.where(
        b_p == 0,
        jnp.take_along_axis(lengths, i_p, axis=1),
        jnp.take_along_axis(offsets, i_p, axis=1),
    )
    sym_p = jnp.take_along_axis(sym, i_p, axis=1)
    lit = (sym_p >> (8 * jnp.clip(b_p, 0, 3))) & 0xFF
    val = jnp.where(um_p == 1, ptr, lit)
    prow = jnp.where(p < psz[:, None], val, 0)
    return fbyte, prow


def _scatter_kernel(
    fo_ref,
    po_ref,
    sym_ref,
    len_ref,
    off_ref,
    emit_ref,
    um_ref,
    lo_ref,
    nt_ref,
    ps_ref,
    out_ref,
    *,
    symbol_size,
    sec_flags,
    cap,
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    g, c = sym_ref.shape
    cb = c // 8
    bufsz = c * symbol_size
    fbyte, prow = _build_sections(
        sym_ref[...],
        len_ref[...],
        off_ref[...],
        emit_ref[...],
        um_ref[...],
        lo_ref[...],
        nt_ref[...],
        ps_ref[...],
        symbol_size=symbol_size,
    )

    # Kernel III: blend each chunk's compact prefix into the blob at its
    # global offset (RMW merge over a full-width window; grid steps run
    # sequentially, so later chunks re-blend their own bytes).  Offsets
    # are SMEM scalar reads; clamping keeps the padded rows' zero-width
    # windows in bounds even for all-literal worst cases.
    jf = lax.broadcasted_iota(jnp.int32, (1, cb), 1)
    jp = lax.broadcasted_iota(jnp.int32, (1, bufsz), 1)
    for row in range(g):
        ci = i * g + row
        fw = (nt_ref[row] + 7) // 8
        pw = ps_ref[row]
        fdst = jnp.minimum(sec_flags + fo_ref[ci], cap - cb)
        cur = pl.load(out_ref, (slice(None), pl.dslice(fdst, cb)))
        pl.store(
            out_ref,
            (slice(None), pl.dslice(fdst, cb)),
            jnp.where(jf < fw, fbyte[row : row + 1, :], cur),
        )
        pdst = jnp.minimum(sec_flags + po_ref[ci], cap - bufsz)
        cur = pl.load(out_ref, (slice(None), pl.dslice(pdst, bufsz)))
        pl.store(
            out_ref,
            (slice(None), pl.dslice(pdst, bufsz)),
            jnp.where(jp < pw, prow[row : row + 1, :], cur),
        )


def _cost(nc, c, s):
    lg = _ceil_log2(c)
    # two binary searches + flag pack + payload build per position
    flops = nc * c * (2 * lg + 8 + 4 * s)
    return pl.CostEstimate(
        flops=flops,
        bytes_accessed=nc * c * 4 * 6 + nc * ((c + 7) // 8 + c * s),
        transcendentals=0,
    )


def _pad_rows(x, pad):
    if not pad:
        return x
    zeros = jnp.zeros((pad,) + x.shape[1:], x.dtype)
    return jnp.concatenate([x, zeros], axis=0)


@functools.partial(
    jax.jit,
    static_argnames=(
        "symbol_size",
        "cap",
        "sec_flags",
        "chunks_per_block",
        "interpret",
    ),
)
def lz_scatter_pallas(
    symbols,
    lengths,
    offsets,
    emitted,
    use_match,
    local_off,
    n_tokens,
    payload_sizes,
    *,
    symbol_size,
    cap,
    sec_flags,
    chunks_per_block=8,
    interpret=False,
):
    """Fused deflate-scatter: Kernel-I outputs -> (blob, flag_total, pay_total).

    ``blob`` is a (cap,) int32 byte buffer with the compact flag section at
    ``sec_flags`` and the payload section right after it — the bytes
    ``deflate.scatter_section`` would have produced, with the header/table
    region [0, sec_flags) left zero for the caller to fill.
    """
    fo, po, f_tot, p_tot = lz_global_offsets_pallas(
        n_tokens, payload_sizes, interpret=interpret
    )
    nc, c = symbols.shape
    g = chunks_per_block
    pad = (-nc) % g
    # the scatter grid covers nc+pad chunks; when that exceeds pass 1's
    # 128-lane padding (g does not divide 128 and nc is a lane multiple),
    # extend the scalar-prefetch offsets so fo_ref[ci]/po_ref[ci] stay in
    # bounds.  Zero is safe: padded rows have zero-width windows, so their
    # RMW blend at the (clamped, in-bounds) destination stores back what it
    # loaded.
    short = nc + pad - fo.shape[0]
    if short > 0:
        fo = jnp.concatenate([fo, jnp.zeros((short,), jnp.int32)])
        po = jnp.concatenate([po, jnp.zeros((short,), jnp.int32)])
    sym = _pad_rows(symbols.astype(jnp.int32), pad)
    lens = _pad_rows(lengths.astype(jnp.int32), pad)
    offs = _pad_rows(offsets.astype(jnp.int32), pad)
    emit = _pad_rows(emitted.astype(jnp.int32), pad)
    um = _pad_rows(use_match.astype(jnp.int32), pad)
    lo = _pad_rows(local_off.astype(jnp.int32), pad)
    nt = _pad_rows(n_tokens.astype(jnp.int32), pad)
    ps = _pad_rows(payload_sizes.astype(jnp.int32), pad)
    npad = nc + pad
    spec2d = pl.BlockSpec((g, c), lambda i, fo_, po_: (i, 0))
    spec1d = pl.BlockSpec((g,), lambda i, fo_, po_: (i,))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(npad // g,),
        in_specs=[spec2d] * 6 + [spec1d] * 2,
        out_specs=pl.BlockSpec((1, cap), lambda i, fo_, po_: (0, 0)),
    )
    out = pl.pallas_call(
        functools.partial(
            _scatter_kernel,
            symbol_size=symbol_size,
            sec_flags=sec_flags,
            cap=cap,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, cap), jnp.int32),
        cost_estimate=_cost(npad, c, symbol_size),
        interpret=interpret,
    )(fo, po, sym, lens, offs, emit, um, lo, nt, ps)
    return out[0], f_tot, p_tot
