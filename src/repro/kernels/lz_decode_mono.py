"""Single-launch GPULZ decoder: section gathers fused into ONE Pallas kernel.

The split decode path (core/pipeline.py:decompress_chunks with the
``fused`` decoder) still stages the container through XLA before the kernel
sees it: two ``deflate.gather_section`` gathers materialize the (nc, C//8)
flag and (nc, C*S) payload blocks in HBM, and only then does
kernels/lz_decode.py run.  That is the decode-side analogue of the HBM
round-trip the fused-mono *compressor* (kernels/lz_fused.py) removed — and
decode is the serving-restore / KV-onlining hot path, where Sitaridi et al.
(*Massively-Parallel Lossless Data Decompression*, PAPERS.md) show
end-to-end kernel residency is what moves throughput.

This kernel reads the container blob straight from HBM instead: the blob is
passed whole with ``memory_space=ANY``, the per-chunk flag/payload byte
offsets (derived from the A/B tables core/format.py already carries) ride
scalar prefetch, and each grid step DMAs its block's section windows
directly into VMEM scratch before running the exact ``_decode_values``
chain of kernels/lz_decode.py.  ``deflate.gather_section`` drops out of the
decode path entirely: ONE launch per decompress.

DMA windows are fixed-width (C//8 flag bytes, C*S payload bytes per chunk —
the aligned per-chunk maxima), so a chunk's window may overrun its compact
section into the next chunk's bytes; lane masks against the true per-chunk
sizes zero those bytes, reproducing gather_section's zero-fill exactly.
The wrapper pads the blob by one full window per section so the last live
chunk's window stays in bounds and the belt-and-braces offset clamps (the
lz_fused.py slide-phase idiom) never engage for live chunks.

Geometry (``chunks_per_block``) resolves through core/autotune.py at the
ops.py call site.  Byte-identity with the split decoders is enforced by
tests/test_decode_mono.py (S×W sweep vs the oracle + golden corpus) and the
one-launch property by its pallas-call counter test.

Real-TPU caveat: the dynamic, byte-granular (unaligned) ``pl.dslice`` DMA
starts on the ANY-space blob are validated in interpret mode only — no
other kernel in the repo exercises this Mosaic path.  Until a real-TPU
smoke has run (ROADMAP), ``REPRO_FUSED_MONO=0`` is the escape hatch that
drops the TPU ``"auto"`` default back to the split ``fused`` decoder.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import format as fmt
from repro.kernels.lz_decode import _ceil_log2, _decode_values


def _mono_decode_kernel(
    fofs_ref,  # scalar prefetch: (npad,) absolute flag-window byte offsets
    pofs_ref,  # scalar prefetch: (npad,) absolute payload-window byte offsets
    ntok_ref,  # (g,) per-chunk token counts
    psz_ref,  # (g,) per-chunk payload byte sizes
    blob_ref,  # (1, lpad) int32 container bytes, HBM-resident (ANY)
    out_ref,  # (g, C) decoded symbols
    fbuf,  # (g, C//8) VMEM flag window
    pbuf,  # (g, C*S) VMEM payload window
    sems,
    *,
    symbol_size,
    nc,
    lpad,
):
    i = pl.program_id(0)
    g, c = out_ref.shape
    s = symbol_size
    cb = c // 8
    bufsz = c * s

    # ---- fused gather: per-chunk section windows DMA'd straight from HBM --
    for row in range(g):
        ci = i * g + row

        @pl.when(ci < nc)
        def _fetch_row(row=row, ci=ci):
            # live offsets never clamp (the wrapper pads the blob past every
            # window); the clamp only guards pathological table values
            fo = jnp.minimum(fofs_ref[ci], lpad - cb)
            po = jnp.minimum(pofs_ref[ci], lpad - bufsz)
            fdma = pltpu.make_async_copy(
                blob_ref.at[:, pl.dslice(fo, cb)],
                fbuf.at[pl.dslice(row, 1), :],
                sems.at[0],
            )
            pdma = pltpu.make_async_copy(
                blob_ref.at[:, pl.dslice(po, bufsz)],
                pbuf.at[pl.dslice(row, 1), :],
                sems.at[1],
            )
            fdma.start()
            pdma.start()
            fdma.wait()
            pdma.wait()

    # Mask each fixed-width window to its chunk's true section size: the
    # overrun bytes (next chunk's data, or scratch garbage on skipped pad
    # rows) become the zeros deflate.gather_section would have produced.
    nt = ntok_ref[...]
    fsz = (nt + 7) // 8
    lane_f = lax.broadcasted_iota(jnp.int32, (g, cb), 1)
    flags = jnp.where(lane_f < fsz[:, None], fbuf[...], 0)
    lane_p = lax.broadcasted_iota(jnp.int32, (g, bufsz), 1)
    payload = jnp.where(lane_p < psz_ref[...][:, None], pbuf[...], 0)

    out_ref[...] = _decode_values(flags, payload, nt, symbol_size=s)


def _cost(nc, c, s):
    lg = _ceil_log2(c)
    flops = nc * c * (8 * lg + s + 12)
    return pl.CostEstimate(
        flops=flops,
        # sections in (via DMA windows) + tables + symbols out
        bytes_accessed=nc * ((c + 7) // 8 + c * s) * 4 + nc * 8 + nc * c * 4,
        transcendentals=0,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "symbol_size",
        "chunk_symbols",
        "n_chunks",
        "chunks_per_block",
        "interpret",
    ),
)
def lz_decode_mono_pallas(
    blob,
    n_tokens,
    payload_sizes,
    *,
    symbol_size,
    chunk_symbols,
    n_chunks,
    chunks_per_block=8,
    interpret=False,
):
    """ONE launch: container byte blob -> (nc, C) int32 symbols.

    ``blob`` is the whole container (any integer dtype, >= the live
    container bytes; trailing padding is ignored), ``n_tokens`` /
    ``payload_sizes`` the (nc,) A/B tables ``format.validate_container``
    returns.  The per-chunk section offsets are reduced to two cumsums here
    and prefetched as scalars — no gathered section arrays ever exist.
    """
    c, s, nc = chunk_symbols, symbol_size, n_chunks
    if c % 8:
        raise ValueError(f"chunk size must be a multiple of 8: {c}")
    g = chunks_per_block
    cb = c // 8
    bufsz = c * s

    b = blob.astype(jnp.int32).reshape(1, -1)
    # pad so every fixed-width chunk window stays in bounds; lane-align
    lpad = -(-(b.shape[1] + cb + bufsz) // 128) * 128
    b = jnp.pad(b, ((0, 0), (0, lpad - b.shape[1])))

    nt = n_tokens.astype(jnp.int32)
    psz = payload_sizes.astype(jnp.int32)
    fsz = (nt + 7) // 8
    fcs = jnp.cumsum(fsz)
    pcs = jnp.cumsum(psz)
    sec_flags = fmt.HEADER_BYTES + 8 * nc
    fofs = sec_flags + fcs - fsz  # absolute flag-section starts
    pofs = sec_flags + fcs[-1] + pcs - psz  # absolute payload starts

    pad = (-nc) % g
    if pad:
        z = jnp.zeros((pad,), jnp.int32)
        nt = jnp.concatenate([nt, z])
        psz = jnp.concatenate([psz, z])
        fofs = jnp.concatenate([fofs, z])
        pofs = jnp.concatenate([pofs, z])
    npad = nc + pad

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(npad // g,),
        in_specs=[
            pl.BlockSpec((g,), lambda i, fo_, po_: (i,)),
            pl.BlockSpec((g,), lambda i, fo_, po_: (i,)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((g, c), lambda i, fo_, po_: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, cb), jnp.int32),
            pltpu.VMEM((g, bufsz), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _mono_decode_kernel, symbol_size=s, nc=nc, lpad=lpad
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((npad, c), jnp.int32),
        cost_estimate=_cost(npad, c, s),
        interpret=interpret,
    )(fofs, pofs, nt, psz, b)
    return out[:nc]
