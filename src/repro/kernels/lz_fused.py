"""Single-kernel GPULZ compressor: Kernels I+II+III in ONE Pallas kernel.

The ``fused-deflate`` pipeline (kernels/lz_match.py + lz_scatter.py) still
splits matching from emit across three kernel launches, so the (nc, C)
match/flag/length intermediates of Kernel I round-trip through HBM before
the deflate-scatter re-reads them — the last HBM round-trip the paper's
workflow (d) removes (Fig. 4(c) vs (d); cf. the end-to-end-residency lesson
of Sitaridi et al., *Massively-Parallel Lossless Data Decompression*).  This
module folds the whole compressor into one kernel:

  * **Kernel I** per chunk block: multi-byte matching, the selection walk
    and the local prefix sums (shared helpers ``_match_values`` /
    ``_select_and_scan`` from lz_match.py) — intermediates never leave VMEM.
  * **Kernel II** as a running carry: TPU grid steps execute sequentially,
    so BOTH global exclusive prefix sums degenerate to two SMEM scalars
    accumulated across blocks (the single-pass analogue of CUB's decoupled
    look-back) — no separate offsets kernel, no (nc,) offset arrays in HBM.
  * **Kernel III** as per-chunk DMA windows: the compact flag/payload bytes
    are rebuilt in VMEM (``_build_sections`` from lz_scatter.py) and DMA'd
    to the output blob at the carried offsets.  The blob lives in HBM
    (``memory_space=ANY``) and is only ever touched through per-chunk VMEM
    windows — unlike lz_scatter's (1, cap) VMEM-resident output block, so
    containers are no longer bounded by what fits in VMEM (~4 MiB).

Layout trick: a chunk's final payload offset depends on the TOTAL flag
section size, which a single forward sweep only knows after the last chunk.
The kernel therefore stages the payload stream at a fixed base past the
worst-case flag section and appends a short *slide* phase to the same grid:
after the last block, ``f_tot`` is known, and the remaining grid steps DMA
the staged payload down to ``sec_flags + f_tot`` window by window (windows
are masked to the live payload, so the slide simultaneously zero-fills
everything from the live end to the buffer top — stale staging bytes
included).  Forward order makes the move hazard-free: every destination
window lies strictly below its source.

Byte-identity with the XLA pipeline is enforced by tests/test_pipeline.py,
tests/test_conformance.py and the golden corpus (tests/golden/); the
one-launch property by the pallas-call counter test.  Real-TPU caveats
(DMA granularity of byte-offset windows, scalar VMEM reads in the row loop)
are tracked in ROADMAP.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lz_match import (
    MAX_LEN_CAP,
    _levels,
    _match_values,
    _pad_chunks,
    _select_and_scan,
)
from repro.kernels.lz_scatter import _build_sections


def _mono_kernel(
    bt_ref,  # scalar prefetch: per-step block index (clamped past phase A)
    sym_ref,
    out_ref,  # (1, cap_alloc) int32 byte blob, HBM-resident (ANY)
    ntok_ref,
    psz_ref,
    tot_ref,
    len_s,
    emit_s,
    fbuf,
    pbuf,
    slidebuf,
    carry,  # SMEM [flag_off, pay_off] running across the sequential grid
    sems,
    *,
    window,
    max_len,
    min_match,
    symbol_size,
    nc,
    nb,
    sec_flags,
    stage,
    cap_alloc,
):
    i = pl.program_id(0)
    g, c = sym_ref.shape
    s = symbol_size
    cb = c // 8
    bufsz = c * s
    sw = g * bufsz  # slide window = one block's worth of payload bytes

    @pl.when(i == 0)
    def _init():
        carry[0] = 0
        carry[1] = 0

    @pl.when(i < nb)
    def _compress_block():
        # ---- Kernel I: match + select + local prefix sums, all in VMEM ----
        lengths, offsets = _match_values(
            sym_ref[...], window=window, max_len=max_len
        )
        len_s[...] = lengths
        emitted, um, _, local_off, psz, ntok = _select_and_scan(
            len_s, emit_s, lengths, min_match=min_match, symbol_size=s
        )
        ntok_ref[...] = ntok
        psz_ref[...] = psz

        # ---- encode tail: compact section bytes for the whole block -------
        fbyte, prow = _build_sections(
            sym_ref[...],
            lengths,
            offsets,
            emitted.astype(jnp.int32),
            um.astype(jnp.int32),
            local_off,
            ntok,
            psz,
            symbol_size=s,
        )
        fbuf[...] = fbyte
        pbuf[...] = prow

        # ---- Kernels II+III: carry the global offsets, DMA the windows ----
        # Each chunk writes a full aligned window at its carried offset; the
        # next chunk's window starts inside it and overwrites the dead tail,
        # so consecutive windows deflate the stream without any RMW blend.
        # Payload goes to a staging base past the worst-case flag section
        # (final placement needs f_tot — see module docstring).
        for row in range(g):
            ci = i * g + row

            @pl.when(ci < nc)
            def _emit_row(row=row):
                fo = carry[0]
                po = carry[1]
                fdma = pltpu.make_async_copy(
                    fbuf.at[pl.dslice(row, 1), :],
                    out_ref.at[:, pl.dslice(sec_flags + fo, cb)],
                    sems.at[0],
                )
                pdma = pltpu.make_async_copy(
                    pbuf.at[pl.dslice(row, 1), :],
                    out_ref.at[:, pl.dslice(stage + po, bufsz)],
                    sems.at[1],
                )
                fdma.start()
                pdma.start()
                fdma.wait()
                pdma.wait()
                carry[0] = fo + (ntok[row] + 7) // 8
                carry[1] = po + psz[row]

    @pl.when(i >= nb)
    def _slide():
        # ---- slide phase: staged payload -> sec_flags + f_tot -------------
        k = i - nb
        f_tot = carry[0]
        p_tot = carry[1]

        @pl.when(i == nb)
        def _totals():
            lane = lax.broadcasted_iota(jnp.int32, tot_ref.shape, 1)
            tot_ref[...] = jnp.where(
                lane == 0, f_tot, jnp.where(lane == 1, p_tot, 0)
            )

        # Clamped windows only ever move zeros (the mask below kills every
        # byte past p_tot long before the clamps can engage), so reading
        # garbage at the clamped source is harmless.
        src = jnp.minimum(stage + k * sw, cap_alloc - sw)
        rd = pltpu.make_async_copy(
            out_ref.at[:, pl.dslice(src, sw)], slidebuf, sems.at[2]
        )
        rd.start()
        rd.wait()
        jg = k * sw + lax.broadcasted_iota(jnp.int32, (1, sw), 1)
        slidebuf[...] = jnp.where(jg < p_tot, slidebuf[...], 0)
        dst = jnp.minimum(sec_flags + f_tot + k * sw, cap_alloc - sw)
        wr = pltpu.make_async_copy(
            slidebuf, out_ref.at[:, pl.dslice(dst, sw)], sems.at[2]
        )
        wr.start()
        wr.wait()


def _cost(nc, c, s, window, levels):
    # Kernel I dominates: per (position, offset) eq + doubling levels, plus
    # the section rebuild's binary searches and the slide's byte traffic.
    lg = _levels(c, c)
    flops = nc * c * (window * (2 + 3 * levels + 5) + 2 * lg + 8 + 4 * s)
    return pl.CostEstimate(
        flops=flops,
        bytes_accessed=nc * c * 4 + 3 * nc * ((c + 7) // 8 + c * s),
        transcendentals=0,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "window",
        "min_match",
        "symbol_size",
        "cap",
        "sec_flags",
        "max_len",
        "chunks_per_block",
        "interpret",
    ),
)
def lz_fused_mono_pallas(
    symbols,
    *,
    window,
    min_match,
    symbol_size,
    cap,
    sec_flags,
    max_len=MAX_LEN_CAP,
    chunks_per_block=8,
    interpret=False,
):
    """ONE kernel: (nc, C) int32 symbols -> deflated container sections.

    Returns ``(blob, n_tokens, payload_sizes, flag_total, pay_total)``:
    ``blob`` is a (cap,) int32 byte buffer with the compact flag section at
    ``sec_flags``, the payload section right after it, zeros from the live
    end to ``cap``, and the header/table region [0, sec_flags) left for the
    caller to fill (``pipeline._finalize_container``); the (nc,) tables and
    the two section totals are the same values the split pipeline computes.
    """
    x = symbols.astype(jnp.int32)
    nc, c = x.shape
    if c % 8:
        raise ValueError(f"chunk size must be a multiple of 8: {c}")
    g = chunks_per_block
    x, _ = _pad_chunks(x, g)
    npad = x.shape[0]
    nb = npad // g
    s = symbol_size
    cb = c // 8
    bufsz = c * s
    sw = g * bufsz
    # staging base: one window of slack past the worst-case flag section, so
    # the last real chunk's full-width flag window can spill dead bytes
    # without touching staged payload
    stage = sec_flags + nc * cb + cb
    # alloc: staging extent + spill + two slide windows of slack for the
    # offset clamps; the format-visible prefix [0, cap) is sliced off below
    cap_alloc = stage + nc * bufsz + bufsz + 2 * sw
    assert cap <= cap_alloc
    nslide = -(-(nc * (cb + bufsz) + cb + bufsz) // sw) + 2
    nsteps = nb + nslide
    bt = jnp.minimum(jnp.arange(nsteps, dtype=jnp.int32), nb - 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nsteps,),
        in_specs=[pl.BlockSpec((g, c), lambda i, bt_: (bt_[i], 0))],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((g,), lambda i, bt_: (bt_[i],)),
            pl.BlockSpec((g,), lambda i, bt_: (bt_[i],)),
            pl.BlockSpec((1, 128), lambda i, bt_: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, c), jnp.int32),  # lengths (dynamic-column walk)
            pltpu.VMEM((g, c), jnp.int32),  # emitted (dynamic-column walk)
            pltpu.VMEM((g, cb), jnp.int32),  # block flag bytes
            pltpu.VMEM((g, bufsz), jnp.int32),  # block payload bytes
            pltpu.VMEM((1, sw), jnp.int32),  # slide window
            pltpu.SMEM((2,), jnp.int32),  # running [flag_off, pay_off]
            pltpu.SemaphoreType.DMA((3,)),
        ],
    )
    out, ntok, psz, tot = pl.pallas_call(
        functools.partial(
            _mono_kernel,
            window=window,
            max_len=max_len,
            min_match=min_match,
            symbol_size=s,
            nc=nc,
            nb=nb,
            sec_flags=sec_flags,
            stage=stage,
            cap_alloc=cap_alloc,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((1, cap_alloc), jnp.int32),
            jax.ShapeDtypeStruct((npad,), jnp.int32),
            jax.ShapeDtypeStruct((npad,), jnp.int32),
            jax.ShapeDtypeStruct((1, 128), jnp.int32),
        ],
        cost_estimate=_cost(npad, c, s, window, _levels(window, max_len)),
        interpret=interpret,
    )(bt, x)
    return out[0, :cap], ntok[:nc], psz[:nc], tot[0, 0], tot[0, 1]
