"""Pure-jnp oracles for the Pallas kernels.

``ref.lz_match`` / ``ref.lz_kernel1`` produce exactly the values the kernels
must produce; tests sweep shapes/dtypes and assert exact equality (integer
outputs — allclose degenerates to equality).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import encode as encode_mod
from repro.core import match as match_mod


def lz_match(symbols, *, window, max_len=match_mod.MAX_LEN_CAP):
    return match_mod.find_matches(
        symbols.astype(jnp.int32), window=window, max_len=max_len
    )


def lz_kernel1(
    symbols, *, window, min_match, symbol_size, max_len=match_mod.MAX_LEN_CAP
):
    lengths, offsets = lz_match(symbols, window=window, max_len=max_len)
    emitted = encode_mod.select_tokens_scan(lengths, min_match=min_match)
    fields = encode_mod.token_fields(
        lengths, emitted, min_match=min_match, symbol_size=symbol_size
    )
    return dict(
        lengths=lengths,
        offsets=offsets,
        emitted=emitted,
        local_off=fields["local_off"],
        payload_sizes=fields["payload_sizes"],
        n_tokens=fields["n_tokens"],
    )
