"""Pallas bit-plane transpose kernels for the lossy-fz frontend.

FZ-GPU's bitshuffle stage (PAPERS.md) as two tiled TPU kernels mirroring
the layout fixed in core/bitshuffle.py: each 512-unit uint16 block becomes
16 bit planes of 64 bytes (LSB plane first, unit ``8j`` in each packed
byte's LSB).  Both directions are pure per-block permutations, so the grid
is embarrassingly parallel: one grid step transposes ``ROWS_PER_STEP``
independent blocks from a (nb, 512) uint16 view into a (nb, 1024) uint8
view (and back).

All arithmetic runs widened to int32 inside the kernel — the shift/mask
lattice lowers as plain vector ops; only the final store narrows to uint8 /
uint16.  Like the other kernels these are interpret-mode validated on CPU
(byte-identical to core/bitshuffle.py's XLA reference by test); the
``REPRO_BITSHUFFLE_PALLAS`` gate selects them on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.bitshuffle import BLOCK_BYTES, BLOCK_UNITS, PLANE_BYTES, PLANES

ROWS_PER_STEP = 8


def _shuffle_kernel(u_ref, out_ref):
    u = u_ref[...].astype(jnp.int32)                       # (g, 512)
    g = u.shape[0]
    plane = lax.broadcasted_iota(jnp.int32, (g, BLOCK_UNITS, PLANES), 2)
    bits = (u[:, :, None] >> plane) & 1
    bits = bits.reshape(g, PLANE_BYTES, 8, PLANES)
    weight = lax.broadcasted_iota(jnp.int32, bits.shape, 2)
    packed = jnp.sum(bits << weight, axis=2)               # (g, 64, 16)
    out = packed.transpose(0, 2, 1).reshape(g, BLOCK_BYTES)
    out_ref[...] = out.astype(jnp.uint8)


def _unshuffle_kernel(p_ref, out_ref):
    p = p_ref[...].astype(jnp.int32)                       # (g, 1024)
    g = p.shape[0]
    p = p.reshape(g, PLANES, PLANE_BYTES)
    pos = lax.broadcasted_iota(jnp.int32, (g, PLANES, PLANE_BYTES, 8), 3)
    bits = (p[:, :, :, None] >> pos) & 1
    bits = bits.transpose(0, 2, 3, 1)                      # (g, 64, 8, 16)
    weight = lax.broadcasted_iota(jnp.int32, bits.shape, 3)
    vals = jnp.sum(bits << weight, axis=3)                 # (g, 64, 8)
    out_ref[...] = vals.reshape(g, BLOCK_UNITS).astype(jnp.uint16)


def _pad_rows(x: jnp.ndarray, rows: int) -> tuple[jnp.ndarray, int]:
    nb = x.shape[0]
    padded = -(-nb // rows) * rows
    if padded != nb:
        x = jnp.pad(x, ((0, padded - nb), (0, 0)))
    return x, padded


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitshuffle_pallas(units: jnp.ndarray, *, interpret: bool = False):
    """(N,) uint16 -> (2N,) uint8; N % BLOCK_UNITS == 0."""
    n = units.shape[0]
    nb = n // BLOCK_UNITS
    rows, padded = _pad_rows(units.reshape(nb, BLOCK_UNITS), ROWS_PER_STEP)
    out = pl.pallas_call(
        _shuffle_kernel,
        grid=(padded // ROWS_PER_STEP,),
        in_specs=[
            pl.BlockSpec((ROWS_PER_STEP, BLOCK_UNITS), lambda i: (i, 0))
        ],
        out_specs=pl.BlockSpec((ROWS_PER_STEP, BLOCK_BYTES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, BLOCK_BYTES), jnp.uint8),
        interpret=interpret,
    )(rows)
    return out[:nb].reshape(nb * BLOCK_BYTES)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitunshuffle_pallas(shuffled: jnp.ndarray, *, interpret: bool = False):
    """(2N,) uint8 -> (N,) uint16; 2N % BLOCK_BYTES == 0."""
    nb = shuffled.shape[0] // BLOCK_BYTES
    rows, padded = _pad_rows(
        shuffled.reshape(nb, BLOCK_BYTES), ROWS_PER_STEP
    )
    out = pl.pallas_call(
        _unshuffle_kernel,
        grid=(padded // ROWS_PER_STEP,),
        in_specs=[
            pl.BlockSpec((ROWS_PER_STEP, BLOCK_BYTES), lambda i: (i, 0))
        ],
        out_specs=pl.BlockSpec(
            (ROWS_PER_STEP, BLOCK_UNITS), lambda i: (i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((padded, BLOCK_UNITS), jnp.uint16),
        interpret=interpret,
    )(rows)
    return out[:nb].reshape(nb * BLOCK_UNITS)
