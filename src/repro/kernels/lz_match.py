"""Pallas TPU kernel for GPULZ Kernel I (match + select + local prefix sum).

TPU mapping of the paper's fused kernel (§3.3.2):

  CUDA thread block + shared memory  ->  Pallas grid cell + VMEM block
  one thread per coding position     ->  positions on vector lanes
  window walk per thread             ->  fori over window offsets d = 1..W,
                                         capped log-doubling run lengths
  chunk per thread block             ->  ``chunks_per_block`` chunks stacked on
                                         sublanes (fills the 8x128 VREG tile)
  one encode thread per block        ->  in-kernel selection walk over lanes
                                         (dynamic column load/store)
  shared-mem local prefix sum        ->  in-VMEM log-doubling prefix sum

Everything between the symbol load and the (len/off/emitted/local-offset)
stores stays in VMEM — the equality rows and run-length intermediates never
touch HBM.  That is precisely the paper's two-pass-prefix-sum + kernel-fusion
insight (their Fig. 4 (c) vs (d)); the unfused XLA pipeline in core/ is the
workflow-(c) baseline we compare against in EXPERIMENTS.md.

Kernels are validated in interpret mode against kernels/ref.py (pure jnp).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

MAX_LEN_CAP = 255


def _levels(window: int, max_len: int) -> int:
    cap = min(window, max_len)
    k = 0
    while (1 << k) < cap:
        k += 1
    return k


def _shift_left_zero(x, stride, idx, c):
    """out[..., i] = x[..., i + stride] with zero fill (roll + mask)."""
    return jnp.where(idx < c - stride, jnp.roll(x, -stride, axis=-1), 0)


def _shift_right_zero(x, stride, idx):
    return jnp.where(idx >= stride, jnp.roll(x, stride, axis=-1), 0)


def _match_values(x, *, window, max_len):
    """(G, C) symbols -> (lengths, offsets) values; runs entirely in VMEM.

    The offset loop is bucketed by ceil(log2 d): candidates are capped at
    min(d, max_len), so offsets in (2^{k-1}, 2^k] only need k doubling
    levels (~15% fewer VPU ops at W=128; see EXPERIMENTS.md §Perf)."""
    g, c = x.shape
    max_levels = _levels(window, max_len)
    idx = lax.broadcasted_iota(jnp.int32, (g, c), 1)
    pack = window + 1

    def body_for(levels):
        def body(d, best):
            shifted = jnp.roll(x, d, axis=-1)  # wrapped lanes masked below
            eq = ((x == shifted) & (idx >= d)).astype(jnp.int32)
            r = eq
            for k in range(levels):
                stride = 1 << k
                r = r + jnp.where(r == stride, _shift_left_zero(r, stride, idx, c), 0)
            cand = jnp.minimum(r, jnp.minimum(d, max_len))
            return jnp.maximum(best, cand * pack + d)

        return body

    best = jnp.zeros((g, c), jnp.int32)
    lo, k = 1, 0
    while lo <= window:
        k = min(k, max_levels)
        hi = min(window, 1 << k) if k else min(window, 1)
        best = lax.fori_loop(lo, hi + 1, body_for(k), best)
        lo = hi + 1
        k += 1
    lengths = best // pack
    offsets = jnp.where(lengths > 0, best % pack, 0)
    return lengths, offsets


def _match_kernel(x_ref, len_ref, off_ref, *, window, max_len):
    lengths, offsets = _match_values(x_ref[...], window=window, max_len=max_len)
    len_ref[...] = lengths
    off_ref[...] = offsets


def _select_and_scan(len_ref, emit_ref, lengths, *, min_match, symbol_size):
    """Selection walk + local prefix sums, VMEM-resident.

    ``len_ref`` must already hold ``lengths``; ``emit_ref`` is the scratch
    the walk's dynamic-column stores go through and holds the 0/1 emitted
    mask on return.  Returns ``(emitted, use_match, sizes, local_off,
    payload_sizes, n_tokens)`` values — the per-position arrays are (g, C),
    the per-chunk reductions (g,).  Shared by the fused Kernel I below and
    the single-kernel compressor (lz_fused.py).
    """
    g, c = len_ref.shape

    # --- encode walk (paper: one thread per block; here: lanes via dynamic
    # column access, all `g` chunks in lockstep on sublanes) ----------------
    def body(i, next_pos):
        len_i = pl.load(len_ref, (slice(None), pl.dslice(i, 1)))
        emit = next_pos == i
        step = jnp.where(len_i >= min_match, len_i, 1)
        pl.store(emit_ref, (slice(None), pl.dslice(i, 1)), emit.astype(jnp.int32))
        return jnp.where(emit, i + step, next_pos)

    lax.fori_loop(0, c, body, jnp.zeros((g, 1), jnp.int32))

    # --- local prefix sum (paper's up/down-sweep == lane-shift doubling) ---
    emitted = emit_ref[...] == 1
    use_match = emitted & (lengths >= min_match)
    sizes = jnp.where(emitted, jnp.where(use_match, 2, symbol_size), 0).astype(
        jnp.int32
    )
    idx = lax.broadcasted_iota(jnp.int32, (g, c), 1)
    incl = sizes
    ntok = emitted.astype(jnp.int32)
    k = 1
    while k < c:
        incl = incl + _shift_right_zero(incl, k, idx)
        ntok = ntok + _shift_right_zero(ntok, k, idx)
        k *= 2
    return emitted, use_match, sizes, incl - sizes, incl[:, c - 1], ntok[:, c - 1]


def _fused_kernel(
    x_ref,
    len_ref,
    off_ref,
    emit_ref,
    lo_ref,
    paysz_ref,
    ntok_ref,
    *,
    window,
    max_len,
    min_match,
    symbol_size,
):
    lengths, offsets = _match_values(x_ref[...], window=window, max_len=max_len)
    len_ref[...] = lengths
    off_ref[...] = offsets
    _, _, _, local_off, paysz, ntok = _select_and_scan(
        len_ref, emit_ref, lengths, min_match=min_match, symbol_size=symbol_size
    )
    lo_ref[...] = local_off               # exclusive local offsets
    paysz_ref[...] = paysz                # per-chunk compressed payload bytes
    ntok_ref[...] = ntok                  # per-chunk token count (flag bits)


def _pad_chunks(symbols, gsz):
    nc = symbols.shape[0]
    pad = (-nc) % gsz
    if pad:
        symbols = jnp.concatenate(
            [symbols, jnp.zeros((pad, symbols.shape[1]), symbols.dtype)], axis=0
        )
    return symbols, nc


def _cost(nc, c, window, levels):
    # per (position, offset): eq + levels*(cmp+sel+add) + cap/min + pack/max
    flops = nc * c * window * (2 + 3 * levels + 5)
    return pl.CostEstimate(
        flops=flops, bytes_accessed=nc * c * 4 * 3, transcendentals=0
    )


@functools.partial(
    jax.jit,
    static_argnames=("window", "max_len", "chunks_per_block", "interpret"),
)
def lz_match_pallas(
    symbols, *, window, max_len=MAX_LEN_CAP, chunks_per_block=8, interpret=False
):
    """(nc, C) int32 -> (lengths, offsets), each (nc, C) int32."""
    x, nc = _pad_chunks(symbols.astype(jnp.int32), chunks_per_block)
    npad, c = x.shape
    g = chunks_per_block
    grid = (npad // g,)
    spec = pl.BlockSpec((g, c), lambda i: (i, 0))
    lengths, offsets = pl.pallas_call(
        functools.partial(_match_kernel, window=window, max_len=max_len),
        grid=grid,
        in_specs=[spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((npad, c), jnp.int32),
            jax.ShapeDtypeStruct((npad, c), jnp.int32),
        ],
        cost_estimate=_cost(npad, c, window, _levels(window, max_len)),
        interpret=interpret,
    )(x)
    return lengths[:nc], offsets[:nc]


@functools.partial(
    jax.jit,
    static_argnames=(
        "window",
        "max_len",
        "min_match",
        "symbol_size",
        "chunks_per_block",
        "interpret",
    ),
)
def lz_kernel1_pallas(
    symbols,
    *,
    window,
    min_match,
    symbol_size,
    max_len=MAX_LEN_CAP,
    chunks_per_block=8,
    interpret=False,
):
    """Fused Kernel I: -> dict(lengths, offsets, emitted, local_off,
    payload_sizes, n_tokens), shapes (nc, C) / (nc,)."""
    x, nc = _pad_chunks(symbols.astype(jnp.int32), chunks_per_block)
    npad, c = x.shape
    g = chunks_per_block
    grid = (npad // g,)
    spec2d = pl.BlockSpec((g, c), lambda i: (i, 0))
    spec1d = pl.BlockSpec((g,), lambda i: (i,))
    sds2 = jax.ShapeDtypeStruct((npad, c), jnp.int32)
    sds1 = jax.ShapeDtypeStruct((npad,), jnp.int32)
    out = pl.pallas_call(
        functools.partial(
            _fused_kernel,
            window=window,
            max_len=max_len,
            min_match=min_match,
            symbol_size=symbol_size,
        ),
        grid=grid,
        in_specs=[spec2d],
        out_specs=[spec2d, spec2d, spec2d, spec2d, spec1d, spec1d],
        out_shape=[sds2, sds2, sds2, sds2, sds1, sds1],
        cost_estimate=_cost(npad, c, window, _levels(window, max_len)),
        interpret=interpret,
    )(x)
    lengths, offsets, emitted, local_off, paysz, ntok = out
    return dict(
        lengths=lengths[:nc],
        offsets=offsets[:nc],
        emitted=emitted[:nc] == 1,
        local_off=local_off[:nc],
        payload_sizes=paysz[:nc],
        n_tokens=ntok[:nc],
    )
