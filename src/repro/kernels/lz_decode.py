"""Pallas TPU kernel for fused GPULZ decompression.

The XLA reference decoder (core/decode.py:decode_parallel) stages every
intermediate — flag bits, the two read/write prefix sums, per-token
length/offset/literal columns, the token-id fill and ceil(log2 C) rounds of
pointer doubling — through HBM as separate ops.  This kernel keeps the whole
chain resident in VMEM per chunk block (cf. Sitaridi et al.,
*Massively-Parallel Lossless Data Decompression*, PAPERS.md): the only HBM
traffic is the compact flag/payload sections in and the decoded symbols out,
written exactly once.

Algorithm (identical math to decode_parallel, TPU-shaped):

  flag extraction      one gather per position from the chunk's flag bytes
  read offsets         prefix sum over [2 | S] token byte sizes
                       (lane-shift doubling — no HBM cumsum)
  token fields         payload gathers at the read offsets (len/off/literal)
  write offsets        prefix sum over token output lengths
  token-id fill        branchless binary search over the sorted token start
                       positions (log2 C gathers) — replaces decode_parallel's
                       scatter+cumsum, which has no efficient Mosaic lowering
  copy resolution      ceil(log2 C) pointer-doubling gathers; match length <=
                       offset (match.py) makes back-references a forest rooted
                       at literals, so doubling terminates

Like lz_match.py, ``chunks_per_block`` chunks ride the sublane dimension so
the 8x128 VREG tile stays full for small C.  Kernels are validated in
interpret mode against core/decode.py (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.lz_match import _shift_right_zero


def _ceil_log2(n: int) -> int:
    k = 0
    while (1 << k) < n:
        k += 1
    return max(1, k)


def _prefix_sum_excl(x, idx, c):
    """Exclusive prefix sum along lanes via log-shift doubling (stays in VMEM)."""
    incl = x
    k = 1
    while k < c:
        incl = incl + _shift_right_zero(incl, k, idx)
        k *= 2
    return incl - x


def _search_last_le(sorted_rows, queries, n):
    """res[., q] = last index i in [0, n) with sorted_rows[., i] <= queries[., q].

    Branchless binary search over a nondecreasing row (log2 n gathers —
    the gather-friendly replacement for scatter+cumsum fills, which have no
    efficient Mosaic lowering).  Defaults to 0 when every element exceeds
    the query; callers mask those lanes.  Shared by the decode kernel's
    token-id fill and the deflate-scatter kernel's rank/offset searches.
    """
    res = jnp.zeros_like(queries)
    for shift in reversed(range(_ceil_log2(n))):
        probe = res + (1 << shift)
        pv = jnp.take_along_axis(sorted_rows, jnp.clip(probe, 0, n - 1), axis=1)
        res = jnp.where((probe <= n - 1) & (pv <= queries), probe, res)
    return res


def _decode_values(flag_bytes, payload, n_tokens, *, symbol_size):
    """(G, cb) flags + (G, C*S) payload + (G,) counts -> (G, C) symbols."""
    g, cb = flag_bytes.shape
    c = cb * 8
    s = symbol_size
    t = lax.broadcasted_iota(jnp.int32, (g, c), 1)
    active = (t < n_tokens[:, None]).astype(jnp.int32)

    byte = jnp.take_along_axis(flag_bytes, t // 8, axis=1)
    flags = ((byte >> (t % 8)) & 1) * active

    # token read offsets: prefix sum over [2 | S] encoded byte sizes
    read_size = jnp.where(active == 1, jnp.where(flags == 1, 2, s), 0)
    read_off = _prefix_sum_excl(read_size, t, c)

    def pay_at(k):
        return jnp.take_along_axis(
            payload, jnp.clip(read_off + k, 0, payload.shape[1] - 1), axis=1
        )

    ln = jnp.where(flags == 1, pay_at(0), 1) * active
    off = jnp.where(flags == 1, pay_at(1), 0) * active
    lit = jnp.zeros((g, c), jnp.int32)
    for b in range(s):
        lit = lit + (pay_at(b) << (8 * b))
    lit = jnp.where(flags == 0, lit, 0)

    out_pos = _prefix_sum_excl(ln, t, c)  # token write starts (symbols)

    # Per-output-symbol token id.  Token starts are strictly increasing over
    # active tokens (ln >= 1), so the covering token of output position w is
    # the last token with out_pos <= w (inactive tokens get the sentinel c,
    # keeping the row sorted).
    pos = jnp.where((active == 1) & (ln > 0), out_pos, c)
    token_id = _search_last_le(pos, t, c)

    flag_w = jnp.take_along_axis(flags, token_id, axis=1)
    off_w = jnp.take_along_axis(off, token_id, axis=1)
    lit_w = jnp.take_along_axis(lit, token_id, axis=1)
    src = jnp.where(flag_w == 1, jnp.clip(t - off_w, 0, c - 1), t)
    for _ in range(_ceil_log2(c)):
        src = jnp.take_along_axis(src, src, axis=1)
    return jnp.take_along_axis(lit_w, src, axis=1)


def _decode_kernel(flag_ref, pay_ref, ntok_ref, out_ref, *, symbol_size):
    out_ref[...] = _decode_values(
        flag_ref[...], pay_ref[...], ntok_ref[...], symbol_size=symbol_size
    )


def _cost(nc, c, s):
    lg = _ceil_log2(c)
    # per position: flag extract + 2 prefix sums + binary search + doubling
    flops = nc * c * (8 * lg + s + 12)
    return pl.CostEstimate(
        flops=flops,
        bytes_accessed=nc * ((c + 7) // 8 + c * s + 4 + c * 4),
        transcendentals=0,
    )


@functools.partial(
    jax.jit, static_argnames=("symbol_size", "chunks_per_block", "interpret")
)
def lz_decode_pallas(
    flag_bytes,
    payload,
    n_tokens,
    *,
    symbol_size,
    chunks_per_block=8,
    interpret=False,
):
    """Fused decoder: (nc, C//8) flag bytes + (nc, C*S) payload bytes +
    (nc,) token counts -> (nc, C) int32 symbols.

    Inputs are the per-chunk aligned sections produced by
    deflate.gather_section (int-valued; any integer dtype accepted)."""
    f = flag_bytes.astype(jnp.int32)
    p = payload.astype(jnp.int32)
    nt = n_tokens.astype(jnp.int32)
    nc, cb = f.shape
    c = cb * 8
    g = chunks_per_block
    pad = (-nc) % g
    if pad:
        f = jnp.concatenate([f, jnp.zeros((pad, cb), jnp.int32)], axis=0)
        p = jnp.concatenate([p, jnp.zeros((pad, p.shape[1]), jnp.int32)], axis=0)
        nt = jnp.concatenate([nt, jnp.zeros((pad,), jnp.int32)], axis=0)
    npad = nc + pad
    grid = (npad // g,)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, symbol_size=symbol_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((g, cb), lambda i: (i, 0)),
            pl.BlockSpec((g, p.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((g,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((g, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, c), jnp.int32),
        cost_estimate=_cost(npad, c, symbol_size),
        interpret=interpret,
    )(f, p, nt)
    return out[:nc]
