"""Pallas kernels for the entropy container stage (core/entropy.py).

Two kernels back the ``deflate-full`` backend/decoder pair:

  * ``byte_histogram_pallas`` — the code-length front end: a sequential-grid
    reduction over 1024-byte tiles of the section buffer.  Each grid step
    one-hot-compares its tile against the 256 symbol lanes and accumulates
    into a revisited (1, 256) output block (constant index map, initialized
    at step 0) — the Pallas analogue of the XLA 257-slot scatter-add
    fallback in ``core.entropy.byte_histogram``, identical counts by test.

  * ``huffman_gap_decode_pallas`` — the parallel bitstream decoder: the
    container blob stays HBM-resident (``memory_space=ANY``, the
    lz_decode_mono.py idiom) and each grid step DMAs one fixed-width
    bitstream window per gap-array sub-block into VMEM at scalar-prefetched
    byte offsets.  Every sub-block lane then walks exactly ``sub``
    codewords from its entry point: a 24-bit window is gathered at the
    lane's bit offset, all 15 candidate lengths are range-tested against
    the canonical ``first``/``count`` tables at once (the prefix property
    guarantees a unique hit), and the decode table maps the hit to its
    symbol.  The sequential Huffman constraint lives only *inside* a
    sub-block — sub-blocks are embarrassingly parallel, which is the gap
    array's entire point (Sitaridi et al., PAPERS.md).

The decode table rides in one (8, 128) int32 block: rows 0-2 are the
``first`` / ``count`` / ``base`` per-length tables (16 live lanes), rows
3-4 the 256-entry symbol ``order`` map split across two lanes' rows.

Real-TPU caveat (same class as lz_decode_mono.py, documented in
EXPERIMENTS.md): the per-lane ``take_along_axis`` window gathers and the
dynamic per-codeword column store are validated in interpret mode only;
``REPRO_ENTROPY_PALLAS=0`` drops the TPU default back to the XLA
scan/scatter paths in core/entropy.py until a real-TPU smoke has run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

HIST_TILE = 1024  # bytes per histogram grid step (8 x 128 int32 lanes)
N_SYMBOLS = 256
MAX_CODE_LEN = 15


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def window_bytes(sub: int) -> int:
    """Fixed DMA window per sub-block: worst case ``sub`` 15-bit codewords
    starting at any bit phase, plus the 2-byte lookahead of the last
    24-bit window read, lane-aligned."""
    return _round_up((7 + MAX_CODE_LEN * sub) // 8 + 3, 128)


# --------------------------------------------------------------- histogram


def _hist_kernel(start_ref, len_ref, buf_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile = buf_ref.shape[1]
    vals = (buf_ref[...].reshape(tile, 1)) & 0xFF
    gidx = i * tile + lax.broadcasted_iota(jnp.int32, (tile, 1), 0)
    lo = start_ref[0]
    ok = (gidx >= lo) & (gidx < lo + len_ref[0])
    sym = lax.broadcasted_iota(jnp.int32, (1, N_SYMBOLS), 1)
    eq = (vals == sym) & ok
    out_ref[...] += jnp.sum(eq.astype(jnp.int32), axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def byte_histogram_pallas(buf, start, length, *, interpret=False):
    """(n,) int32 byte buffer -> (256,) int32 counts of [start, start+len).

    ``start``/``length`` may be traced; they ride scalar prefetch.  The
    grid is sequential over 1024-byte tiles, accumulating into one
    revisited (1, 256) block.
    """
    b = jnp.asarray(buf, jnp.int32).reshape(1, -1)
    npad = _round_up(max(b.shape[1], 1), HIST_TILE)
    b = jnp.pad(b, ((0, 0), (0, npad - b.shape[1])))
    sarr = jnp.asarray(start, jnp.int32).reshape(1)
    larr = jnp.asarray(length, jnp.int32).reshape(1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(npad // HIST_TILE,),
        in_specs=[pl.BlockSpec((1, HIST_TILE), lambda i, s_, l_: (0, i))],
        out_specs=pl.BlockSpec((1, N_SYMBOLS), lambda i, s_, l_: (0, 0)),
    )
    out = pl.pallas_call(
        _hist_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, N_SYMBOLS), jnp.int32),
        cost_estimate=pl.CostEstimate(
            flops=npad * N_SYMBOLS,
            bytes_accessed=npad * 4 + N_SYMBOLS * 4,
            transcendentals=0,
        ),
        interpret=interpret,
    )(sarr, larr, b)
    return out[0]


# ------------------------------------------------------ gap-array decoder


def _gap_decode_kernel(
    wstart_ref,  # scalar prefetch: (npad,) absolute window byte starts
    rem_ref,  # (g,) entry-point bit remainders within each window
    tab_ref,  # (8, 128) packed decode table (see module docstring)
    blob_ref,  # (1, lpad) container bytes, HBM-resident (ANY)
    out_ref,  # (g, sub) decoded bytes
    wbuf,  # (g, win) VMEM bitstream windows
    sems,
    *,
    sub,
    win,
    nsub,
):
    i = pl.program_id(0)
    g = out_ref.shape[0]

    for row in range(g):
        li = i * g + row

        @pl.when(li < nsub)
        def _fetch(row=row, li=li):
            dma = pltpu.make_async_copy(
                blob_ref.at[:, pl.dslice(wstart_ref[li], win)],
                wbuf.at[pl.dslice(row, 1), :],
                sems.at[0],
            )
            dma.start()
            dma.wait()

    first = tab_ref[0, : MAX_CODE_LEN + 1]
    count = tab_ref[1, : MAX_CODE_LEN + 1]
    base = tab_ref[2, : MAX_CODE_LEN + 1]
    order = tab_ref[3:5, :].reshape(N_SYMBOLS)
    w = wbuf[...] & 0xFF
    # iota-built constants: a captured jnp.arange would be a trace-level
    # constant, which pallas_call rejects
    ls = 1 + lax.broadcasted_iota(jnp.int32, (1, MAX_CODE_LEN), 1)
    fc = jnp.take(first, ls)  # (1, 15) first codeword per length
    cn = jnp.take(count, ls)

    def step(t, off):
        byte = off >> 3
        look = lax.broadcasted_iota(jnp.int32, (1, 3), 1)
        idx = jnp.clip(byte[:, None] + look, 0, win - 1)
        b3 = jnp.take_along_axis(w, idx, axis=1)
        w24 = (b3[:, 0] << 16) | (b3[:, 1] << 8) | b3[:, 2]
        win15 = (w24 >> (9 - (off & 7))) & ((1 << MAX_CODE_LEN) - 1)
        cand = win15[:, None] >> (MAX_CODE_LEN - ls)
        ok = (cand >= fc) & (cand - fc < cn)
        sel = jnp.argmax(ok, axis=1)  # unique hit: canonical prefix property
        lsel = sel + 1
        csel = jnp.take_along_axis(cand, sel[:, None], axis=1)[:, 0]
        sidx = jnp.take(base, lsel) + csel - jnp.take(first, lsel)
        sym = jnp.take(order, jnp.clip(sidx, 0, N_SYMBOLS - 1))
        out_ref[:, pl.dslice(t, 1)] = sym[:, None]
        return off + lsel

    lax.fori_loop(0, sub, step, rem_ref[...])


@functools.partial(
    jax.jit, static_argnames=("sub", "chunks_per_block", "interpret")
)
def huffman_gap_decode_pallas(
    blob,
    wstarts,
    rems,
    first,
    count,
    base,
    order,
    *,
    sub,
    chunks_per_block=8,
    interpret=False,
):
    """Gap-array parallel canonical-Huffman decode, one launch.

    ``blob`` is the whole container as a flat int32 byte buffer (stays in
    HBM); ``wstarts``/``rems`` are the (nsub,) per-sub-block window byte
    starts and bit remainders (``base_byte + gap >> 3`` / ``gap & 7``);
    ``first``/``count``/``base`` are the (16,) canonical per-length tables
    and ``order`` the (256,) symbol map from
    ``entropy.canonical_tables_jax``.  Returns (nsub, sub) int32 decoded
    bytes; lanes beyond a section's live byte count decode garbage the
    caller masks (exactly like the XLA scan fallback).
    """
    g = chunks_per_block
    win = window_bytes(sub)
    b = jnp.asarray(blob, jnp.int32).reshape(1, -1)
    lpad = _round_up(b.shape[1] + win, 128)
    b = jnp.pad(b, ((0, 0), (0, lpad - b.shape[1])))

    nsub = wstarts.shape[0]
    ws = jnp.clip(jnp.asarray(wstarts, jnp.int32), 0, lpad - win)
    rm = jnp.asarray(rems, jnp.int32)
    pad = (-nsub) % g
    if pad:
        z = jnp.zeros((pad,), jnp.int32)
        ws = jnp.concatenate([ws, z])
        rm = jnp.concatenate([rm, z])
    npad = nsub + pad

    tab = jnp.zeros((8, 128), jnp.int32)
    tab = tab.at[0, : MAX_CODE_LEN + 1].set(jnp.asarray(first, jnp.int32))
    tab = tab.at[1, : MAX_CODE_LEN + 1].set(jnp.asarray(count, jnp.int32))
    tab = tab.at[2, : MAX_CODE_LEN + 1].set(jnp.asarray(base, jnp.int32))
    tab = tab.at[3:5, :].set(jnp.asarray(order, jnp.int32).reshape(2, 128))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(npad // g,),
        in_specs=[
            pl.BlockSpec((g,), lambda i, w_: (i,)),
            pl.BlockSpec((8, 128), lambda i, w_: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((g, sub), lambda i, w_: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, win), jnp.int32),
            pltpu.SemaphoreType.DMA((1,)),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_gap_decode_kernel, sub=sub, win=win, nsub=nsub),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((npad, sub), jnp.int32),
        cost_estimate=pl.CostEstimate(
            flops=npad * sub * (2 * MAX_CODE_LEN + 12),
            bytes_accessed=npad * win * 4 + npad * sub * 4,
            transcendentals=0,
        ),
        interpret=interpret,
    )(ws, rm, tab, b)
    return out[:nsub]
