"""Quickstart: GPULZ compression of multi-byte data.

    PYTHONPATH=src python examples/quickstart.py

Shows the paper's core API: multi-byte symbols (S), window levels (W), chunked
parallel compression, the adaptive parameter selector, and the in-graph
(jittable) path used for gradient/KV compression.
"""

import numpy as np
import jax.numpy as jnp

from repro.core import lzss, quant
from repro.core.params import select_params


def main():
    rng = np.random.default_rng(0)

    # --- 1. uint16 quantization codes (the paper's flagship data type) -----
    t = np.linspace(0, 60 * np.pi, 1 << 19).astype(np.float32)
    field = np.sin(t) * 50 + np.cos(3 * t) * 4
    eb = quant.relative_error_bound(field, 1e-3)
    q = quant.quantize(jnp.asarray(field), error_bound=eb, ndim=1)
    codes = np.asarray(q.codes)

    for s in (1, 2):
        for w in (32, 128):
            cfg = lzss.LZSSConfig(symbol_size=s, window=w, chunk_symbols=2048)
            res = lzss.compress(codes, cfg)
            print(f"S={s} W={w:3d}: ratio {res.ratio:5.2f} "
                  f"({res.orig_bytes} -> {res.total_bytes} bytes)")

    # --- 2. lossless roundtrip ---------------------------------------------
    cfg = lzss.DEFAULT_CONFIG  # paper default C=2048, S=2, W=128
    res = lzss.compress(codes, cfg)
    out = lzss.decompress(res.data)
    assert np.array_equal(out.view(np.uint16), codes.reshape(-1))
    print(f"roundtrip OK at default config, ratio {res.ratio:.2f}")

    # --- 3. adaptive parameter selection (paper §3.2.3) ---------------------
    picked = select_params(codes, level=3)
    print(f"selector picked: S={picked.symbol_size} W={picked.window}")
    noisy = rng.integers(0, 2**31, 1 << 16).astype(np.int32)
    picked2 = select_params(noisy, level=3)
    print(f"selector on incompressible int32: S={picked2.symbol_size} "
          f"(falls back to byte matching)")

    # --- 4. in-graph compression (the jittable core) ------------------------
    import jax

    symbols = lzss.pack_symbols(jnp.asarray(codes.view(np.uint8)), 2)
    symbols = symbols.reshape(-1, cfg.chunk_symbols)
    buf, total = jax.jit(
        lambda s: lzss.compress_chunks(s, cfg)
    )(symbols)
    print(f"in-graph compress_chunks: {symbols.size * 2} -> {int(total)} bytes"
          f" (jit-compatible, used for gradient/KV compression)")


if __name__ == "__main__":
    main()
