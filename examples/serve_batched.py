"""Batched serving with KV-cache block compression.

    PYTHONPATH=src python examples/serve_batched.py

Runs greedy generation for a batch of prompts through the serving engine,
evicting cold KV blocks through the GPULZ block store, and reports the
eviction compression ratio (the paper's multi-byte S=2 path on bf16 data).
"""

import numpy as np

from repro import configs
from repro.launch import steps
from repro.configs.base import TrainConfig
from repro.serving.engine import ServingEngine


def main():
    cfg = configs.reduced_config(configs.get_config("llama3.2-1b"))
    params = steps.init_train_state(cfg, TrainConfig(), 0)["params"]
    engine = ServingEngine(cfg, params, max_len=96, kv_compress=True)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (4, 12)).astype(np.int32)
    result = engine.generate(prompts, max_new_tokens=24)
    print("generated:", result.tokens.shape)
    print("sequence 0:", result.tokens[0].tolist())

    # manually exercise the eviction path on realistic KV data: attention
    # keys are strongly structured (rope bands + repeated prompt segments)
    base = rng.normal(0, 0.05, (16, 2, 16)).astype(np.float16)
    k_block = np.repeat(base, 16, axis=0)  # repeated-segment structure
    # one batched dispatch compresses the whole eviction round
    engine.kv_store.evict_many(
        [(("seq0", b), k_block) for b in range(6)]
    )
    back = engine.kv_store.restore(("seq0", 0))
    assert np.array_equal(back, k_block)
    s = engine.kv_store.stats
    print(f"kv eviction: {s.evictions} blocks, "
          f"{s.evicted_bytes_raw} -> {s.evicted_bytes_stored} bytes "
          f"(ratio {s.eviction_ratio:.2f})")


if __name__ == "__main__":
    main()
