"""Batched serving with the paged-KV capacity tier.

    PYTHONPATH=src python examples/serve_batched.py

Runs greedy generation for a batch of prompts twice: once through the
dense-cache serving engine, once through the paged capacity tier
(`kv_offload=True`) under a resident-block budget smaller than the full
working set — cold blocks are evicted through the GPULZ block store (the
paper's multi-byte S=2 path on bf16 data), their device slots actually
freed, and restored on access (mostly by prefetch).  The two token
streams must be bit-identical; the paging stats show the capacity tier
was really exercised.
"""

import numpy as np

from repro import configs
from repro.launch import steps
from repro.configs.base import TrainConfig
from repro.serving.engine import ServingEngine


def main():
    cfg = configs.reduced_config(configs.get_config("llama3.2-1b"))
    params = steps.init_train_state(cfg, TrainConfig(), 0)["params"]

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (4, 12)).astype(np.int32)

    dense = ServingEngine(cfg, params, max_len=96)
    ref = dense.generate(prompts, max_new_tokens=24)
    print("dense tokens:", ref.tokens.shape)

    # horizon 35 -> 5 blocks/seq * 4 seqs = 20 resident blocks per layer at
    # peak; the full working set is num_layers * 20.  A budget of 24 holds
    # barely more than one layer's blocks, so decode must continuously
    # evict (compress + free slot) and restore (decompress into a fresh
    # slot) while staying exact.
    paged = ServingEngine(
        cfg, params, max_len=96, kv_compress=True, kv_offload=True,
        block_tokens=8, budget_blocks=24,
    )
    out = paged.generate(prompts, max_new_tokens=24)
    assert np.array_equal(out.tokens, ref.tokens), "paged decode diverged"
    print("paged tokens bit-identical to dense:", out.tokens.shape)
    print("sequence 0:", out.tokens[0].tolist())

    s = paged.kv_store.stats
    ps = paged.paging_stats()
    print(f"kv eviction: {s.evictions} blocks in "
          f"{s.eviction_dispatches} batched dispatches, "
          f"{s.evicted_bytes_raw} -> {s.evicted_bytes_stored} bytes "
          f"(ratio {s.eviction_ratio:.2f})")
    print(f"kv restore: {s.restores} blocks in "
          f"{s.restore_dispatches} batched dispatches "
          f"({ps['prefetch_hits']}/{ps['prefetch_issued']} prefetch hits, "
          f"{ps['demand_restores']} demand)")
    print(f"resident high-water: {ps['high_water']} "
          f"<= budget {ps['budget_blocks']} "
          f"(working set {ps['working_set_blocks']})")


if __name__ == "__main__":
    main()
