"""End-to-end training driver: a ~100M-param llama-style model for a few
hundred steps on synthetic data, with GPULZ-compressed checkpoints and
straggler-guarded steps.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 200]

(~100M params: 8 layers x d_model 768 x ffn 2048, vocab 32k.  On this CPU
container a step takes a few seconds; pass --tiny for a quick smoke run.)
"""

import argparse
import sys

sys.argv = [sys.argv[0]]  # re-parsed below via launch.train's CLI

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_lm_ckpt")
    args, _ = ap.parse_known_args()

    class A:
        arch = "llama3.2-1b"
        reduced = bool(args.tiny)
        d_model = 0 if args.tiny else 768
        d_ff = 0 if args.tiny else 2048
        layers = 0 if args.tiny else 8
        steps = 30 if args.tiny else args.steps
        batch = 4
        seq = 256
        lr = 3e-4
        microbatches = 1
        ckpt_dir = args.ckpt_dir
        ckpt_every = 50
        heartbeat = "/tmp/repro_tiny_lm_heartbeat.json"
        log_every = 10

    losses = train_cli.train_loop(A)
    assert losses[-1] < losses[0], "loss must decrease"
    print("OK: loss decreased; checkpoints at", A.ckpt_dir)


if __name__ == "__main__":
    main()
