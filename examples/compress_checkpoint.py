"""Checkpoint compression end-to-end: train state -> GPULZ shards -> restore
onto a (different) mesh — the elastic-restart path.

    PYTHONPATH=src python examples/compress_checkpoint.py
"""

import tempfile

import jax
import numpy as np

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import TrainConfig
from repro.launch import steps


def main():
    cfg = configs.reduced_config(configs.get_config("llama3.2-1b"))
    tc = TrainConfig()
    state = steps.init_train_state(cfg, tc, 0)
    # make the params non-trivial so ratios are honest
    state["params"] = jax.tree.map(
        lambda p: p if p.dtype == np.int32 else p * 1.0, state["params"]
    )

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, compress=True)
        mgr.save(state, 100)
        st = mgr.stats(100)
        print(f"checkpoint: {st['orig_bytes']/1e6:.2f} MB -> "
              f"{st['stored_bytes']/1e6:.2f} MB (ratio {st['ratio']:.2f})")
        # zero-initialized Adam moments dominate the win; bf16 params less so
        restored, step = mgr.restore_latest(jax.eval_shape(lambda: state))
        ok = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored))
        )
        print(f"restored step {step}, bit-exact: {ok}")


if __name__ == "__main__":
    main()
