"""Paper Table 2: compression throughput vs (C, W, S).

This container measures the XLA-CPU pipeline (1 core) — the shape of the
trends (S up => faster, W up => slower, C up => slower) is the reproduction
target; absolute GB/s on TPU comes from the §Roofline analysis of the Pallas
kernel, not from this host."""

from __future__ import annotations

from benchmarks.common import emit, throughput_gbs, time_fn
from repro.core import lzss
from repro.data import datasets


def run(nbytes: int = 1 << 21, dataset: str = "nyx-quant"):
    print("# table2: name,us_per_call,GB/s")
    data = datasets.load(dataset, nbytes)
    for c in (2048, 4096):
        for w in (32, 64, 128, 255):
            for s in (1, 2, 4):
                cfg = lzss.LZSSConfig(symbol_size=s, window=w, chunk_symbols=c)
                t = time_fn(lambda: lzss.compress(data, cfg), warmup=1,
                            iters=2)
                emit(
                    f"table2/{dataset}/C{c}/W{w}/S{s}", t,
                    f"{throughput_gbs(nbytes, t):.4f}",
                )
    # decompression throughput (paper §4.4 tail)
    cfg = lzss.DEFAULT_CONFIG
    blob = lzss.compress(data, cfg).data
    t = time_fn(lambda: lzss.decompress(blob), warmup=1, iters=2)
    emit(f"table2/{dataset}/decompress", t,
         f"{throughput_gbs(nbytes, t):.4f}")


if __name__ == "__main__":
    run()
