"""Reference LZ4-block-format compressor (ratio baseline for Fig. 8/9).

Faithful LZ4 *format* accounting: greedy hash-table matching over a 64 KB
window, min match 4, sequences of [token | literal-length ext | literals |
2-byte offset | match-length ext], final literal run.  Numpy/host — the
paper's nvCOMP LZ4 baseline is closed-source; what matters for Fig. 8 is the
format's ratio behaviour (fixed token overhead vs LZSS flag bits).
"""

from __future__ import annotations

import numpy as np

MIN_MATCH = 4
WINDOW = 1 << 16


def lz4_compressed_size(data: np.ndarray, max_bytes: int | None = None) -> int:
    """Size in bytes of a greedy LZ4-block encoding of ``data``."""
    d = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
    if max_bytes is not None:
        d = d[:max_bytes]
    n = d.size
    if n < 13:
        return n + 1
    # hash table over 4-byte sequences
    dv = d[: n - 3].astype(np.uint32)
    seq = dv | (d[1 : n - 2].astype(np.uint32) << 8) \
        | (d[2 : n - 1].astype(np.uint32) << 16) \
        | (d[3:n].astype(np.uint32) << 24)
    hashes = ((seq * np.uint32(2654435761)) >> np.uint32(16)).astype(np.int64)
    table = {}
    out = 0
    i = 0
    anchor = 0
    limit = n - 12  # LZ4: last 12 bytes are literals
    db = d.tobytes()
    while i < limit:
        h = hashes[i]
        cand = table.get(h, -1)
        table[h] = i
        if (
            cand >= 0
            and i - cand <= WINDOW
            and db[cand : cand + 4] == db[i : i + 4]
        ):
            ln = 4
            maxl = n - i - 5
            while ln < maxl and db[cand + ln] == db[i + ln]:
                ln += 1
            lit = i - anchor
            out += 1 + (max(0, lit - 15) + 254) // 255 + lit  # token+ext+lits
            out += 2 + (max(0, ln - 4 - 15) + 254) // 255     # offset+ext
            i += ln
            anchor = i
        else:
            i += 1
    lit = n - anchor
    out += 1 + (max(0, lit - 15) + 254) // 255 + lit
    return out


def lz4_ratio(data: np.ndarray, max_bytes: int | None = None) -> float:
    d = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
    if max_bytes is not None:
        d = d[:max_bytes]
    return d.size / max(1, lz4_compressed_size(d))
