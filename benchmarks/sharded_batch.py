"""Shard-mapped batch compression vs the single-device batched dispatch.

Times ``lzss.compress_many`` / ``decompress_many`` for a batch of B buffers
with the ``"sharded"`` compressor/decoder pair (the B dimension shard-mapped
over a mesh axis; ``sharding/batch.py``) against the plain single-device
dispatch, and verifies byte identity while at it.

On a CPU container the mesh is *forced host devices*
(``--xla_force_host_platform_device_count``), so absolute numbers measure
dispatch structure only — host "devices" share the same cores and the
sharded path cannot show a real speedup (see EXPERIMENTS.md §Sharded-batch).
On a real multi-chip TPU slice the same sweep measures the actual scaling of
the batch axis.

``--devices`` must take effect before jax initializes, so ``main`` edits
``XLA_FLAGS`` before its (function-local) jax import — run the script
directly (``make bench-sharded`` / ``bench-sharded-smoke``), not from an
already-initialized process.  Importing this module has no side effects.
"""

from __future__ import annotations

import argparse
import json
import os


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count (0 = use existing devices)")
    ap.add_argument("--buffers", type=int, default=16, help="batch size B")
    ap.add_argument("--nbytes", type=int, default=1 << 16,
                    help="bytes per buffer")
    ap.add_argument("--window", type=int, default=64)
    ap.add_argument("--chunk-symbols", type=int, default=2048)
    ap.add_argument("--out-json", default="/tmp/BENCH_sharded.json",
                    help="artifact path (NOT tracked at the repo root: "
                         "forced host-device numbers are dispatch-structure "
                         "only)")
    return ap.parse_args(argv)


def corpus(b: int, nbytes: int) -> list:
    """Run-heavy + noisy buffers (matches AND literals in every container)."""
    import numpy as np

    rng = np.random.default_rng(0)
    out = []
    for _ in range(b):
        runs = np.repeat(
            rng.integers(0, 9, nbytes // 8).astype(np.uint16), 2
        ).view(np.uint8)
        noise = rng.integers(0, 256, nbytes // 4, dtype=np.uint16).view(np.uint8)
        filler = rng.integers(0, 256, nbytes, dtype=np.uint8)
        # pad with noise so any --nbytes works, not just multiples of 8
        buf = np.concatenate([runs, noise, filler])[:nbytes]
        assert buf.size == nbytes
        out.append(buf.copy())
    return out


def run(args) -> dict:
    import jax
    import numpy as np

    from benchmarks.common import emit, throughput_gbs, time_fn
    from repro.core import lzss

    print("# sharded_batch: name,us_per_call,GB/s")
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    items = corpus(args.buffers, args.nbytes)
    total = sum(x.size for x in items)
    kw = dict(
        symbol_size=2, window=args.window, chunk_symbols=args.chunk_symbols
    )
    single = lzss.LZSSConfig(**kw)
    sharded = lzss.LZSSConfig(
        **kw, backend="sharded", decoder="sharded", mesh=mesh
    )

    results = {}
    ref = lzss.compress_many(items, single)
    for name, cfg in (("single-device", single), ("sharded", sharded)):
        t_c = time_fn(lambda: lzss.compress_many(items, cfg))
        batch = lzss.compress_many(items, cfg)
        assert np.array_equal(batch.data, ref.data), f"{name}: blobs diverged"
        mesh_arg = mesh if name == "sharded" else None
        t_d = time_fn(lambda: lzss.decompress_many(batch, mesh=mesh_arg))
        emit(f"sharded_batch/compress-{name}", t_c,
             f"{throughput_gbs(total, t_c):.4f}")
        emit(f"sharded_batch/decompress-{name}", t_d,
             f"{throughput_gbs(total, t_d):.4f}")
        results[name] = {
            "compress_seconds_per_call": t_c,
            "decompress_seconds_per_call": t_d,
            "gb_per_s_compress": throughput_gbs(total, t_c),
            "nbytes_total": int(total),
        }

    record = {
        "benchmark": "sharded_batch",
        "platform": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "forced_host_devices": bool(args.devices),
        "n_devices": jax.device_count(),
        "buffers": args.buffers,
        "byte_identical": True,  # asserted above
        "results": results,
        "sharded_over_single_compress": (
            results["single-device"]["compress_seconds_per_call"]
            / max(results["sharded"]["compress_seconds_per_call"], 1e-12)
        ),
    }
    with open(args.out_json, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {args.out_json}")
    return record


def main(argv=None):
    args = parse_args(argv)
    if args.devices:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={args.devices}"
        ).strip()
    return run(args)


if __name__ == "__main__":
    main()
