"""Byte-level canonical Huffman size estimator (host, numpy).

Used by the Table-3 use case: original cuSZ = Huffman(quant codes);
improved cuSZ = Huffman(GPULZ(quant codes)).  Size-exact (codebook +
bitstream), encoder-only — the use case reports ratios and throughput of the
GPULZ stage; full Huffman decode rides the ``deflate-full`` container
backend (core/entropy.py), not this estimator.

The code-length assignment itself lives in ``repro.core.entropy`` (promoted
from this module when the entropy container subsystem landed); this module
keeps only the size arithmetic on top of it.
"""

from __future__ import annotations

import numpy as np

from repro.core.entropy import huffman_code_lengths

__all__ = [
    "huffman_code_lengths",
    "huffman_compressed_bytes",
    "huffman_ratio",
]


def huffman_compressed_bytes(data: np.ndarray) -> int:
    """Exact canonical-Huffman payload size + 256-entry length table."""
    d = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
    counts = np.bincount(d, minlength=256)
    lengths = huffman_code_lengths(counts)
    bits = int((counts * lengths).sum())
    return (bits + 7) // 8 + 256  # payload + codebook lengths


def huffman_ratio(data: np.ndarray) -> float:
    d = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
    return d.size / max(1, huffman_compressed_bytes(d))
