"""Byte-level canonical Huffman size estimator (host, numpy).

Used by the Table-3 use case: original cuSZ = Huffman(quant codes);
improved cuSZ = Huffman(GPULZ(quant codes)).  Size-exact (codebook +
bitstream), encoder-only — the use case reports ratios and throughput of the
GPULZ stage; Huffman decode is out of scope for this paper.
"""

from __future__ import annotations

import heapq

import numpy as np


def huffman_code_lengths(counts: np.ndarray) -> np.ndarray:
    """Code length per symbol (0 for absent symbols)."""
    heap = [(int(c), i) for i, c in enumerate(counts) if c > 0]
    if len(heap) == 1:
        lengths = np.zeros(counts.size, np.int64)
        lengths[heap[0][1]] = 1
        return lengths
    heapq.heapify(heap)
    # internal nodes: (count, id); track merges to recover depths
    parent = {}
    next_id = counts.size
    heap = [(c, i) for c, i in heap]
    heapq.heapify(heap)
    while len(heap) > 1:
        c1, n1 = heapq.heappop(heap)
        c2, n2 = heapq.heappop(heap)
        parent[n1] = next_id
        parent[n2] = next_id
        heapq.heappush(heap, (c1 + c2, next_id))
        next_id += 1
    lengths = np.zeros(counts.size, np.int64)
    for sym in range(counts.size):
        if counts[sym] == 0:
            continue
        d, node = 0, sym
        while node in parent:
            node = parent[node]
            d += 1
        lengths[sym] = d
    return lengths


def huffman_compressed_bytes(data: np.ndarray) -> int:
    """Exact canonical-Huffman payload size + 256-entry length table."""
    d = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
    counts = np.bincount(d, minlength=256)
    lengths = huffman_code_lengths(counts)
    bits = int((counts * lengths).sum())
    return (bits + 7) // 8 + 256  # payload + codebook lengths


def huffman_ratio(data: np.ndarray) -> float:
    d = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
    return d.size / max(1, huffman_compressed_bytes(d))
