"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load_records(d: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_table(recs, mesh="16x16", compressed=False):
    rows = [r for r in recs
            if r["mesh"] == mesh and r.get("compressed_grads", False) == compressed]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant |"
        " 6ND/HLO | roofline_frac | args GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        rl = r["roofline"]
        arg = r["memory"].get("argument_size_in_bytes", 0) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} | "
            f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
            f"{rl['dominant']} | {rl['useful_flops_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.3f} | {arg:.2f} |"
        )
    return "\n".join(out)


def dominant_summary(recs, mesh="16x16"):
    rows = [r for r in recs if r["mesh"] == mesh
            and not r.get("compressed_grads", False)]
    worst = sorted(rows, key=lambda r: r["roofline"]["roofline_fraction"])[:5]
    coll = sorted(rows, key=lambda r: -r["roofline"]["collective_s"])[:5]
    lines = ["worst roofline fraction:"]
    for r in worst:
        lines.append(f"  {r['arch']} x {r['shape']}: "
                     f"{r['roofline']['roofline_fraction']:.3f} "
                     f"({r['roofline']['dominant']})")
    lines.append("most collective-bound:")
    for r in coll:
        lines.append(f"  {r['arch']} x {r['shape']}: "
                     f"coll={r['roofline']['collective_s']:.3e}s")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = load_records(args.dir)
    print(fmt_table(recs, args.mesh))
    print()
    print(dominant_summary(recs, args.mesh))


if __name__ == "__main__":
    main()
