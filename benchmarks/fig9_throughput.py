"""Paper Fig. 9: throughput comparison — gpulz default vs gpulz-best-speed
(fastest config) vs CULZSS-workflow emulation.

The paper's 22-272x speedup over CULZSS comes from moving encode off the
CPU-sequential path onto the accelerator.  We reproduce that *structure*:
`culzss-workflow` = GPU(XLA) matching + host-python sequential encode (their
Fig. 4a), vs `gpulz` = fully in-graph Kernel I-III (their Fig. 4d).  Both run
on this container's CPU, so the RATIO of the two numbers is the
reproduction; absolute GB/s for TPU comes from §Roofline.

``--backend`` additionally sweeps the pipeline backends (xla baseline vs
fused Pallas Kernel I vs the fused ``fused-deflate`` emit path vs the
single-kernel ``fused-mono`` compressor) and records them in
BENCH_pipeline.json — the perf trajectory of the backend refactors (see
EXPERIMENTS.md §Pipeline).  On CPU the fused backends run their kernels in
interpret mode, so their absolute numbers are NOT meaningful off-TPU; the
JSON tags the platform."""

from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import emit, throughput_gbs, time_fn
from repro.core import encode, lzss, match
from repro.data import datasets


def culzss_workflow_seconds(data: np.ndarray, window=128, c=2048) -> float:
    """GPU-matching + host sequential encode (CULZSS structure)."""
    import time

    n = data.size
    nc = -(-n // c)
    padded = np.zeros(nc * c, np.uint8)
    padded[:n] = data
    symbols = lzss.pack_symbols(padded, 1).reshape(nc, c)
    match.find_matches(symbols, window=window)  # warm the jit

    t0 = time.perf_counter()
    lengths, offsets = map(np.asarray, match.find_matches(symbols,
                                                          window=window))
    # host-side sequential encode per chunk (the CULZSS CPU stage)
    out_bytes = 0
    for k in range(nc):
        i = 0
        while i < c:
            ln = int(lengths[k, i])
            if ln >= 3:
                out_bytes += 2
                i += ln
            else:
                out_bytes += 1
                i += 1
    return time.perf_counter() - t0


def backend_sweep(
    data: np.ndarray,
    backends=("xla", "fused", "fused-deflate", "fused-mono"),
    sweep_nbytes: int = 1 << 16,
    out_json: str = "BENCH_pipeline.json",
    dataset: str = "hurr-quant",
) -> dict:
    """Time each pipeline backend on the same corpus; write BENCH_pipeline.json.

    Uses a smaller slice (``sweep_nbytes``) than the headline numbers: off-TPU
    the fused backend interprets the Pallas kernel body, so large inputs are
    prohibitively slow without telling us anything new.
    """
    slice_ = np.ascontiguousarray(data[:sweep_nbytes])
    results = {}
    for backend in backends:
        cfg = lzss.LZSSConfig(
            symbol_size=2, window=128, chunk_symbols=2048, backend=backend
        )
        t = time_fn(lambda: lzss.compress(slice_, cfg), warmup=1, iters=2)
        gbs = throughput_gbs(slice_.nbytes, t)
        emit(f"fig9/{dataset}/backend-{backend}", t, f"{gbs:.4f}")
        results[backend] = {
            "seconds_per_call": t,
            "gb_per_s": gbs,
            "nbytes": int(slice_.nbytes),
        }
    record = {
        "benchmark": "fig9_backend_sweep",
        "dataset": dataset,
        "platform": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "backends": results,
    }
    # per-backend speedup vs the unfused xla baseline ("fused_over_xla",
    # "fused_deflate_over_xla", ...) — the trajectory the JSON exists for
    if "xla" in results:
        for key, entry in results.items():
            if key == "xla":
                continue
            record[f"{key.replace('-', '_')}_over_xla"] = (
                results["xla"]["seconds_per_call"]
                / max(entry["seconds_per_call"], 1e-12)
            )
    with open(out_json, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {out_json}")
    return record


def run(nbytes: int = 1 << 20, dataset: str = "hurr-quant",
        backend: str = "fused-mono", sweep_nbytes: int = 1 << 16,
        out_json: str = "BENCH_pipeline.json"):
    print("# fig9: name,us_per_call,GB/s")
    data = datasets.load(dataset, nbytes)

    t_gpulz = time_fn(
        lambda: lzss.compress(data, lzss.DEFAULT_CONFIG), warmup=1, iters=2
    )
    emit(f"fig9/{dataset}/gpulz", t_gpulz,
         f"{throughput_gbs(nbytes, t_gpulz):.4f}")

    fast_cfg = lzss.LZSSConfig(symbol_size=4, window=32, chunk_symbols=2048)
    t_fast = time_fn(lambda: lzss.compress(data, fast_cfg), warmup=1, iters=2)
    emit(f"fig9/{dataset}/gpulz-best-speed", t_fast,
         f"{throughput_gbs(nbytes, t_fast):.4f}")

    t_culzss = culzss_workflow_seconds(data)
    emit(f"fig9/{dataset}/culzss-workflow", t_culzss,
         f"{throughput_gbs(nbytes, t_culzss):.4f}")
    emit(f"fig9/{dataset}/speedup-vs-culzss", 0.0,
         f"{t_culzss / t_gpulz:.1f}x|paper=22.2x-avg")

    # pipeline backend sweep: always include the xla baseline (and the
    # intermediate fusion stages when sweeping the fully fused backends, so
    # the JSON separates the Kernel-I win from the Kernel-II/III fusion win
    # from the single-kernel fold)
    if backend == "xla":
        backends = ("xla",)
    elif backend == "fused-deflate":
        backends = ("xla", "fused", "fused-deflate")
    elif backend == "fused-mono":
        backends = ("xla", "fused", "fused-deflate", "fused-mono")
    else:
        backends = ("xla", backend)
    backend_sweep(data, backends=backends, sweep_nbytes=sweep_nbytes,
                  out_json=out_json, dataset=dataset)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nbytes", type=int, default=1 << 20)
    ap.add_argument("--dataset", default="hurr-quant")
    ap.add_argument("--backend", default="fused-mono",
                    choices=sorted(lzss.available_backends()),
                    help="pipeline backend to sweep against the xla baseline")
    ap.add_argument("--sweep-nbytes", type=int, default=1 << 16,
                    help="corpus slice for the backend sweep (interpret mode "
                         "makes fused slow off-TPU)")
    ap.add_argument("--out-json", default="BENCH_pipeline.json",
                    help="sweep artifact path (point smoke runs elsewhere "
                         "so the tracked perf record isn't clobbered)")
    args = ap.parse_args()
    run(nbytes=args.nbytes, dataset=args.dataset, backend=args.backend,
        sweep_nbytes=args.sweep_nbytes, out_json=args.out_json)
