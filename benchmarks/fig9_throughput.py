"""Paper Fig. 9: throughput comparison — gpulz default vs gpulz-best-speed
(fastest config) vs CULZSS-workflow emulation.

The paper's 22-272x speedup over CULZSS comes from moving encode off the
CPU-sequential path onto the accelerator.  We reproduce that *structure*:
`culzss-workflow` = GPU(XLA) matching + host-python sequential encode (their
Fig. 4a), vs `gpulz` = fully in-graph Kernel I-III (their Fig. 4d).  Both run
on this container's CPU, so the RATIO of the two numbers is the
reproduction; absolute GB/s for TPU comes from §Roofline."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, throughput_gbs, time_fn
from repro.core import encode, lzss, match
from repro.data import datasets


def culzss_workflow_seconds(data: np.ndarray, window=128, c=2048) -> float:
    """GPU-matching + host sequential encode (CULZSS structure)."""
    import time

    cfg = lzss.LZSSConfig(symbol_size=1, window=window, chunk_symbols=c)
    n = data.size
    nc = -(-n // c)
    padded = np.zeros(nc * c, np.uint8)
    padded[:n] = data
    symbols = lzss.pack_symbols(padded, 1).reshape(nc, c)
    match.find_matches(symbols, window=window)  # warm the jit

    t0 = time.perf_counter()
    lengths, offsets = map(np.asarray, match.find_matches(symbols,
                                                          window=window))
    # host-side sequential encode per chunk (the CULZSS CPU stage)
    out_bytes = 0
    for k in range(nc):
        i = 0
        while i < c:
            ln = int(lengths[k, i])
            if ln >= 3:
                out_bytes += 2
                i += ln
            else:
                out_bytes += 1
                i += 1
    return time.perf_counter() - t0


def run(nbytes: int = 1 << 20, dataset: str = "hurr-quant"):
    print("# fig9: name,us_per_call,GB/s")
    data = datasets.load(dataset, nbytes)

    t_gpulz = time_fn(
        lambda: lzss.compress(data, lzss.DEFAULT_CONFIG), warmup=1, iters=2
    )
    emit(f"fig9/{dataset}/gpulz", t_gpulz,
         f"{throughput_gbs(nbytes, t_gpulz):.4f}")

    fast_cfg = lzss.LZSSConfig(symbol_size=4, window=32, chunk_symbols=2048)
    t_fast = time_fn(lambda: lzss.compress(data, fast_cfg), warmup=1, iters=2)
    emit(f"fig9/{dataset}/gpulz-best-speed", t_fast,
         f"{throughput_gbs(nbytes, t_fast):.4f}")

    t_culzss = culzss_workflow_seconds(data)
    emit(f"fig9/{dataset}/culzss-workflow", t_culzss,
         f"{throughput_gbs(nbytes, t_culzss):.4f}")
    emit(f"fig9/{dataset}/speedup-vs-culzss", 0.0,
         f"{t_culzss / t_gpulz:.1f}x|paper=22.2x-avg")


if __name__ == "__main__":
    run()
