"""Roofline analysis for the GPULZ Pallas kernel itself (TPU v5e model).

The matching kernel is VPU (vector-unit) work — equality compares and integer
doubling recurrences, no MXU formulation exists (DESIGN.md §2).  Terms:

  compute:  ops/symbol = W * (c_eq + 4*levels + c_sel)  on 8x128 int lanes
            VPU peak ~= 8*128 lanes * 4 ALUs * 0.94 GHz ~= 3.85e12 op/s
  memory:   fused Kernel I streams x once (4 B/sym as i32) and writes
            len/off (8 B/sym): ~12 B/sym  ->  819/12e-9 = 68 G sym/s bound
  => compute-bound everywhere; the S-knob (multi-byte symbols) divides the
     per-BYTE cost by S — exactly the paper's throughput argument.

The unfused XLA pipeline (paper workflow (c)) additionally materializes the
equality/run-length intermediates in HBM each of the W iterations; its
bytes/symbol come from cost_analysis of compress_chunks, giving the
fused-vs-unfused comparison (paper Fig. 4 (c) vs (d)) quantitatively.
"""

from __future__ import annotations


from benchmarks.common import emit

VPU_OPS = 3.85e12      # int ops/s/chip (8x128 lanes x 4 ALUs x 0.94 GHz)
HBM_BW = 819e9


def levels_for(window: int) -> int:
    cap = min(window, 255)
    k = 0
    while (1 << k) < cap:
        k += 1
    return k


def kernel_ops_per_symbol(window: int) -> float:
    """Fused-kernel vector ops per symbol (matching phase; selection ~O(1))."""
    return window * (2 + 4 * levels_for(window) + 5)


def analytic(run_xla_comparison: bool = True):
    print("# kernel_roofline: name,us_per_call,derived")
    for w in (32, 64, 128, 255):
        ops = kernel_ops_per_symbol(w)
        sym_s = VPU_OPS / ops
        for s in (1, 2, 4):
            gbs = sym_s * s / 1e9
            emit(f"kernel/analytic/W{w}/S{s}", 0.0,
                 f"{gbs:.2f}GB/s-compute-bound")
        mem_bound = HBM_BW / 12 / 1e9
        emit(f"kernel/analytic/W{w}/mem-bound", 0.0,
             f"{mem_bound:.1f}Gsym/s (not binding: {sym_s/1e9:.2f}G compute)")

    if not run_xla_comparison:
        return
    # unfused XLA pipeline bytes/flops per symbol via cost_analysis
    import jax
    import jax.numpy as jnp
    from repro.core import lzss

    nc, c = 64, 2048
    cfg = lzss.LZSSConfig(symbol_size=2, window=64, chunk_symbols=c)
    lowered = jax.jit(
        lambda x: lzss.compress_chunks(x, cfg)
    ).lower(jax.ShapeDtypeStruct((nc, c), jnp.int32))
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    n = nc * c
    flops_sym = cost.get("flops", 0) / n
    bytes_sym = cost.get("bytes accessed", 0) / n
    emit("kernel/xla-unfused/W64/flops-per-symbol", 0.0, f"{flops_sym:.0f}")
    emit("kernel/xla-unfused/W64/bytes-per-symbol", 0.0, f"{bytes_sym:.0f}")
    fused_bytes = 12.0
    emit("kernel/fused-vs-unfused/hbm-reduction", 0.0,
         f"{bytes_sym / fused_bytes:.0f}x")


if __name__ == "__main__":
    analytic()
