"""Lossy frontend: ratio/throughput vs error bound for the ``lossy-fz`` pair.

The lossless sweeps (fig8/fig9/fig10) exclude the method-2 pair — its ratio
is a function of the error bound, which those registry-generic sweeps have no
axis for.  This driver IS that axis: the same f32 corpus slice (the
``hurr-field`` surrogate — the hurr quant dataset's pre-quantization field)
compresses at each bound in the sweep, and every row records ratio, compress
and decode throughput, and the measured ``max |x' - x|``.

Every row *asserts* reconstruction within its bound before it is written —
a BENCH_lossy.json that exists at all certifies the bound held at every
point, on the platform named inside it.  The ``eb = 0`` row is the bit-exact
passthrough mode and doubles as the lossless reference ratio.

On CPU the Pallas inner kernels run in interpret mode, so absolute
throughput numbers are NOT meaningful off-TPU (same interpretation rules as
BENCH_pipeline.json); ratios and the bound check are platform-independent.
The schema of the tracked artifact is guarded by tests/test_benchmarks.py
(``make check-bench``)."""

from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import emit, throughput_gbs, time_fn
from repro.core import lzss
from repro.data import datasets

# ratio-vs-bound sweep points; 0.0 = the bit-exact passthrough reference
EBS = (1e-2, 1e-3, 1e-4, 0.0)


def _eb_key(eb: float) -> str:
    return f"{eb:g}"


def lossy_sweep(
    data: np.ndarray,
    ebs=EBS,
    sweep_nbytes: int = 1 << 16,
    out_json: str = "BENCH_lossy.json",
    dataset: str = "hurr-field",
    inner: str = "auto",
) -> dict:
    """Compress/decode the same f32 slice at each bound; write the JSON.

    ``data`` is a uint8 view of an f32 stream (``datasets.load``'s layout).
    Raises AssertionError if any row's reconstruction violates its bound —
    the artifact is only ever written with every row certified.
    """
    nbytes = (sweep_nbytes // 4) * 4
    slice_ = np.ascontiguousarray(data[:nbytes])
    x = slice_.view(np.float32)
    results = {}
    for eb in ebs:
        cfg = lzss.LZSSConfig(
            symbol_size=4, window=128, chunk_symbols=2048,
            backend="lossy-fz", lossy_eb=eb, lossy_inner=inner,
        )
        res = lzss.compress(slice_, cfg)
        t_c = time_fn(lambda: lzss.compress(slice_, cfg), warmup=1, iters=2)
        blob = res.data
        t_d = time_fn(lambda: lzss.decompress(blob), warmup=1, iters=2)
        rec = np.asarray(lzss.decompress(blob)).view(np.float32)
        fin = np.isfinite(x)
        assert np.array_equal(
            rec[~fin].view(np.uint32), x[~fin].view(np.uint32)
        ), f"eb={eb}: non-finite elements must round-trip bit-exactly"
        max_err = float(np.max(np.abs(rec[fin] - x[fin]))) if fin.any() else 0.0
        if eb == 0.0:
            assert np.array_equal(
                rec.view(np.uint32), x.view(np.uint32)
            ), "eb=0 must be bit-exact"
        else:
            assert max_err <= float(np.float32(eb)), (
                f"eb={eb}: max err {max_err} violates the bound"
            )
        emit(f"fig_lossy/{dataset}/eb-{_eb_key(eb)}", t_c,
             f"{res.ratio:.4f}")
        results[_eb_key(eb)] = {
            "eb": float(eb),
            "ratio": float(res.ratio),
            "total_bytes": int(res.total_bytes),
            "orig_bytes": int(slice_.nbytes),
            "nbytes": int(slice_.nbytes),
            "max_abs_err": max_err,
            "bound_ok": True,  # asserted above; recorded for the schema
            "compress_seconds_per_call": t_c,
            "compress_gb_per_s": throughput_gbs(slice_.nbytes, t_c),
            "decode_seconds_per_call": t_d,
            "decode_gb_per_s": throughput_gbs(slice_.nbytes, t_d),
        }
    record = {
        "benchmark": "fig_lossy_sweep",
        "dataset": dataset,
        "platform": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "inner": inner,
        "ebs": results,
    }
    # the headline the sweep exists for: how much ratio each bound buys
    # over the bit-exact reference on the same corpus
    lossless_key = _eb_key(0.0)
    if lossless_key in results:
        base = results[lossless_key]["ratio"]
        for key, entry in results.items():
            if key != lossless_key:
                record[f"eb_{key}_over_lossless"] = entry["ratio"] / max(
                    base, 1e-12
                )
    with open(out_json, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {out_json}")
    return record


def run(nbytes: int = 1 << 20, dataset: str = "hurr-field",
        sweep_nbytes: int = 1 << 16, inner: str = "auto",
        out_json: str = "BENCH_lossy.json"):
    print("# fig_lossy: name,us_per_call,ratio")
    data = datasets.load(dataset, nbytes)
    lossy_sweep(data, sweep_nbytes=sweep_nbytes, out_json=out_json,
                dataset=dataset, inner=inner)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nbytes", type=int, default=1 << 20)
    ap.add_argument("--dataset", default="hurr-field",
                    help="f32 corpus (uint8 view of an f32 stream)")
    ap.add_argument("--sweep-nbytes", type=int, default=1 << 16,
                    help="corpus slice for the sweep (interpret mode makes "
                         "the inner kernels slow off-TPU)")
    ap.add_argument("--inner", default="auto",
                    help="inner lossless stage registry key "
                         "('auto'/'deflate-full'/...)")
    ap.add_argument("--out-json", default="BENCH_lossy.json",
                    help="sweep artifact path (point smoke runs elsewhere "
                         "so the tracked record isn't clobbered)")
    args = ap.parse_args()
    run(nbytes=args.nbytes, dataset=args.dataset,
        sweep_nbytes=args.sweep_nbytes, inner=args.inner,
        out_json=args.out_json)
