"""Decode-side throughput: decoder backend sweep (xla-parallel baseline vs
the fused Pallas decoder, plus the paper-faithful xla-scan oracle on demand).

The paper only parallelizes decompression at chunk granularity (the
``xla-scan`` structure); this repo's restore paths (KV block restore,
checkpoint load, serving cold-block fetch) ride the decoder registry in
core/pipeline.py, where ``xla-parallel`` is the unfused beyond-paper decoder
and ``fused`` keeps the whole decode chain (flag scan, the two prefix sums,
payload gather, pointer-doubling copy resolution) in VMEM per chunk block —
the decode-side mirror of the Fig. 4(c)->(d) compression comparison.

``--decoder`` sweeps registry keys against the ``xla-parallel`` baseline and
writes ``BENCH_decode.json``.  On CPU the fused decoder runs the Pallas
kernel in interpret mode, so its absolute number is NOT meaningful off-TPU;
the JSON tags the platform (same interpretation rules as BENCH_pipeline.json,
see EXPERIMENTS.md §Decode)."""

from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import emit, throughput_gbs, time_fn
from repro.core import lzss
from repro.data import datasets


def decoder_sweep(
    data: np.ndarray,
    decoders=("xla-parallel", "fused"),
    sweep_nbytes: int = 1 << 16,
    out_json: str = "BENCH_decode.json",
    dataset: str = "hurr-quant",
) -> dict:
    """Time each registered decoder on the same container; write the JSON.

    Throughput is measured in *decoded* (original) bytes per second — the
    figure a restore path cares about.  A smaller slice than the headline
    numbers keeps interpret-mode runs tractable off-TPU.
    """
    slice_ = np.ascontiguousarray(data[:sweep_nbytes])
    res = lzss.compress(slice_, lzss.DEFAULT_CONFIG)
    results = {}
    for decoder in decoders:
        key = lzss.resolve_decoder(decoder)
        t = time_fn(
            lambda: lzss.decompress(res.data, decoder=key), warmup=1, iters=2
        )
        gbs = throughput_gbs(slice_.nbytes, t)
        emit(f"fig10/{dataset}/decoder-{key}", t, f"{gbs:.4f}")
        results[key] = {
            "seconds_per_call": t,
            "gb_per_s": gbs,
            "nbytes": int(slice_.nbytes),
        }
    record = {
        "benchmark": "fig10_decoder_sweep",
        "dataset": dataset,
        "platform": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "container_bytes": int(res.total_bytes),
        "ratio": res.ratio,
        "decoders": results,
    }
    if "xla-parallel" in results and "fused" in results:
        record["fused_over_xla_parallel"] = (
            results["xla-parallel"]["seconds_per_call"]
            / max(results["fused"]["seconds_per_call"], 1e-12)
        )
    with open(out_json, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {out_json}")
    return record


def run(nbytes: int = 1 << 20, dataset: str = "hurr-quant",
        decoder: str = "fused", sweep_nbytes: int = 1 << 16,
        out_json: str = "BENCH_decode.json"):
    print("# fig10: name,us_per_call,GB/s")
    data = datasets.load(dataset, nbytes)

    # headline: default-config container, decoded with the XLA baseline
    res = lzss.compress(data, lzss.DEFAULT_CONFIG)
    t = time_fn(
        lambda: lzss.decompress(res.data, decoder="xla-parallel"),
        warmup=1, iters=2,
    )
    emit(f"fig10/{dataset}/gpulz-decode", t,
         f"{throughput_gbs(data.nbytes, t):.4f}")

    # decoder sweep: always include the xla-parallel baseline so the JSON
    # records both sides of the comparison
    decoders = (
        ("xla-parallel",) if lzss.resolve_decoder(decoder) == "xla-parallel"
        else ("xla-parallel", decoder)
    )
    decoder_sweep(data, decoders=decoders, sweep_nbytes=sweep_nbytes,
                  out_json=out_json, dataset=dataset)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nbytes", type=int, default=1 << 20)
    ap.add_argument("--dataset", default="hurr-quant")
    ap.add_argument("--decoder", default="fused",
                    choices=sorted(lzss.available_decoders()) + ["auto"],
                    help="decoder to sweep against the xla-parallel baseline")
    ap.add_argument("--sweep-nbytes", type=int, default=1 << 16,
                    help="corpus slice for the decoder sweep (interpret mode "
                         "makes fused slow off-TPU)")
    ap.add_argument("--out-json", default="BENCH_decode.json",
                    help="sweep artifact path (point smoke runs elsewhere "
                         "so the tracked perf record isn't clobbered)")
    args = ap.parse_args()
    run(nbytes=args.nbytes, dataset=args.dataset, decoder=args.decoder,
        sweep_nbytes=args.sweep_nbytes, out_json=args.out_json)
