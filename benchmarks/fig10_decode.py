"""Decode-side throughput: generic sweep over ALL registered decoders.

The paper only parallelizes decompression at chunk granularity (the
``xla-scan`` structure); this repo's restore paths (KV block restore,
checkpoint load, serving cold-block fetch) ride the decoder registry in
core/pipeline.py, where ``xla-parallel`` is the unfused beyond-paper decoder,
``fused`` keeps the whole decode chain in VMEM per chunk block (sections
still gathered by XLA), and ``fused-mono`` is the single-launch decoder that
reads the container blob straight from HBM — the decode-side mirror of the
Fig. 4(c)->(d) compression comparison.

The sweep enumerates ``lzss.available_decoders()`` generically (plus any
decoder registered by the embedding application), so a newly registered
decoder joins ``BENCH_decode.json`` automatically and the schema guard in
tests/test_benchmarks.py fails if one goes missing.  Every non-baseline
decoder gets a ``<decoder>_over_xla_parallel`` speedup key (dashes
underscored).  On CPU the Pallas decoders run in interpret mode, so their
absolute numbers are NOT meaningful off-TPU; the JSON tags the platform
(same interpretation rules as BENCH_pipeline.json, see EXPERIMENTS.md
§Decode)."""

from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import emit, throughput_gbs, time_fn
from repro.core import lzss
from repro.data import datasets

BASELINE = "xla-parallel"


def ratio_key(decoder: str) -> str:
    """JSON key for a decoder's speedup over the baseline."""
    return f"{decoder.replace('-', '_')}_over_{BASELINE.replace('-', '_')}"


def decoder_sweep(
    data: np.ndarray,
    decoders=None,
    sweep_nbytes: int = 1 << 16,
    out_json: str = "BENCH_decode.json",
    dataset: str = "hurr-quant",
) -> dict:
    """Time each registered decoder on the same container; write the JSON.

    ``decoders=None`` sweeps every *lossless* key in
    ``lzss.available_decoders()`` — the method-2 ``lossy-fz`` decoder only
    accepts lossy containers, whose geometry depends on the error bound;
    benchmarks/fig_lossy.py times that pair across its bound sweep instead.
    Throughput is measured in *decoded* (original) bytes per second — the
    figure a restore path cares about.  A smaller slice than the headline
    numbers keeps interpret-mode runs tractable off-TPU.
    """
    from repro.core import format as fmt, pipeline

    if decoders is None:
        decoders = tuple(
            d for d in lzss.available_decoders()
            if pipeline.container_method(d) != fmt.METHOD_LOSSY
        )
    slice_ = np.ascontiguousarray(data[:sweep_nbytes])
    res = lzss.compress(slice_, lzss.DEFAULT_CONFIG)
    # each decoder gets a container of its own method: the raw decoders time
    # the method-0 LZSS container, the entropy decoder a method-1 one (a raw
    # container is a ValueError for it by design, and vice versa)
    per_method = {pipeline.container_method("auto"): res}
    results = {}
    for decoder in decoders:
        key = lzss.resolve_decoder(decoder)
        method = pipeline.container_method(key)
        if method not in per_method:
            cfg = lzss.LZSSConfig(
                symbol_size=lzss.DEFAULT_CONFIG.symbol_size,
                window=lzss.DEFAULT_CONFIG.window,
                chunk_symbols=lzss.DEFAULT_CONFIG.chunk_symbols,
                backend="deflate-full",
            )
            per_method[method] = lzss.compress(slice_, cfg)
        blob = per_method[method].data
        t = time_fn(
            lambda: lzss.decompress(blob, decoder=key), warmup=1, iters=2
        )
        gbs = throughput_gbs(slice_.nbytes, t)
        emit(f"fig10/{dataset}/decoder-{key}", t, f"{gbs:.4f}")
        results[key] = {
            "seconds_per_call": t,
            "gb_per_s": gbs,
            "nbytes": int(slice_.nbytes),
        }
    record = {
        "benchmark": "fig10_decoder_sweep",
        "dataset": dataset,
        "platform": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "container_bytes": int(res.total_bytes),
        "ratio": res.ratio,
        "decoders": results,
    }
    if BASELINE in results:
        base_t = results[BASELINE]["seconds_per_call"]
        for key, entry in results.items():
            if key != BASELINE:
                record[ratio_key(key)] = base_t / max(
                    entry["seconds_per_call"], 1e-12
                )
    with open(out_json, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {out_json}")
    return record


def run(nbytes: int = 1 << 20, dataset: str = "hurr-quant",
        decoders: str = "all", sweep_nbytes: int = 1 << 16,
        out_json: str = "BENCH_decode.json"):
    print("# fig10: name,us_per_call,GB/s")
    data = datasets.load(dataset, nbytes)

    # headline: default-config container, decoded with the XLA baseline
    res = lzss.compress(data, lzss.DEFAULT_CONFIG)
    t = time_fn(
        lambda: lzss.decompress(res.data, decoder=BASELINE),
        warmup=1, iters=2,
    )
    emit(f"fig10/{dataset}/gpulz-decode", t,
         f"{throughput_gbs(data.nbytes, t):.4f}")

    # decoder sweep: every registered decoder by default, so the tracked
    # JSON always records one entry per registry key (schema-guarded); a
    # restricted list always keeps the baseline so the speedup keys exist
    if decoders == "all":
        keys = None
    else:
        keys = tuple(dict.fromkeys(
            [BASELINE] + [d for d in decoders.split(",") if d]
        ))
    decoder_sweep(data, decoders=keys, sweep_nbytes=sweep_nbytes,
                  out_json=out_json, dataset=dataset)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nbytes", type=int, default=1 << 20)
    ap.add_argument("--dataset", default="hurr-quant")
    ap.add_argument("--decoders", default="all",
                    help="comma-separated registry keys to sweep against the "
                         f"{BASELINE} baseline, or 'all' (default) for every "
                         "registered decoder")
    ap.add_argument("--sweep-nbytes", type=int, default=1 << 16,
                    help="corpus slice for the decoder sweep (interpret mode "
                         "makes the Pallas decoders slow off-TPU)")
    ap.add_argument("--out-json", default="BENCH_decode.json",
                    help="sweep artifact path (point smoke runs elsewhere "
                         "so the tracked perf record isn't clobbered)")
    args = ap.parse_args()
    run(nbytes=args.nbytes, dataset=args.dataset, decoders=args.decoders,
        sweep_nbytes=args.sweep_nbytes, out_json=args.out_json)
