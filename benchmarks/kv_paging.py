"""Paged-KV capacity tier: decode throughput vs. resident-block budget.

Sweeps ``ServingEngine(kv_offload=True)`` across resident budgets between
the per-layer peak (the smallest budget that can be exact) and the full
all-layers working set (no eviction pressure), against the dense-cache
engine as baseline.  Every paged point must stay bit-identical to the dense
tokens — the sweep *asserts* exactness, so BENCH_kv.json is a correctness
record as much as a perf one.

Numbers on CPU measure dispatch structure (eviction/restore rounds, batched
dispatch counts, prefetch hit rates), NOT real accelerator decode speed:
the per-layer launches run XLA-on-CPU and the GPULZ eviction codec runs the
platform "auto" pipeline (see EXPERIMENTS.md §Serving).
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro import configs
from repro.models import model as model_lib
from repro.serving.engine import ServingEngine


def _timed_generate(eng, prompts, new_tokens):
    """(result, seconds) with jit compiles warmed by an identical dry run."""
    eng.generate(prompts, max_new_tokens=new_tokens)
    t0 = time.perf_counter()
    r = eng.generate(prompts, max_new_tokens=new_tokens)
    return r, time.perf_counter() - t0


def paging_sweep(budgets=None, batch: int = 4, max_len: int = 64,
                 block_tokens: int = 8, prompt_tokens: int = 8,
                 new_tokens: int = 48, arch: str = "llama3.2-1b",
                 kv_backend: str = "auto", kv_prefetch: bool = True,
                 out_json: str = "BENCH_kv.json") -> dict:
    """Throughput-vs-budget sweep; writes the BENCH_kv.json record."""
    cfg = configs.reduced_config(configs.get_config(arch))
    params = model_lib.init_params(cfg, 0)
    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, cfg.vocab_size, (batch, prompt_tokens)
    ).astype(np.int32)

    dense = ServingEngine(cfg, params, max_len=max_len)
    r_dense, t_dense = _timed_generate(dense, prompts, new_tokens)
    dense_tps = batch * r_dense.steps / t_dense
    emit("kv_paging/dense", t_dense, f"{dense_tps:.1f}tok/s")

    horizon = min(prompt_tokens + new_tokens - 1, max_len - 1)
    blocks_per_seq = (horizon - 1) // block_tokens + 1
    peak = batch * blocks_per_seq            # min exact budget (layer-stream)
    working_set = cfg.num_layers * peak      # no-eviction budget
    if budgets is None:
        third = (working_set - peak) // 3
        budgets = sorted({peak, peak + third, peak + 2 * third, working_set})

    entries = []
    for budget in budgets:
        eng = ServingEngine(
            cfg, params, max_len=max_len, kv_compress=True, kv_offload=True,
            block_tokens=block_tokens, budget_blocks=budget,
            kv_backend=kv_backend, kv_prefetch=kv_prefetch,
        )
        r, t = _timed_generate(eng, prompts, new_tokens)
        exact = bool(np.array_equal(r.tokens, r_dense.tokens))
        assert exact, (
            f"paged decode at budget={budget} diverged from the dense cache"
        )
        tps = batch * r.steps / t
        ps = eng.paging_stats()
        st = eng.kv_store.stats
        entry = {
            "budget_blocks": int(budget),
            "tokens_per_s": tps,
            "seconds": t,
            "exact": exact,
            "evictions": st.evictions,
            "restores": st.restores,
            "eviction_ratio": st.eviction_ratio,
            "eviction_dispatches": st.eviction_dispatches,
            "restore_dispatches": st.restore_dispatches,
            "demand_restores": ps["demand_restores"],
            "prefetch_issued": ps["prefetch_issued"],
            "prefetch_hits": ps["prefetch_hits"],
            "high_water": ps["high_water"],
        }
        entries.append(entry)
        emit(
            f"kv_paging/budget-{budget}", t,
            f"{tps:.1f}tok/s|ev={st.evictions}|rs={st.restores}",
        )

    record = {
        "benchmark": "kv_paging_sweep",
        "arch": arch,
        "platform": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "batch": batch,
        "max_len": max_len,
        "block_tokens": block_tokens,
        "prompt_tokens": prompt_tokens,
        "new_tokens": new_tokens,
        "kv_backend": kv_backend,
        "kv_prefetch": kv_prefetch,
        "working_set_blocks": working_set,
        "peak_layer_blocks": peak,
        "dense": {"tokens_per_s": dense_tps, "seconds": t_dense},
        "budgets": entries,
    }
    with open(out_json, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {out_json}")
    return record


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--block-tokens", type=int, default=8)
    ap.add_argument("--prompt-tokens", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=48)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--kv-backend", default="auto",
                    help="eviction-codec registry key (e.g. deflate-full)")
    ap.add_argument("--no-prefetch", action="store_true")
    ap.add_argument("--budgets", type=int, nargs="*", default=None,
                    help="resident-block budgets to sweep (default: four "
                         "points from the per-layer peak to the working set)")
    ap.add_argument("--out-json", default="BENCH_kv.json",
                    help="sweep artifact path (point smoke runs elsewhere "
                         "so the tracked perf record isn't clobbered)")
    args = ap.parse_args()
    paging_sweep(
        budgets=args.budgets, batch=args.batch, max_len=args.max_len,
        block_tokens=args.block_tokens, prompt_tokens=args.prompt_tokens,
        new_tokens=args.new_tokens, arch=args.arch,
        kv_backend=args.kv_backend, kv_prefetch=not args.no_prefetch,
        out_json=args.out_json,
    )
