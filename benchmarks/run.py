"""Benchmark harness entry point — one module per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [--quick] [--only tableN]
Output: CSV rows ``name,us_per_call,derived`` (+ `#` table headers).
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller datasets (CI-sized)")
    ap.add_argument("--only", default="",
                    help="substring filter: table1|table2|fig8|fig9|table3")
    args = ap.parse_args()
    nbytes = 1 << 19 if args.quick else 1 << 21

    from benchmarks import (fig8_ratio, fig9_throughput, table1_ratio,
                            table2_throughput, table3_usecase)

    suites = {
        "table1": lambda: table1_ratio.run(nbytes=nbytes),
        "table2": lambda: table2_throughput.run(nbytes=nbytes),
        "fig8": lambda: fig8_ratio.run_paper_table(nbytes=nbytes),
        "fig9": lambda: fig9_throughput.run(nbytes=min(nbytes, 1 << 20)),
        "table3": lambda: table3_usecase.run(nbytes=nbytes),
    }
    for name, fn in suites.items():
        if args.only and args.only not in name:
            continue
        print(f"## {name}", file=sys.stderr)
        fn()


if __name__ == "__main__":
    main()
