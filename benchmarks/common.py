"""Shared benchmark helpers: timing + CSV output convention.

Every benchmark prints ``name,us_per_call,derived`` rows (one per paper-table
cell); `derived` carries the table's own metric (compression ratio, GB/s, ...).
"""

from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in seconds (jit-warmed)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or (
            isinstance(out, (tuple, list))
        ) else None
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        try:
            jax.block_until_ready(out)
        except Exception:
            pass
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived) -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def throughput_gbs(nbytes: int, seconds: float) -> float:
    return nbytes / max(seconds, 1e-12) / 1e9
