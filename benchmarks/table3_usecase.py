"""Paper Table 3: the cuSZ use case — error-bounded quantization codes,
with vs without GPULZ before entropy coding (rel_eb 1e-2, A100 in the paper).

  original cuSZ:  field -> Lorenzo quant -> Huffman
  cuSZ + GPULZ:   field -> Lorenzo quant -> GPULZ -> Huffman

Plus the framework's own production variant of the same idea: GPULZ-compressed
*checkpoint* shards (optimizer moments + bf16 params)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, throughput_gbs, time_fn
from benchmarks.huffman import huffman_compressed_bytes
from repro.core import lzss, quant

PAPER = {  # (cusz CR, cusz+gpulz CR)
    "cesm-like": (22.6, 43.2), "hurr-like": (24.3, 29.1),
    "nyx-like": (30.1, 74.8), "rtm-like": (28.6, 249.8),
}


def fields(nbytes):
    n = nbytes // 4
    side2 = int(np.sqrt(n))
    side3 = int(round(n ** (1 / 3)))
    y, x = np.mgrid[0:side2, 0:side2].astype(np.float32) / side2
    z3, y3, x3 = np.mgrid[0:side3, 0:side3, 0:side3].astype(np.float32) / side3
    t = np.linspace(0, 120 * np.pi, n).astype(np.float32)
    return {
        "cesm-like": (np.sin(8 * np.pi * x) * np.cos(2 * np.pi * y) * 20, 2),
        "hurr-like": (np.sin(6 * np.pi * x + 2 * y) * 30 + x * 50, 2),
        "nyx-like": ((np.sin(2 * np.pi * x3) * np.sin(2 * np.pi * y3)
                      * np.sin(2 * np.pi * z3)) * 100, 3),
        "rtm-like": ((np.sin(t) * np.exp(-((t % 60) / 30) ** 2) * 100)
                     .reshape(-1), 1),
    }


def run(nbytes: int = 1 << 21):
    print("# table3: name,us_per_call,CR[|paper]")
    for name, (field, ndim) in fields(nbytes).items():
        field = field.astype(np.float32)
        eb = quant.relative_error_bound(field, 1e-2)
        q = quant.quantize(jnp.asarray(field), error_bound=eb, ndim=ndim)
        codes = np.asarray(q.codes)
        orig = field.nbytes

        cusz = orig / huffman_compressed_bytes(codes)

        cfg = lzss.LZSSConfig(symbol_size=2, window=128, chunk_symbols=4096)
        t_lz = time_fn(lambda: lzss.compress(codes, cfg), warmup=1, iters=2)
        lz = lzss.compress(codes, cfg)
        improved = orig / huffman_compressed_bytes(lz.data)

        p = PAPER.get(name, ("?", "?"))
        emit(f"table3/{name}/cusz", 0.0, f"{cusz:.1f}|paper={p[0]}")
        emit(f"table3/{name}/cusz+gpulz", t_lz,
             f"{improved:.1f}|paper={p[1]}")
        emit(f"table3/{name}/gpulz-throughput", t_lz,
             f"{throughput_gbs(codes.nbytes, t_lz):.4f}GB/s")

    # framework production variant: checkpoint-shard compression
    rng = np.random.default_rng(0)
    m = (rng.normal(0, 1e-3, 1 << 19).astype(np.float32)
         * (rng.random(1 << 19) < 0.05))  # sparse adam moments
    cfg = lzss.LZSSConfig(symbol_size=4, window=64, chunk_symbols=4096)
    t = time_fn(lambda: lzss.compress(m, cfg), warmup=1, iters=2)
    emit("table3/checkpoint-moments/gpulz", t,
         f"{lzss.compress(m, cfg).ratio:.2f}")


if __name__ == "__main__":
    run()
