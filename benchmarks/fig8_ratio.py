"""Paper Fig. 8: compression ratio — gpulz (default C=2048,S=2,W=128) vs
gpulz-best (best over the Table-1 grid) vs CULZSS-style (single-byte LZSS,
W=128 — the paper's apples-to-apples baseline) vs LZ4 block format."""

from __future__ import annotations

from benchmarks.common import emit
from benchmarks.lz4_format import lz4_ratio
from repro.core import lzss
from repro.data import datasets

# Paper Fig. 8 reference ratios (gpulz default / culzss / nvcomp-lz4)
PAPER = {
    "hurr-quant": (4.9, 4.4, 3.2), "hacc-quant": (2.0, 1.9, 1.9),
    "nyx-quant": (7.2, 6.2, 4.0), "tpch-int32": (1.3, 1.4, 1.2),
    "tpch-string": (2.4, 2.6, 2.3), "rtm-float32": (2.9, 2.7, 2.5),
}


def best_ratio(data):
    best = 0.0
    for c in (2048, 4096):
        for w in (32, 64, 128, 255):
            for s in (1, 2, 4):
                cfg = lzss.LZSSConfig(symbol_size=s, window=w, chunk_symbols=c)
                best = max(best, lzss.compress(data, cfg).ratio)
    return best


def run(nbytes: int = 1 << 21):
    print("# fig8: name,us_per_call,ratio[|paper]")
    for ds in datasets.DATASETS:
        data = datasets.load(ds, nbytes)
        gpulz = lzss.compress(data, lzss.DEFAULT_CONFIG).ratio
        culzss = lzss.compress(
            data,
            lzss.LZSSConfig(symbol_size=1, window=128, chunk_symbols=2048),
        ).ratio
        lz4 = lz4_ratio(data, max_bytes=1 << 20)
        best = best_ratio(data)
        p = PAPER.get(ds, ("?",) * 3)
        emit(f"fig8/{ds}/gpulz", 0.0, f"{gpulz:.2f}|paper={p[0]}")
        emit(f"fig8/{ds}/gpulz-best", 0.0, f"{best:.2f}")
        emit(f"fig8/{ds}/culzss-style", 0.0, f"{culzss:.2f}|paper={p[1]}")
        emit(f"fig8/{ds}/lz4-format", 0.0, f"{lz4:.2f}|paper={p[2]}")


if __name__ == "__main__":
    run()
