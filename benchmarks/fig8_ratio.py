"""Compression ratio: generic sweep over ALL registered compressor backends.

The paper's Fig. 8 table (gpulz vs CULZSS-style vs LZ4, per dataset) stays
available behind ``--paper-table``.  The default entry point is the backend
ratio sweep: every key in ``lzss.available_backends()`` compresses the same
corpus slice and the achieved ratio lands in ``BENCH_ratio.json`` — the
ratio-side mirror of the fig9/fig10 throughput sweeps, with the same
registry-generic structure (a newly registered backend joins the JSON
automatically and the schema guard in tests/test_benchmarks.py fails if one
goes missing).

All method-0 (raw LZSS) backends produce byte-identical containers, so their
ratios coincide by construction; the sweep exists to track the *entropy*
trajectory — ``deflate_full_over_fused_mono`` records how much the canonical
Huffman second stage buys over the LZSS-only container on the same corpus
(> 1 on any corpus with a skewed post-LZSS byte histogram; the tracked
artifact is measured at >= 64 KiB where the 272+-byte entropy metadata has
amortized, see EXPERIMENTS.md §Entropy)."""

from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import emit
from benchmarks.lz4_format import lz4_ratio
from repro.core import lzss
from repro.data import datasets

BASELINE = "fused-mono"

# Paper Fig. 8 reference ratios (gpulz default / culzss / nvcomp-lz4)
PAPER = {
    "hurr-quant": (4.9, 4.4, 3.2), "hacc-quant": (2.0, 1.9, 1.9),
    "nyx-quant": (7.2, 6.2, 4.0), "tpch-int32": (1.3, 1.4, 1.2),
    "tpch-string": (2.4, 2.6, 2.3), "rtm-float32": (2.9, 2.7, 2.5),
}


def ratio_key(backend: str) -> str:
    """JSON key for a backend's ratio gain over the baseline."""
    return f"{backend.replace('-', '_')}_over_{BASELINE.replace('-', '_')}"


def ratio_sweep(
    data: np.ndarray,
    backends=None,
    sweep_nbytes: int = 1 << 16,
    out_json: str = "BENCH_ratio.json",
    dataset: str = "hurr-quant",
) -> dict:
    """Compress the same slice with each registered backend; write the JSON.

    ``backends=None`` sweeps every *lossless* key in
    ``lzss.available_backends()`` — the method-2 ``lossy-fz`` pair's ratio
    is a function of its error bound, which this sweep has no axis for
    (benchmarks/fig_lossy.py sweeps ratio vs bound instead).  Ratios
    (unlike the throughput sweeps) are platform-independent, but the JSON
    still tags the platform for provenance.
    """
    from repro.core import format as fmt, pipeline

    if backends is None:
        backends = tuple(
            b for b in lzss.available_backends()
            if pipeline.container_method(b) != fmt.METHOD_LOSSY
        )
    slice_ = np.ascontiguousarray(data[:sweep_nbytes])
    results = {}
    for backend in backends:
        cfg = lzss.LZSSConfig(
            symbol_size=2, window=128, chunk_symbols=2048, backend=backend
        )
        res = lzss.compress(slice_, cfg)
        emit(f"fig8/{dataset}/backend-{backend}", 0.0, f"{res.ratio:.4f}")
        results[backend] = {
            "ratio": float(res.ratio),
            "total_bytes": int(res.total_bytes),
            "orig_bytes": int(slice_.nbytes),
            "nbytes": int(slice_.nbytes),
        }
    record = {
        "benchmark": "fig8_ratio_sweep",
        "dataset": dataset,
        "platform": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "backends": results,
    }
    # per-backend ratio gain vs the LZSS-only fused-mono baseline — the
    # entropy-trajectory numbers the JSON exists for
    if BASELINE in results:
        base = results[BASELINE]["ratio"]
        for key, entry in results.items():
            if key != BASELINE:
                record[ratio_key(key)] = entry["ratio"] / max(base, 1e-12)
    with open(out_json, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# wrote {out_json}")
    return record


def best_ratio(data):
    best = 0.0
    for c in (2048, 4096):
        for w in (32, 64, 128, 255):
            for s in (1, 2, 4):
                cfg = lzss.LZSSConfig(symbol_size=s, window=w, chunk_symbols=c)
                best = max(best, lzss.compress(data, cfg).ratio)
    return best


def run_paper_table(nbytes: int = 1 << 21):
    """The original paper-reference table (Fig. 8 reproduction)."""
    print("# fig8: name,us_per_call,ratio[|paper]")
    for ds in datasets.DATASETS:
        data = datasets.load(ds, nbytes)
        gpulz = lzss.compress(data, lzss.DEFAULT_CONFIG).ratio
        culzss = lzss.compress(
            data,
            lzss.LZSSConfig(symbol_size=1, window=128, chunk_symbols=2048),
        ).ratio
        lz4 = lz4_ratio(data, max_bytes=1 << 20)
        best = best_ratio(data)
        p = PAPER.get(ds, ("?",) * 3)
        emit(f"fig8/{ds}/gpulz", 0.0, f"{gpulz:.2f}|paper={p[0]}")
        emit(f"fig8/{ds}/gpulz-best", 0.0, f"{best:.2f}")
        emit(f"fig8/{ds}/culzss-style", 0.0, f"{culzss:.2f}|paper={p[1]}")
        emit(f"fig8/{ds}/lz4-format", 0.0, f"{lz4:.2f}|paper={p[2]}")


def run(nbytes: int = 1 << 20, dataset: str = "hurr-quant",
        backends: str = "all", sweep_nbytes: int = 1 << 16,
        out_json: str = "BENCH_ratio.json"):
    print("# fig8: name,us_per_call,ratio")
    data = datasets.load(dataset, nbytes)
    # a restricted list always keeps the baseline so the gain keys exist
    if backends == "all":
        keys = None
    else:
        keys = tuple(dict.fromkeys(
            [BASELINE] + [b for b in backends.split(",") if b]
        ))
    ratio_sweep(data, backends=keys, sweep_nbytes=sweep_nbytes,
                out_json=out_json, dataset=dataset)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nbytes", type=int, default=1 << 20)
    ap.add_argument("--dataset", default="hurr-quant")
    ap.add_argument("--backends", default="all",
                    help="comma-separated registry keys to sweep against the "
                         f"{BASELINE} baseline, or 'all' (default) for every "
                         "registered backend")
    ap.add_argument("--sweep-nbytes", type=int, default=1 << 16,
                    help="corpus slice for the ratio sweep (interpret mode "
                         "makes the fused backends slow off-TPU)")
    ap.add_argument("--out-json", default="BENCH_ratio.json",
                    help="sweep artifact path (point smoke runs elsewhere "
                         "so the tracked record isn't clobbered)")
    ap.add_argument("--paper-table", action="store_true",
                    help="print the paper Fig. 8 reference table instead of "
                         "running the backend ratio sweep")
    args = ap.parse_args()
    if args.paper_table:
        run_paper_table(nbytes=args.nbytes)
    else:
        run(nbytes=args.nbytes, dataset=args.dataset, backends=args.backends,
            sweep_nbytes=args.sweep_nbytes, out_json=args.out_json)
