"""Paper Table 1: compression ratio vs (chunk size C, window W, symbol S)
on the six dataset surrogates.  Paper's own measurements are printed in the
last column for calibration (surrogates match character, not bytes)."""

from __future__ import annotations


from benchmarks.common import emit, time_fn
from repro.core import lzss
from repro.data import datasets

# Paper Table 1 reference values at C=2048 (ratio), keyed (dataset, W, S)
PAPER_C2048 = {
    ("hurr-quant", 32, 1): 3.14, ("hurr-quant", 32, 2): 3.77,
    ("hurr-quant", 128, 2): 4.91, ("hurr-quant", 255, 2): 5.32,
    ("hacc-quant", 128, 2): 1.97, ("nyx-quant", 128, 2): 7.19,
    ("tpch-int32", 128, 1): 1.43, ("tpch-int32", 128, 2): 1.34,
    ("tpch-string", 128, 1): 2.57, ("rtm-float32", 128, 4): 2.94,
}


def run(nbytes: int = 1 << 21, chunks=(2048, 4096), windows=(32, 64, 128, 255),
        symbols=(1, 2, 4)):
    print("# table1: name,us_per_call,ratio[|paper]")
    for ds in datasets.DATASETS:
        data = datasets.load(ds, nbytes)
        for c in chunks:
            for w in windows:
                for s in symbols:
                    cfg = lzss.LZSSConfig(symbol_size=s, window=w,
                                          chunk_symbols=c)
                    t = time_fn(lambda: lzss.compress(data, cfg), iters=1)
                    r = lzss.compress(data, cfg).ratio
                    paper = PAPER_C2048.get((ds, w, s))
                    tag = f"{r:.2f}" + (f"|paper={paper}" if paper and c == 2048
                                        else "")
                    emit(f"table1/{ds}/C{c}/W{w}/S{s}", t, tag)


if __name__ == "__main__":
    run()
