# Tier-1 verification + common dev entry points.
# `repro` is importable either via `pip install -e .` (pyproject.toml) or via
# PYTHONPATH=src — the targets below use the latter so they work in the
# offline CI container without an install step.
#
# CI (.github/workflows/ci.yml) runs: test-fast + bench-smoke + check-bench
# on a Python 3.10/3.11 matrix (test-fast includes the golden-corpus format
# pin, tests/test_golden.py), test-multidevice + bench-sharded-smoke in a
# separate multidevice lane (8 forced host devices), test-serving +
# bench-kv-smoke in a serving lane (also 8 forced host devices, for the
# sharded eviction/restore tests), test-property as its own hypothesis
# lane, test-lossy + bench-lossy-smoke in a lossy lane (error-bounded
# frontend conformance), test-async as the crash-consistency/fault-
# injection lane for the async checkpoint writer (pytest-timeout +
# faulthandler so a deadlock fails with stacks instead of hanging), and
# `ruff check` / `ruff format --check` as a separate lint job.

PY ?= python

.PHONY: test test-fast test-multidevice test-property test-serving \
	test-lossy test-async check-bench lint \
	bench-pipeline bench-decode bench-ratio bench-sharded bench-kv \
	bench-lossy bench-sharded-smoke bench-decode-smoke bench-ratio-smoke \
	bench-kv-smoke bench-lossy-smoke bench-smoke bench

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# test_properties.py is excluded here: its strategies deliberately mint
# fresh jit traces per fuzzed geometry, which is the dedicated property
# lane's job (test-property below) — running it in the 2x-Python CI matrix
# would duplicate that wall-clock on every PR.  Likewise the stress-marked
# concurrency tests belong to the async lane (test-async below).  Plain
# `make test` still includes both.
test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow and not stress" \
		--ignore=tests/test_properties.py

# Property-based lane (requires hypothesis: pip install -e .[test]).  The
# ci-property profile (tests/conftest.py) derandomizes the example stream so
# failures reproduce; statistics go to stdout for the CI artifact.
test-property:
	PYTHONPATH=src HYPOTHESIS_PROFILE=ci-property $(PY) -m pytest -q \
		tests/test_properties.py --hypothesis-show-statistics

# Sharding/batch tests with the test process itself seeing 8 (forced host)
# devices: exercises the shard-mapped "sharded" compressor/decoder pair on
# a real mesh — the @multidevice tests that skip under plain tier-1.
test-multidevice:
	PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		$(PY) -m pytest -q tests/test_sharding.py -m "not slow"

# Serving lane: engine + paged-KV capacity-tier tests.  Runs with 8 forced
# host devices so the kv_mesh-sharded eviction/restore tests execute instead
# of skipping (single-device tests are unaffected by the flag).
test-serving:
	PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		$(PY) -m pytest -q tests/test_serving.py tests/test_serving_paged.py

# Lossy lane: the error-bounded frontend (quantize -> bitshuffle -> inner
# lossless stage) end to end — registry pair, bound conformance on the
# adversarial corpora, golden lossy blobs, every consumer wiring (grad
# exchange, KV tier, checkpoint groups, sharded batches), plus the
# hypothesis bound property under the fixed-seed ci-property profile
# (skips cleanly where hypothesis isn't installed).
test-lossy:
	PYTHONPATH=src HYPOTHESIS_PROFILE=ci-property $(PY) -m pytest -q \
		tests/test_lossy.py tests/test_quant.py
	PYTHONPATH=src HYPOTHESIS_PROFILE=ci-property $(PY) -m pytest -q \
		tests/test_properties.py -k lossy

# Async-I/O lane: crash-consistency, fault-injection and concurrency-stress
# harness for the double-buffered background checkpoint writer
# (runtime/async_io.py + the runtime/fault.py FaultyFS seam).  Deadlocks
# must FAIL, not hang CI: pytest-timeout (pip install -e .[test]) bounds
# each test — its flags are auto-omitted where the plugin isn't installed
# (offline container) — and pytest's built-in faulthandler dumps every
# thread's stack as a last resort either way.
test-async:
	PYTHONPATH=src $(PY) -m pytest -q tests/test_async_io.py \
		-p faulthandler -o faulthandler_timeout=300 \
		$$($(PY) -c "import importlib.util as u; print('--timeout=300 --timeout-method=thread' if u.find_spec('pytest_timeout') else '')")

# Schema-validate the tracked BENCH_*.json perf records (catches a smoke run
# accidentally written to the repo root before it clobbers the trajectory)
# plus the core/autotune.py cache schema (a drift there would silently
# invalidate every persisted tuning entry).
check-bench:
	PYTHONPATH=src $(PY) -m pytest -q tests/test_benchmarks.py -k artifact_schema

# Mirrors the CI lint job (requires ruff: pip install -e .[lint]).  Format
# enforcement covers the kernel + sharding subsystems, the serving tier,
# the pipeline module and the autotuner; the rest of src/ converges module
# by module as PRs touch it.
lint:
	ruff check src tests benchmarks
	ruff format --check src/repro/kernels src/repro/sharding \
		src/repro/serving \
		src/repro/core/pipeline.py src/repro/core/autotune.py \
		src/repro/core/entropy.py src/repro/core/lossy.py \
		src/repro/core/bitshuffle.py src/repro/runtime/async_io.py

bench-pipeline:
	PYTHONPATH=src:. $(PY) benchmarks/fig9_throughput.py --backend fused-mono

bench-decode:
	PYTHONPATH=src:. $(PY) benchmarks/fig10_decode.py --decoders all

# Compression-ratio sweep over EVERY registered compressor backend (the
# fig8 headline: deflate-full's entropy stage vs the LZSS-only container).
# Writes the tracked BENCH_ratio.json at the repo root.
bench-ratio:
	PYTHONPATH=src:. $(PY) benchmarks/fig8_ratio.py --backends all

# Shard-mapped batch compression vs the single-device dispatch on a forced
# host mesh (the script sets XLA_FLAGS itself, before importing jax).
bench-sharded:
	PYTHONPATH=src:. $(PY) benchmarks/sharded_batch.py --devices 8

# Paged-KV capacity-tier sweep: decode throughput vs resident-block budget,
# with per-budget exactness asserted against the dense-cache engine.  Writes
# the tracked BENCH_kv.json at the repo root.
bench-kv:
	PYTHONPATH=src:. $(PY) benchmarks/kv_paging.py

# Tiny-size smoke of the paging sweep: real capacity pressure (budget 4 of
# an 8-block working set) but a dozen tokens, so it finishes in seconds.
# JSON to /tmp so the tracked BENCH_kv.json perf record isn't clobbered.
bench-kv-smoke:
	PYTHONPATH=src:. $(PY) benchmarks/kv_paging.py \
		--batch 2 --max-len 32 --prompt-tokens 4 --new-tokens 12 \
		--block-tokens 8 --out-json /tmp/BENCH_kv.smoke.json

# Lossy ratio/throughput-vs-bound sweep; every row asserts reconstruction
# within its bound before the JSON is written.  Writes the tracked
# BENCH_lossy.json at the repo root.
bench-lossy:
	PYTHONPATH=src:. $(PY) benchmarks/fig_lossy.py

# Tiny-size smoke of the lossy sweep: the full bound axis (including the
# bit-exact eb=0 reference row) on a small slice, bound asserted per row.
# JSON to /tmp so the tracked BENCH_lossy.json perf record isn't clobbered.
bench-lossy-smoke:
	PYTHONPATH=src:. $(PY) benchmarks/fig_lossy.py \
		--nbytes 16384 --sweep-nbytes 8192 \
		--out-json /tmp/BENCH_lossy.smoke.json

bench-sharded-smoke:
	PYTHONPATH=src:. $(PY) benchmarks/sharded_batch.py --devices 8 \
		--buffers 8 --nbytes 8192 \
		--out-json /tmp/BENCH_sharded.smoke.json

# Tiny-size smoke of the fig10 decode sweep over EVERY registered decoder
# (the default --decoders all): exercises the generic registry enumeration
# plus the fused-mono single-launch path end to end in seconds.  JSON to
# /tmp so the tracked BENCH_decode.json perf record isn't clobbered.
bench-decode-smoke:
	PYTHONPATH=src:. $(PY) benchmarks/fig10_decode.py \
		--nbytes 16384 --sweep-nbytes 8192 \
		--out-json /tmp/BENCH_decode.smoke.json

# Tiny-size smoke of the fig8 ratio sweep over EVERY registered backend:
# exercises the generic registry enumeration + the deflate-full entropy
# container end to end in seconds.  JSON to /tmp so the tracked
# BENCH_ratio.json perf record isn't clobbered.
bench-ratio-smoke:
	PYTHONPATH=src:. $(PY) benchmarks/fig8_ratio.py \
		--nbytes 16384 --sweep-nbytes 8192 \
		--out-json /tmp/BENCH_ratio.smoke.json

# Tiny-size smoke of all three fig sweeps: exercises the bench scripts end
# to end (compress + decode + ratio + JSON artifacts) in seconds, even in
# interpret mode.  The decode/ratio parts are their own targets so the CI
# steps and local runs share one definition.  JSONs go to /tmp so the
# tracked BENCH_*.json perf records aren't clobbered with meaningless smoke
# numbers.
bench-smoke: bench-decode-smoke bench-ratio-smoke
	PYTHONPATH=src:. $(PY) benchmarks/fig9_throughput.py \
		--nbytes 16384 --sweep-nbytes 8192 \
		--out-json /tmp/BENCH_pipeline.smoke.json

bench:
	PYTHONPATH=src:. $(PY) benchmarks/run.py
