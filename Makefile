# Tier-1 verification + common dev entry points.
# `repro` is importable either via `pip install -e .` (pyproject.toml) or via
# PYTHONPATH=src — the targets below use the latter so they work in the
# offline CI container without an install step.
#
# CI (.github/workflows/ci.yml) runs: test-fast + bench-smoke + check-bench
# on a Python 3.10/3.11 matrix, and `ruff check` / `ruff format --check` as
# a separate lint job.

PY ?= python

.PHONY: test test-fast check-bench lint \
	bench-pipeline bench-decode bench-smoke bench

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow"

# Schema-validate the tracked BENCH_*.json perf records (catches a smoke run
# accidentally written to the repo root before it clobbers the trajectory).
check-bench:
	PYTHONPATH=src $(PY) -m pytest -q tests/test_benchmarks.py -k artifact_schema

# Mirrors the CI lint job (requires ruff: pip install -e .[lint]).
lint:
	ruff check src tests benchmarks
	ruff format --check src/repro/kernels

bench-pipeline:
	PYTHONPATH=src:. $(PY) benchmarks/fig9_throughput.py --backend fused-deflate

bench-decode:
	PYTHONPATH=src:. $(PY) benchmarks/fig10_decode.py --decoder fused

# Tiny-size smoke of both fig sweeps: exercises the bench scripts end to end
# (compress + decode + JSON artifacts) in seconds, even in interpret mode.
# JSONs go to /tmp so the tracked BENCH_*.json perf records aren't clobbered
# with meaningless smoke-size numbers.
bench-smoke:
	PYTHONPATH=src:. $(PY) benchmarks/fig9_throughput.py \
		--nbytes 16384 --sweep-nbytes 8192 \
		--out-json /tmp/BENCH_pipeline.smoke.json
	PYTHONPATH=src:. $(PY) benchmarks/fig10_decode.py \
		--nbytes 16384 --sweep-nbytes 8192 \
		--out-json /tmp/BENCH_decode.smoke.json

bench:
	PYTHONPATH=src:. $(PY) benchmarks/run.py
