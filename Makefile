# Tier-1 verification + common dev entry points.
# `repro` is importable either via `pip install -e .` (pyproject.toml) or via
# PYTHONPATH=src — the targets below use the latter so they work in the
# offline CI container without an install step.

PY ?= python

.PHONY: test test-fast bench-pipeline bench

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow"

bench-pipeline:
	PYTHONPATH=src:. $(PY) benchmarks/fig9_throughput.py --backend fused

bench:
	PYTHONPATH=src:. $(PY) benchmarks/run.py
